"""§5.5: identifying system bottlenecks by tuning subsystems vs combinations.

Paper narrative: the DB tuned alone improves 63%; behind the front-end
cache/LB the composed deployment stays at the untuned-DB level no matter how
long it is tuned => the front end is the bottleneck.
"""
from __future__ import annotations

import time
from typing import List

from repro.core import FrontendSurrogate, MySQLSurrogate, identify_bottleneck

from .common import Row


class _DurableDB:
    """Production policy: durability knobs pinned (no fsync cheating) — this
    keeps the tuned-alone gain in the paper's +63% regime instead of the
    unconstrained surrogate ceiling."""

    def __init__(self):
        self.base = MySQLSurrogate("zipfian_rw")
        self.name = "mysql[durable]"

    def space(self):
        return self.base.space().freeze(
            {"innodb_flush_log_at_trx_commit": 1, "sync_binlog": True})

    def test(self, config):
        full = dict(config)
        full.setdefault("innodb_flush_log_at_trx_commit", 1)
        full.setdefault("sync_binlog", True)
        return self.base.test(full)


def run() -> List[Row]:
    db = _DurableDB()
    fe = FrontendSurrogate(capacity_ceiling=11000.0)
    t0 = time.time()
    report = identify_bottleneck({"db": db, "frontend": fe},
                                 budget_per_system=60, seed=0)
    n = sum(r.n_tests for r in report.member_reports.values()) + \
        report.composed_report.n_tests
    us = (time.time() - t0) * 1e6 / n
    db_rep = report.member_reports["db"]
    comp = report.composed_report
    return [
        ("bottleneck_db_alone_gain", us,
         f"+{(db_rep.improvement - 1) * 100:.0f}%"),
        ("bottleneck_composed_gain", us,
         f"+{(comp.improvement - 1) * 100:.0f}%"),
        ("bottleneck_composed_vs_db_untuned", us,
         f"{comp.best_metric.value / db_rep.default_metric.value:.2f}x"),
        ("bottleneck_identified", us, report.bottleneck),
    ]
