"""§5.3 ("machine-days vs man-months") + §3 resource-limit scalability.

Improvement as a function of the resource limit: the ACTS guarantee is that
relaxing the budget yields an (expected) better configuration.  Also reports
the budget needed to beat the default by 2x — the "days not months" claim in
test units (each test ≈ minutes of machine time on a real deployment, zero
human time).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import MySQLSurrogate, Tuner

from .common import Row

BUDGETS = (10, 25, 50, 100, 200)
SEEDS = (0, 1, 2)


def run() -> List[Row]:
    sut = MySQLSurrogate("zipfian_rw")
    rows: List[Row] = []
    t0 = time.time()
    n_tests = 0
    means = []
    for budget in BUDGETS:
        imps = []
        for seed in SEEDS:
            rep = Tuner(sut.space(), sut, budget=budget, seed=seed).run()
            imps.append(rep.improvement)
            n_tests += rep.n_tests
        means.append(float(np.mean(imps)))
    us = (time.time() - t0) * 1e6 / max(n_tests, 1)
    for budget, m in zip(BUDGETS, means):
        rows.append((f"budget_{budget}_improvement", us, f"{m:.2f}x"))
    rows.append(("budget_monotone_in_expectation", us,
                 bool(all(a <= b + 0.15 for a, b in zip(means, means[1:])))))
    # tests to 2x: machine time, not man-months
    rep = Tuner(sut.space(), sut, budget=200, seed=0).run()
    t2 = next((t.test_index for t in rep.history
               if -t.value > 2 * rep.default_metric.value), -1)
    rows.append(("tests_to_2x_default", us, t2))
    return rows
