"""Shared benchmark plumbing: timing + the name,us_per_call,derived contract."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, List, Tuple

Row = Tuple[str, float, Any]  # (name, us_per_call, derived)


@dataclass
class BenchResult:
    rows: List[Row]
    notes: List[str]


def timed(fn: Callable[[], Any]) -> Tuple[float, Any]:
    t0 = time.time()
    out = fn()
    return (time.time() - t0) * 1e6, out


def fmt_rows(rows: List[Row]) -> str:
    return "\n".join(f"{n},{us:.1f},{d}" for n, us, d in rows)
