"""Co-tuning benchmark: joint (CompositeSpace) vs independent tuning.

The experiment behind the co-tuning subsystem's acceptance criterion: on
the co-deployment surrogate (a serve-throughput model whose optimum depends
on the decode kernel's block choice — ``repro.serve.space``), compare at
EQUAL total test budget:

* ``independent`` — each system tuned in isolation, unaware of the other:
  the kernel on its microbenchmark shape (half the budget), the serve
  engine against stock kernel blocks (the other half); the two winners are
  then deployed together and measured end to end.
* ``sequential`` — the handoff baseline: kernel first (half budget), then
  the serve engine tuned against the *tuned* kernel (half budget).
* ``joint`` — one ``CompositeSUT`` over the merged space, full budget,
  BestConfig-style subspace round-robin.

All three arms are scored by the same end-to-end measurement
(``coupled_serve_metrics``), so the comparison is apples to apples.  The
JSON at ``BENCH_cotune.json`` is the cross-PR perf artifact; ``--check``
exits non-zero if joint underperforms independent (mean over seeds) —
wired into CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List

import numpy as np

from repro.autotune.space import KERNELS
from repro.autotune.sut import KernelSUT
from repro.core.tuner import Tuner
from repro.serve.space import (
    CotuneParams,
    ServeSurrogate,
    coupled_serve_metrics,
    make_cotune_sut,
    serve_knob_space,
)

from .common import Row

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_cotune.json")

# scaled with the serve knob space: the share_prefix/draft_len axes
# (PR 6) widened the joint product past what 96 trials cover — at 160
# the joint arm wins on every default seed instead of coin-flipping
DEFAULT_BUDGET = 160
DEFAULT_SEEDS = (0, 1, 2)


def _tune_kernel_alone(p: CotuneParams, budget: int, seed: int):
    """The kernel team's view: microbenchmark shape, no co-residency."""
    default_batch = serve_knob_space(p.max_seq)["max_batch"].default
    sut = KernelSUT("decode_attention", p.decode_dims(default_batch),
                    dtype=p.dtype, mode="model")
    return Tuner(sut.space(), sut, budget=budget, seed=seed).run()


def _tune_serve_alone(p: CotuneParams, budget: int, seed: int,
                      kernel_cfg=None):
    """The serve team's view: the kernel is whatever config they deploy
    against (stock blocks unless a tuned config is handed over)."""
    sut = ServeSurrogate(p, kernel_cfg=kernel_cfg)
    return Tuner(sut.space(), sut, budget=budget, seed=seed).run()


def trials_to_best(report) -> int:
    """Charged-test index at which the run first scored its best value
    (the paper's convergence-speed lens on the same trial stream).
    Trial values are the minimized objective (sign-normalized), so the
    best is taken from the history itself."""
    best = min(t.value for t in report.history)
    return min(t.test_index for t in report.history if t.value == best)


def one_seed(p: CotuneParams, budget: int, seed: int) -> Dict[str, Any]:
    half = budget // 2

    krep = _tune_kernel_alone(p, half, seed)
    srep = _tune_serve_alone(p, budget - half, seed)
    indep = coupled_serve_metrics(srep.best_config, krep.best_config, p)

    srep_seq = _tune_serve_alone(p, budget - half, seed,
                                 kernel_cfg=krep.best_config)
    seq = coupled_serve_metrics(srep_seq.best_config, krep.best_config, p)

    sut = make_cotune_sut(p)
    jtuner = Tuner(sut.space(), sut, budget=budget, seed=seed,
                   optimizer="subspace_rr")
    jrep = jtuner.run()
    parts = sut.space().split(jrep.best_config)
    joint = coupled_serve_metrics(parts["serve"], parts["kernel"], p)

    # PR 7 ablation: the same joint tune with static feasibility pruning
    # disabled — infeasible candidates (serve configs below the KV-page
    # deployability floor) are charged tests instead of pruned for free
    sut_np = make_cotune_sut(p)
    jrep_np = Tuner(sut_np.space(), sut_np, budget=budget, seed=seed,
                    optimizer="subspace_rr", feasibility=False).run()
    parts_np = sut_np.space().split(jrep_np.best_config)
    joint_np = coupled_serve_metrics(parts_np["serve"],
                                     parts_np["kernel"], p)

    return {
        "seed": seed,
        "independent": {"tput": indep.value,
                        "objective": indep.objective(),
                        "serve": srep.best_config,
                        "kernel": krep.best_config,
                        "n_infeasible_pruned": srep.n_infeasible_pruned
                        + krep.n_infeasible_pruned},
        "sequential": {"tput": seq.value, "objective": seq.objective(),
                       "serve": srep_seq.best_config,
                       "kernel": krep.best_config,
                       "n_infeasible_pruned": srep_seq.n_infeasible_pruned
                       + krep.n_infeasible_pruned},
        # evaluator_calls << n_tests: batched composite rounds dispatch as
        # single test_batch calls through the CompositeSUT
        "joint": {"tput": joint.value, "objective": joint.objective(),
                  "serve": parts["serve"], "kernel": parts["kernel"],
                  "n_tests": jrep.n_tests,
                  "evaluator_calls": jtuner.n_evaluator_calls,
                  "n_infeasible_pruned": jrep.n_infeasible_pruned,
                  "trials_to_best": trials_to_best(jrep)},
        "joint_no_pruning": {"tput": joint_np.value,
                             "n_tests": jrep_np.n_tests,
                             "n_infeasible_pruned":
                                 jrep_np.n_infeasible_pruned,
                             "trials_to_best": trials_to_best(jrep_np)},
    }


def bench(budget: int = DEFAULT_BUDGET,
          seeds=DEFAULT_SEEDS) -> Dict[str, Any]:
    p = CotuneParams()
    per_seed = [one_seed(p, budget, s) for s in seeds]
    means = {arm: float(np.mean([r[arm]["tput"] for r in per_seed]))
             for arm in ("independent", "sequential", "joint")}
    out = {
        "budget": budget,
        "seeds": list(seeds),
        "params": {"max_seq": p.max_seq, "n_layers": p.n_layers,
                   "sla_s": p.sla_s, "dtype": p.dtype},
        "per_seed": per_seed,
        "mean_tput": means,
        "joint_over_independent": means["joint"] / max(means["independent"],
                                                       1e-12),
        "joint_wins": sum(r["joint"]["tput"] >= r["independent"]["tput"]
                          for r in per_seed),
        # PR 7: static-feasibility pruning accounting (pruned candidates
        # are free; the ablation re-runs the joint arm with pruning off)
        "pruning": {
            "joint_pruned_mean": float(np.mean(
                [r["joint"]["n_infeasible_pruned"] for r in per_seed])),
            "joint_trials_to_best_mean": float(np.mean(
                [r["joint"]["trials_to_best"] for r in per_seed])),
            "no_pruning_trials_to_best_mean": float(np.mean(
                [r["joint_no_pruning"]["trials_to_best"]
                 for r in per_seed])),
            "no_pruning_tput_mean": float(np.mean(
                [r["joint_no_pruning"]["tput"] for r in per_seed])),
        },
    }
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    return out


def rows_from(result: Dict[str, Any]) -> List[Row]:
    m = result["mean_tput"]
    return [
        ("cotune_independent_tput", 0.0, f"{m['independent']:.0f} tok/s"),
        ("cotune_sequential_tput", 0.0, f"{m['sequential']:.0f} tok/s"),
        ("cotune_joint_tput", 0.0, f"{m['joint']:.0f} tok/s"),
        ("cotune_joint_over_independent", 0.0,
         f"{result['joint_over_independent']:.2f}x "
         f"({result['joint_wins']}/{len(result['seeds'])} seeds)"),
        ("cotune_joint_pruning", 0.0,
         f"{result['pruning']['joint_pruned_mean']:.1f} pruned free, "
         f"to-best {result['pruning']['joint_trials_to_best_mean']:.0f} "
         f"vs {result['pruning']['no_pruning_trials_to_best_mean']:.0f} "
         "trials (pruning on vs off)"),
    ]


def run() -> List[Row]:
    """benchmarks.run entry point."""
    return rows_from(bench())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=DEFAULT_BUDGET)
    ap.add_argument("--seeds", type=int, nargs="+",
                    default=list(DEFAULT_SEEDS))
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if joint tuning underperforms "
                         "independent tuning at equal budget")
    args = ap.parse_args(argv)
    result = bench(budget=args.budget, seeds=tuple(args.seeds))
    for name, _, derived in rows_from(result):
        print(f"{name},{derived}")
    print(f"wrote {JSON_PATH}")
    if args.check:
        joint = result["mean_tput"]["joint"]
        indep = result["mean_tput"]["independent"]
        if joint < indep:
            print(f"CHECK FAILED: joint ({joint:.0f} tok/s) underperforms "
                  f"independent ({indep:.0f} tok/s) at equal budget",
                  file=sys.stderr)
            return 1
        print(f"check OK: joint {joint:.0f} >= independent {indep:.0f} "
              f"tok/s at budget {result['budget']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
