"""§5.4: fairer benchmarking via objective (automatic) tuning.

Two "vendors" ship the same engine with different default settings: System A
ships half-tuned defaults, System B ships conservative defaults but has the
higher ceiling.  Comparing *defaults* (what naive benchmarking does) picks A;
comparing *ACTS-tuned* deployments — apples-to-apples, both at their
objective best — picks B.  The benchmark reports both rankings and whether
they flip, which is the paper's argument that un-tuned benchmarking results
are "suspicious or misguiding".
"""
from __future__ import annotations

import dataclasses
import time
from typing import List

from repro.core import MySQLSurrogate, Tuner
from repro.core.params import ParameterSpace

from .common import Row


class _ShiftedDefaults:
    """A surrogate whose shipped defaults are partially tuned."""

    def __init__(self, base, overrides, scale=1.0):
        self.base = base
        self.overrides = overrides
        self.scale = scale
        self.name = base.name + "+defaults"

    def space(self) -> ParameterSpace:
        params = []
        for p in self.base.space():
            if p.name in self.overrides:
                params.append(dataclasses.replace(
                    p, default=self.overrides[p.name]))
            else:
                params.append(p)
        return ParameterSpace(params)

    def test(self, config):
        m = self.base.test(config)
        m.value *= self.scale
        return m


def run() -> List[Row]:
    mb = 1024 * 1024
    # System A: vendor ships tuned-ish defaults, lower ceiling (0.55x engine)
    sys_a = _ShiftedDefaults(
        MySQLSurrogate("uniform_read"),
        {"query_cache_type": "ON", "innodb_buffer_pool_size": 8192 * mb},
        scale=0.55,
    )
    # System B: conservative defaults, best engine
    sys_b = MySQLSurrogate("uniform_read")

    t0 = time.time()
    rep_a = Tuner(sys_a.space(), sys_a, budget=120, seed=0).run()
    rep_b = Tuner(sys_b.space(), sys_b, budget=120, seed=0).run()
    us = (time.time() - t0) * 1e6 / (rep_a.n_tests + rep_b.n_tests)

    default_winner = "A" if rep_a.default_metric.value > \
        rep_b.default_metric.value else "B"
    tuned_winner = "A" if rep_a.best_metric.value > \
        rep_b.best_metric.value else "B"
    return [
        ("fair_default_A_vs_B", us,
         f"{rep_a.default_metric.value:.0f} vs {rep_b.default_metric.value:.0f}"),
        ("fair_tuned_A_vs_B", us,
         f"{rep_a.best_metric.value:.0f} vs {rep_b.best_metric.value:.0f}"),
        ("fair_default_winner", us, default_winner),
        ("fair_tuned_winner", us, tuned_winner),
        ("fair_ranking_flips", us, default_winner != tuned_winner),
    ]
