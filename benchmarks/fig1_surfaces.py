"""Figure 1 reproduction: diverging performance surfaces of MySQL, Tomcat and
Spark under different workloads / deployments / co-deployed software.

For each panel we sample the 2-knob projection the paper plots and report a
*divergence statistic* — where the optimum sits and how the surface shape
changes — demonstrating §2.2's point that performance models are SUT-,
workload- and deployment-specific (so samples cannot be reused across them).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import MySQLSurrogate, SparkSurrogate, TomcatSurrogate

from .common import Row


def _surface_stats(sut, kx, ky, n=15):
    xs, ys, z = sut.surface(kx, ky, n)
    i, j = np.unravel_index(np.argmax(z), z.shape)
    # bumpiness: mean abs second difference, normalized
    d2 = np.abs(np.diff(z, n=2, axis=0)).mean() + np.abs(
        np.diff(z, n=2, axis=1)).mean()
    return {
        "argmax": (xs[i], ys[j]),
        "max": float(z.max()),
        "min": float(z.min()),
        "bumpiness": float(d2 / max(z.mean(), 1e-9)),
        "z": z,
    }


def run() -> List[Row]:
    rows: List[Row] = []
    t0 = time.time()

    # (a)/(d): MySQL, workload changes the dominant knob
    a = _surface_stats(MySQLSurrogate("uniform_read"), "query_cache_type",
                       "innodb_buffer_pool_size")
    d = _surface_stats(MySQLSurrogate("zipfian_rw"), "query_cache_type",
                       "innodb_buffer_pool_size")
    qc_gain_read = a["z"][1].max() / a["z"][0].max()  # ON row vs OFF row
    qc_gain_rw = d["z"][1].max() / d["z"][0].max()
    rows.append(("fig1_mysql_qc_dominance_read", 0.0, f"{qc_gain_read:.2f}x"))
    rows.append(("fig1_mysql_qc_dominance_zipf", 0.0, f"{qc_gain_rw:.2f}x"))

    # (b)/(e): Tomcat, co-deployed JVM shifts the optimum location
    tc = TomcatSurrogate(fully_utilized=False)
    b = _surface_stats(tc, "maxThreads", "acceptCount")
    space = tc.space()
    base = space.default_config()

    def best_threads(tsr):
        vals = []
        for mt in space["maxThreads"].grid(40):
            cfg = dict(base, maxThreads=mt, jvm_TargetSurvivorRatio=tsr)
            vals.append((tc.test(cfg).value, mt))
        return max(vals)[1]

    shift = abs(best_threads(5) - best_threads(95))
    rows.append(("fig1_tomcat_bumpiness", 0.0, f"{b['bumpiness']:.4f}"))
    rows.append(("fig1_tomcat_jvm_optimum_shift_threads", 0.0, shift))

    # (c)/(f): Spark, deployment mode changes the surface
    c = _surface_stats(SparkSurrogate("standalone"), "executor_cores",
                       "executor_memory_mb")
    f = _surface_stats(SparkSurrogate("cluster"), "executor_cores",
                       "executor_memory_mb")
    rows.append(("fig1_spark_standalone_smooth", 0.0,
                 f"bump={c['bumpiness']:.4f}"))
    rows.append(("fig1_spark_cluster_ridge_at_cores",
                 0.0, f.get("argmax")[0]))

    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    return [(n, us, d) for n, _, d in rows]
