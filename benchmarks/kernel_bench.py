"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs pure-jnp reference.

On this CPU container interpret-mode timings are NOT indicative of TPU
performance — the derived column therefore reports allclose deltas and the
arithmetic-intensity of each kernel call (the quantity that matters for the
VMEM-tiling argument), not speedups.
"""
from __future__ import annotations

import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gla import gla_pallas
from repro.kernels.ref import attention_ref, gla_ref
from repro.kernels.rmsnorm import rmsnorm_pallas

from .common import Row


def run() -> List[Row]:
    rng = np.random.default_rng(0)
    rows: List[Row] = []

    B, S, H, KV, D = 1, 256, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    t0 = time.time()
    out = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                                 block_kv=64, interpret=True)
    us = (time.time() - t0) * 1e6
    err = float(jnp.abs(out - attention_ref(q, k, v)).max())
    flops = 4 * B * H * S * S * D / 2
    bytes_ = (q.size + k.size + v.size + out.size) * 4
    rows.append(("flash_attn_256_maxerr", us, f"{err:.2e}"))
    rows.append(("flash_attn_arith_intensity", us,
                 f"{flops / bytes_:.1f} flop/B"))

    x = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    t0 = time.time()
    rn = rmsnorm_pallas(x, s, interpret=True)
    us = (time.time() - t0) * 1e6
    from repro.kernels.ref import rmsnorm_ref

    rows.append(("rmsnorm_maxerr", us,
                 f"{float(jnp.abs(rn - rmsnorm_ref(x, s)).max()):.2e}"))

    gq = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    gk = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    gv = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    gg = jnp.asarray(-np.abs(rng.normal(size=(1, 128, 2)) * 0.3), jnp.float32)
    t0 = time.time()
    y, st = gla_pallas(gq, gk, gv, gg, chunk=32, interpret=True)
    us = (time.time() - t0) * 1e6
    yr, sr = gla_ref(gq, gk, gv, gg)
    rows.append(("gla_chunk_maxerr", us,
                 f"{float(jnp.abs(y - yr).max()):.2e}"))
    return rows
