"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs pure-jnp reference.

On this CPU container interpret-mode timings are NOT indicative of TPU
performance — the derived column therefore reports allclose deltas and the
arithmetic-intensity of each kernel call (the quantity that matters for the
VMEM-tiling argument), not speedups.

The block-size sweep rows report, per candidate tiling, the autotune cost
model's estimated TPU time (the objective the ACTS kernel autotuner
minimizes) next to the interpret-mode wall time and correctness check —
the perf trajectory is additionally written to ``BENCH_kernels.json`` at
the repo root so successive PRs can diff machine-readable numbers.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List

import jax.numpy as jnp
import numpy as np

from repro.autotune import KERNELS
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gla import gla_pallas
from repro.kernels.ref import attention_ref, gla_ref, rmsnorm_ref
from repro.kernels.rmsnorm import rmsnorm_pallas

from .common import Row

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_kernels.json")


def _sweep_flash(rng, record) -> List[Row]:
    rows: List[Row] = []
    B, S, H, KV, D = 1, 256, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, D)), jnp.float32)
    ref = attention_ref(q, k, v)
    dims = {"B": B, "S": S, "SK": S, "H": H, "KV": KV, "D": D}
    model = KERNELS["flash_attention"].model_cost
    for bq, bk in ((32, 32), (64, 64), (128, 128), (64, 128)):
        t0 = time.time()
        out = flash_attention_pallas(q, k, v, causal=True, block_q=bq,
                                     block_kv=bk, interpret=True)
        us = (time.time() - t0) * 1e6
        err = float(jnp.abs(out - ref).max())
        est = model({"block_q": bq, "block_kv": bk}, dims, "float32")
        name = f"flash_attn_S{S}_bq{bq}_bkv{bk}"
        rows.append((name, us, f"model {est * 1e6:.1f}us err {err:.1e}"))
        record(name, us, {"model_us": est * 1e6, "max_err": err,
                          "block_q": bq, "block_kv": bk})
    flops = 4 * B * H * S * S * D / 2
    bytes_ = (q.size + k.size + v.size + q.size) * 4
    rows.append(("flash_attn_arith_intensity", 0.0,
                 f"{flops / bytes_:.1f} flop/B"))
    return rows


def _sweep_rmsnorm(rng, record) -> List[Row]:
    rows: List[Row] = []
    x = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    s = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    ref = rmsnorm_ref(x, s)
    model = KERNELS["rmsnorm"].model_cost
    dims = {"ROWS": 512, "D": 256}
    for br in (64, 128, 256, 512):
        t0 = time.time()
        out = rmsnorm_pallas(x, s, block_rows=br, interpret=True)
        us = (time.time() - t0) * 1e6
        err = float(jnp.abs(out - ref).max())
        est = model({"block_rows": br}, dims, "float32")
        name = f"rmsnorm_512x256_br{br}"
        rows.append((name, us, f"model {est * 1e6:.1f}us err {err:.1e}"))
        record(name, us, {"model_us": est * 1e6, "max_err": err,
                          "block_rows": br})
    return rows


def _sweep_gla(rng, record) -> List[Row]:
    rows: List[Row] = []
    gq = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    gk = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    gv = jnp.asarray(rng.normal(size=(1, 128, 2, 16)), jnp.float32)
    gg = jnp.asarray(-np.abs(rng.normal(size=(1, 128, 2)) * 0.3), jnp.float32)
    yr, _ = gla_ref(gq, gk, gv, gg)
    model = KERNELS["gla"].model_cost
    dims = {"B": 1, "S": 128, "H": 2, "DK": 16, "DV": 16}
    for chunk in (16, 32, 64):
        t0 = time.time()
        y, _state = gla_pallas(gq, gk, gv, gg, chunk=chunk, interpret=True)
        us = (time.time() - t0) * 1e6
        err = float(jnp.abs(y - yr).max())
        est = model({"chunk": chunk}, dims, "float32")
        name = f"gla_S128_chunk{chunk}"
        rows.append((name, us, f"model {est * 1e6:.1f}us err {err:.1e}"))
        record(name, us, {"model_us": est * 1e6, "max_err": err,
                          "chunk": chunk})
    return rows


def run() -> List[Row]:
    rng = np.random.default_rng(0)
    results: Dict[str, Dict[str, Any]] = {}

    def record(name: str, us: float, extra: Dict[str, Any]) -> None:
        results[name] = dict(extra, interpret_us=us)

    rows: List[Row] = []
    rows += _sweep_flash(rng, record)
    rows += _sweep_rmsnorm(rng, record)
    rows += _sweep_gla(rng, record)

    with open(JSON_PATH, "w") as f:
        json.dump({"schema": "kernel-bench-v1", "time": time.time(),
                   "results": results}, f, indent=1, sort_keys=True)
    rows.append(("kernel_bench_json", 0.0, JSON_PATH))
    return rows
