"""§4.1/§4.3: LHS coverage scalability — the three sampling conditions.

Coverage (centered-L2 discrepancy, lower=better; maximin distance,
higher=better) vs sample count, LHS vs iid-random, in the MySQL knob space's
dimensionality.  Condition (3): coverage widens monotonically with m.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import (
    centered_l2_discrepancy,
    lhs_unit,
    min_pairwise_distance,
    random_unit,
)

from .common import Row

DIM = 10
MS = (16, 64, 256)
REPS = 10


def run() -> List[Row]:
    rng = np.random.default_rng(0)
    rows: List[Row] = []
    t0 = time.time()
    n_sets = 0
    for m in MS:
        lhs_d = np.mean([centered_l2_discrepancy(lhs_unit(m, DIM, rng))
                         for _ in range(REPS)])
        rnd_d = np.mean([centered_l2_discrepancy(random_unit(m, DIM, rng))
                         for _ in range(REPS)])
        lhs_md = np.mean([min_pairwise_distance(lhs_unit(m, DIM, rng))
                          for _ in range(REPS)])
        rnd_md = np.mean([min_pairwise_distance(random_unit(m, DIM, rng))
                          for _ in range(REPS)])
        n_sets += 4 * REPS
        rows.append((f"lhs_discrepancy_m{m}", 0.0,
                     f"{lhs_d:.4f} (random {rnd_d:.4f})"))
        rows.append((f"lhs_maximin_m{m}", 0.0,
                     f"{lhs_md:.4f} (random {rnd_md:.4f})"))
    us = (time.time() - t0) * 1e6 / n_sets
    return [(n, us, d) for n, _, d in rows]
