"""Lint wall-time benchmark: the CI gate's cost, itemized per pass.

The PR 10 dataflow passes (project build, call graph, taint fixpoint,
lock dominance) run over the whole of ``src/repro`` on every CI run, so
their wall-time is a perf artifact like any kernel: this module times
each phase separately, counts findings per rule family over the planted
fixtures (the baseline tree is clean by construction — the gate enforces
it), and writes ``BENCH_lint.json`` at the repo root for cross-PR
comparison.  ``--check`` exits non-zero if the full lint of ``src/repro``
exceeds a generous wall-time budget or the baseline is not clean.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import Any, Dict, List

from repro.analysis import dataflow as df
from repro.analysis import lint as L

from .common import Row

REPO = Path(__file__).resolve().parent.parent
JSON_PATH = os.path.join(str(REPO), "BENCH_lint.json")
SRC = REPO / "src" / "repro"
FIXTURES = REPO / "tests" / "fixtures" / "lint"

# the CI gate should never dominate the suite: full tree, all passes
DEFAULT_BUDGET_S = 60.0

# PR 10 families reported separately from the per-file (PR 7) rules
_DATAFLOW_RULES = ("determinism-taint", "jit-trace-capture",
                   "jit-host-effect", "cache-lock-discipline")


def _src_files() -> List[str]:
    return [str(p) for p in sorted(SRC.rglob("*.py"))
            if "__pycache__" not in p.parts]


def bench() -> Dict[str, Any]:
    files = _src_files()

    t0 = time.perf_counter()
    proj = df.build_project(files)
    t_build = time.perf_counter() - t0

    res = df.Resolver(proj)
    t0 = time.perf_counter()
    graph = res.call_graph()
    t_graph = time.perf_counter() - t0
    n_edges = sum(len(v) for v in graph.values())
    n_resolved = sum(1 for v in graph.values() if v)

    t0 = time.perf_counter()
    baseline, n_files = L.lint_paths([str(SRC)])
    t_full = time.perf_counter() - t0

    t0 = time.perf_counter()
    fixture_findings, _ = L.lint_paths([str(FIXTURES)])
    t_fixtures = time.perf_counter() - t0
    per_rule: Dict[str, int] = {}
    for f in fixture_findings:
        per_rule[f.rule] = per_rule.get(f.rule, 0) + 1

    out = {
        "files": n_files,
        "functions": len(proj.sorted_functions()),
        "call_graph": {"nodes": len(graph), "edges": n_edges,
                       "nodes_with_resolved_edges": n_resolved},
        "wall_s": {
            "project_build": round(t_build, 3),
            "call_graph": round(t_graph, 3),
            "full_lint_src": round(t_full, 3),
            "fixture_lint": round(t_fixtures, 3),
        },
        "baseline_findings": len(baseline),
        "fixture_findings_per_rule": dict(sorted(per_rule.items())),
        "dataflow_fixture_findings": sum(
            per_rule.get(r, 0) for r in _DATAFLOW_RULES),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    return out


def rows_from(result: Dict[str, Any]) -> List[Row]:
    w = result["wall_s"]
    g = result["call_graph"]
    return [
        ("lint_full_src", w["full_lint_src"] * 1e6,
         f"{result['files']} files, {result['baseline_findings']} findings"),
        ("lint_call_graph", w["call_graph"] * 1e6,
         f"{g['nodes']} fns, {g['edges']} resolved edges"),
        ("lint_project_build", w["project_build"] * 1e6,
         f"{result['functions']} functions indexed"),
        ("lint_fixture_recall", w["fixture_lint"] * 1e6,
         f"{result['dataflow_fixture_findings']} dataflow findings "
         "planted+caught"),
    ]


def run() -> List[Row]:
    """benchmarks.run entry point."""
    return rows_from(bench())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if the full lint exceeds "
                         f"{DEFAULT_BUDGET_S:.0f}s or src/repro is not "
                         "finding-free")
    ap.add_argument("--budget-s", type=float, default=DEFAULT_BUDGET_S)
    args = ap.parse_args(argv)
    result = bench()
    for name, us, derived in rows_from(result):
        print(f"{name},{us:.1f},{derived}")
    print(f"wrote {JSON_PATH}")
    if args.check:
        wall = result["wall_s"]["full_lint_src"]
        if wall > args.budget_s:
            print(f"CHECK FAILED: full lint took {wall:.1f}s "
                  f"(> {args.budget_s:.0f}s budget)", file=sys.stderr)
            return 1
        if result["baseline_findings"]:
            print("CHECK FAILED: src/repro baseline is not clean",
                  file=sys.stderr)
            return 1
        if result["dataflow_fixture_findings"] < 15:
            print("CHECK FAILED: dataflow fixtures fired fewer findings "
                  "than planted", file=sys.stderr)
            return 1
        print(f"check OK: full lint {wall:.1f}s, baseline clean, "
              f"{result['dataflow_fixture_findings']} planted dataflow "
              "findings caught")
    return 0


if __name__ == "__main__":
    sys.exit(main())
