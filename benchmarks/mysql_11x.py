"""§5.1 reproduction: "Improving System Performance: 11 Times Better".

ACTS (LHS + RRS) tunes the MySQL surrogate's 10 knobs under the uniform-read
workload within a 200-test resource limit.  The paper reports 9,815 ops/s at
the default setting and 118,184 ops/s tuned (12.04x; ">11 times").  The
surrogate is calibrated to those endpoints; the benchmark verifies that the
*search* actually reaches >11x from the measured default within budget.
"""
from __future__ import annotations

import time
from typing import List

from repro.core import MySQLSurrogate, Tuner

from .common import Row

BUDGET = 200


def run() -> List[Row]:
    sut = MySQLSurrogate("uniform_read")
    t0 = time.time()
    rep = Tuner(sut.space(), sut, budget=BUDGET, seed=1).run()
    wall_us = (time.time() - t0) * 1e6
    rows: List[Row] = [
        ("mysql_default_ops", wall_us / rep.n_tests,
         f"{rep.default_metric.value:.0f}"),
        ("mysql_tuned_ops", wall_us / rep.n_tests,
         f"{rep.best_metric.value:.0f}"),
        ("mysql_improvement", wall_us / rep.n_tests,
         f"{rep.improvement:.2f}x"),
        ("mysql_tests_to_beat_default", wall_us / rep.n_tests,
         next((t.test_index for t in rep.history
               if -t.value > rep.default_metric.value * 1.05), -1)),
    ]
    return rows
