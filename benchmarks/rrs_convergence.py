"""§4.3: RRS vs baseline optimizers — convergence quality at equal budget.

Benchmarks on the RRS paper's style of test functions (sphere = easy convex,
Rastrigin = many local minima) and on the bumpy Tomcat surrogate, comparing
RRS / random / smart-hill-climbing / LHS-only at the same resource limit.
"""
from __future__ import annotations

import math
import time
from typing import List

import numpy as np

from repro.core import FloatParam, ParameterSpace, TomcatSurrogate, Tuner, \
    get_optimizer
from repro.core.tuner import CallableSUT, PerfMetric

from .common import Row

OPTS = ("rrs", "random", "shc", "lhs_only")
SEEDS = (0, 1, 2, 3)
BUDGET = 300


def _bench_fn(name, fn, space) -> List[Row]:
    rows = []
    for opt in OPTS:
        vals = []
        t0 = time.time()
        for seed in SEEDS:
            res = get_optimizer(opt).optimize(
                space, fn, BUDGET, np.random.default_rng(seed))
            vals.append(res.best_value)
        us = (time.time() - t0) * 1e6 / (BUDGET * len(SEEDS))
        rows.append((f"{name}_{opt}_best", us, f"{np.mean(vals):.3f}"))
    return rows


def run() -> List[Row]:
    rows: List[Row] = []
    sphere_space = ParameterSpace(
        [FloatParam(f"x{i}", -5, 5, default=4.0) for i in range(8)])
    rows += _bench_fn("sphere8d", lambda c: sum(v * v for v in c.values()),
                      sphere_space)
    rast_space = ParameterSpace(
        [FloatParam(f"x{i}", -5.12, 5.12, default=4.5) for i in range(6)])

    def rastrigin(c):
        xs = list(c.values())
        return 10 * len(xs) + sum(
            x * x - 10 * math.cos(2 * math.pi * x) for x in xs)

    rows += _bench_fn("rastrigin6d", rastrigin, rast_space)

    # bumpy real-ish surface: Tomcat (maximize => tuner handles the sign)
    tc = TomcatSurrogate(fully_utilized=False)
    t0 = time.time()
    n = 0
    for opt in OPTS:
        vals = []
        for seed in SEEDS[:2]:
            rep = Tuner(tc.space(), tc, budget=150, optimizer=opt,
                        seed=seed).run()
            vals.append(rep.best_metric.value)
            n += rep.n_tests
        rows.append((f"tomcat_{opt}_best_txns", 0.0, f"{np.mean(vals):.1f}"))
    us = (time.time() - t0) * 1e6 / max(n, 1)
    return [(name, us if u == 0.0 else u, d) for name, u, d in rows]
