"""§4.3: RRS vs baseline optimizers — convergence quality at equal budget,
plus the batched-vs-sequential evaluation-engine comparison.

Benchmarks on the RRS paper's style of test functions (sphere = easy convex,
Rastrigin = many local minima) and on the bumpy Tomcat surrogate, comparing
RRS / random / smart-hill-climbing / LHS-only at the same resource limit.

``batched_engine`` rows measure the tuning loop's own throughput: the same
RRS run (MySQL surrogate, budget 500, fixed seed) through the vectorized
``BatchEvaluator`` engine vs one ``sut.test`` Python round-trip per trial.
Best configs are asserted identical — the engines run the same trial
sequence — so the speedup column is pure evaluation-path overhead.
"""
from __future__ import annotations

import math
import time
from typing import List

import numpy as np

from repro.core import FloatParam, MySQLSurrogate, ParameterSpace, \
    TomcatSurrogate, Tuner, get_optimizer
from repro.core.tuner import CallableSUT, PerfMetric

from .common import Row

OPTS = ("rrs", "random", "shc", "lhs_only")
SEEDS = (0, 1, 2, 3)
BUDGET = 300
BATCH_BUDGET = 500  # batched-engine comparison budget (acceptance: >= 5x)


def _bench_fn(name, fn, space) -> List[Row]:
    rows = []
    for opt in OPTS:
        vals = []
        t0 = time.time()
        for seed in SEEDS:
            res = get_optimizer(opt).optimize(
                space, fn, BUDGET, np.random.default_rng(seed))
            vals.append(res.best_value)
        us = (time.time() - t0) * 1e6 / (BUDGET * len(SEEDS))
        rows.append((f"{name}_{opt}_best", us, f"{np.mean(vals):.3f}"))
    return rows


def _bench_batched_engine(seed: int = 0, repeats: int = 5) -> List[Row]:
    """Trials/sec of the batched vs sequential engine on the same search."""
    MySQLSurrogate()._max_log_gain_cached()  # one-time calibration out of timing
    for warm in (True, False):  # warm lazy imports + jit-free code paths
        Tuner(MySQLSurrogate().space(), MySQLSurrogate(), budget=60,
              seed=seed, batch=warm).run()

    def timed_run(batch: bool):
        best = math.inf
        rep = None
        for _ in range(repeats):  # best-of-N: shared-container noise
            tuner = Tuner(MySQLSurrogate().space(), MySQLSurrogate(),
                          budget=BATCH_BUDGET, seed=seed, batch=batch)
            t0 = time.perf_counter()
            rep = tuner.run()
            best = min(best, time.perf_counter() - t0)
        return best, rep, tuner

    wall_b, rep_b, tuner_b = timed_run(batch=True)
    wall_s, rep_s, tuner_s = timed_run(batch=False)
    assert rep_b.best_config == rep_s.best_config, \
        "batched and sequential engines diverged"
    assert rep_b.n_tests == rep_s.n_tests == BATCH_BUDGET
    tps_b = BATCH_BUDGET / wall_b
    tps_s = BATCH_BUDGET / wall_s
    return [
        ("batched_engine_mysql_trials_per_sec", wall_b * 1e6 / BATCH_BUDGET,
         f"{tps_b:.0f}/s in {tuner_b.n_evaluator_calls} evaluator calls"),
        ("sequential_engine_mysql_trials_per_sec",
         wall_s * 1e6 / BATCH_BUDGET,
         f"{tps_s:.0f}/s in {tuner_s.n_evaluator_calls} evaluator calls"),
        ("batched_engine_speedup", 0.0, f"{tps_b / tps_s:.1f}x"),
    ]


def _bench_pruning(budget: int = 32) -> List[Row]:
    """Static feasibility pruning (PR 7) on a VMEM-constrained kernel
    tune: pruned candidates are free, so at equal budget the pruned run
    reaches its best in no more charged trials than the unpruned one."""
    from repro.autotune.sut import KernelSUT

    # D=2048 puts the largest flash tiles over VMEM while the default
    # and mid-size tiles stay finite — the pruning path genuinely acts
    dims = {"B": 2, "S": 8192, "SK": 8192, "H": 8, "KV": 8, "D": 2048}

    def tune(feasibility):
        sut = KernelSUT("flash_attention", dims, mode="model")
        return Tuner(sut.space(), sut, budget=budget, seed=0,
                     feasibility=feasibility).run()

    def to_best(rep):
        best = min(t.value for t in rep.history)
        return min(t.test_index for t in rep.history if t.value == best)

    t0 = time.time()
    on, off = tune(None), tune(False)
    us = (time.time() - t0) * 1e6 / (2 * budget)
    assert on.best_metric.value <= off.best_metric.value
    return [
        ("pruned_kernel_tune_flash", us,
         f"{on.n_infeasible_pruned} pruned free, {on.n_tests} charged, "
         f"to-best {to_best(on)} vs {to_best(off)} trials "
         "(pruning on vs off)"),
    ]


def run() -> List[Row]:
    rows: List[Row] = []
    rows += _bench_batched_engine()
    rows += _bench_pruning()
    sphere_space = ParameterSpace(
        [FloatParam(f"x{i}", -5, 5, default=4.0) for i in range(8)])
    rows += _bench_fn("sphere8d", lambda c: sum(v * v for v in c.values()),
                      sphere_space)
    rast_space = ParameterSpace(
        [FloatParam(f"x{i}", -5.12, 5.12, default=4.5) for i in range(6)])

    def rastrigin(c):
        xs = list(c.values())
        return 10 * len(xs) + sum(
            x * x - 10 * math.cos(2 * math.pi * x) for x in xs)

    rows += _bench_fn("rastrigin6d", rastrigin, rast_space)

    # bumpy real-ish surface: Tomcat (maximize => tuner handles the sign)
    tc = TomcatSurrogate(fully_utilized=False)
    t0 = time.time()
    n = 0
    for opt in OPTS:
        vals = []
        for seed in SEEDS[:2]:
            rep = Tuner(tc.space(), tc, budget=150, optimizer=opt,
                        seed=seed).run()
            vals.append(rep.best_metric.value)
            n += rep.n_tests
        rows.append((f"tomcat_{opt}_best_txns", 0.0, f"{np.mean(vals):.1f}"))
    us = (time.time() - t0) * 1e6 / max(n, 1)
    return [(name, us if u == 0.0 else u, d) for name, u, d in rows]
