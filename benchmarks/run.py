# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (deliverable d): one module per paper table/figure.

  fig1_surfaces    §2.2 Fig.1  diverging performance surfaces
  mysql_11x        §5.1        11x throughput over default
  table1_tomcat    §5.2/Tab.1  saturated-server multi-metric gains
  budget_curve     §5.3/§3     improvement vs resource limit
  fair_bench       §5.4        tuned-vs-default ranking flip
  bottleneck       §5.5        subsystem bottleneck identification
  rrs_convergence  §4.3        RRS vs baseline optimizers
  lhs_coverage     §4.3        LHS coverage scalability
  tune_real        §4          measured ACTS on the live JAX runtime
  kernel_bench     kernels     Pallas kernels vs jnp oracles
  cotune_bench     §2.1/§5.5   joint vs independent co-deployment tuning
  serve_bench      serving     continuous-batching + paged KV vs wave loop
  lint_bench       CI gate     dataflow-lint wall-time + planted recall

Run all: ``PYTHONPATH=src python -m benchmarks.run``
One:     ``PYTHONPATH=src python -m benchmarks.run --only mysql_11x``
"""
import argparse
import importlib
import sys
import time
import traceback

MODULES = [
    "fig1_surfaces",
    "mysql_11x",
    "table1_tomcat",
    "budget_curve",
    "fair_bench",
    "bottleneck",
    "rrs_convergence",
    "lhs_coverage",
    "tune_real",
    "kernel_bench",
    "cotune_bench",
    "serve_bench",
    "lint_bench",
]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", choices=MODULES)
    args = ap.parse_args(argv)
    mods = args.only or MODULES
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            rows = mod.run()
            for n, us, d in rows:
                print(f"{n},{us:.1f},{d}")
        except Exception:
            failures += 1
            print(f"{name},0,ERROR", file=sys.stdout)
            traceback.print_exc()
        print(f"# {name}: {time.time() - t0:.1f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
