"""Continuous-batching serve benchmark: continuous (paged) vs wave.

Mixed-length heavy-traffic workload — varied prompt AND generation
lengths, more requests than decode slots — on the reduced surrogate model
(CPU).  The wave runtime must bucket requests by prompt length and holds
every slot until its wave's longest generation finishes; the continuous
runtime admits pending requests into freed slots mid-generation under the
tuned schedule, backed by the paged KV allocator.  Decode tokens/sec is
the headline (slot occupancy is what continuous batching buys); p50/p95
per-request latency rides along, as does the schedule-parity check (the
tokens each request gets must be bit-identical across fifo/sjf/interleave
and vs the wave baseline).

A second, *oversubscribed* workload pins the page-policy claim: at an
equal (small) ``kv_cache_pages`` pool, ``on_demand`` admission (prompt-
size reservations grown per step, recompute preemption on exhaustion)
must complete strictly more decode tokens/sec than worst-case ``reserve``
admission — with bit-identical per-request tokens and a balanced
allocator at exit.

A third, *repeated-shared-prefix* workload pins the prefix-sharing claim:
every request opens with the same long system prompt, so with
``share_prefix`` on, sharers map the donor's resident page groups
(copy-on-write) instead of re-prefilling them.  At an equal pool the
shared arm must clear >= 2x the unshared arm's end-to-end
(prefill+decode) tokens/sec — with bit-identical tokens, strictly fewer
prefill dispatches (the noise-free signal) and a balanced allocator.

``BENCH_serve.json`` is the cross-PR perf artifact; ``--check`` exits
non-zero if continuous+paged underperforms wave at equal engine config,
if ``on_demand`` loses to ``reserve`` on the oversubscribed arm, or if
sharing loses its 2x on the repeated-prefix arm — wired into CI.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from types import SimpleNamespace
from typing import Any, Dict, List

import numpy as np

from .common import Row

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serve.json")

N_REQUESTS = 24
SLOTS = 4
MAX_SEQ = 48
PREFILL_CHUNK = 8
SEED = 0
# oversubscribed arm: decode-heavy requests (worst-case ~2 groups each at
# PAGE_TOKENS=16) against a pool of 5 usable groups — reserve admission
# can hold ~2 requests resident, on_demand packs all 4 slots and preempts
OVERSUB_POOL = 6
# shared-prefix arm: every request opens with the same 32-token system
# prompt (two full 16-token page groups — fully sharable), then a short
# private tail and a short generation, so prefill dominates the bill.
# Both sharing arms run the finer chunk (equal config; only share_prefix
# differs): per-dispatch overhead is the real cost on the tiny model, and
# sharing's win IS the dispatches it skips
SHARED_PREFIX_LEN = 32
SHARED_PREFILL_CHUNK = 4


def _tiny_model():
    import jax

    from repro.configs import ModelConfig
    from repro.models import Model

    cfg = ModelConfig(
        name="tiny-serve-bench", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
        param_dtype="float32", compute_dtype="float32",
        vocab_pad_multiple=64, rope_theta=10_000.0)
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(SEED))


def _workload(seed: int = SEED):
    rng = np.random.default_rng(seed)
    plens = rng.integers(3, 25, size=N_REQUESTS)
    gens = rng.integers(2, 17, size=N_REQUESTS)
    prompts = [rng.integers(1, 512, size=n).tolist() for n in plens]
    return prompts, [int(g) for g in gens]


def _oversub_workload(seed: int = SEED):
    """Decode-heavy mixed lengths: generations dominate the footprint, so
    worst-case reservations strand most of what they hold."""
    rng = np.random.default_rng(seed + 1)
    plens = rng.integers(3, 9, size=N_REQUESTS)
    gens = rng.integers(10, 21, size=N_REQUESTS)
    prompts = [rng.integers(1, 512, size=n).tolist() for n in plens]
    return prompts, [int(g) for g in gens]


def _shared_workload(seed: int = SEED):
    """Repeated-system-prompt traffic: one long common prefix, short
    private tails, short generations — the workload prefix sharing is
    for (prefill is most of each request's bill)."""
    rng = np.random.default_rng(seed + 2)
    prefix = rng.integers(1, 512, size=SHARED_PREFIX_LEN).tolist()
    tails = [rng.integers(1, 512, size=int(n)).tolist()
             for n in rng.integers(1, 3, size=N_REQUESTS)]
    gens = [int(g) for g in rng.integers(2, 7, size=N_REQUESTS)]
    return [prefix + t for t in tails], gens


def _engine(model, params, runtime: str, layout: str, schedule: str,
            page_policy: str = "reserve", pages=None,
            share_prefix: bool = False, chunk: int = PREFILL_CHUNK):
    from repro.serve import ServeConfig, ServeEngine

    return ServeEngine(model, params, ServeConfig(
        max_seq=MAX_SEQ, batch_slots=SLOTS, prefill_chunk=chunk,
        runtime=runtime, kv_layout=layout, schedule=schedule,
        page_policy=page_policy, kv_cache_pages=pages,
        share_prefix=share_prefix))


def _run_continuous(model, params, layout: str, schedule: str,
                    prompts, gens, page_policy: str = "reserve",
                    pages=None, share_prefix: bool = False,
                    chunk: int = PREFILL_CHUNK) -> Dict[str, Any]:
    eng = _engine(model, params, "continuous", layout, schedule,
                  page_policy, pages, share_prefix, chunk)
    eng.generate(prompts, gens)  # warmup: absorb jit specialization
    t0 = time.time()
    res = eng.generate(prompts, gens)
    wall = time.time() - t0
    stats = _arm_stats(res.tokens, res, wall,
                       [r["latency_s"] for r in res.per_request])
    stats["preemptions"] = int(res.preemptions)
    stats["prefill_chunks"] = int(res.prefill_chunks)
    stats["shared_prefix_tokens"] = int(res.shared_prefix_tokens)
    stats["cow_splits"] = int(res.cow_splits)
    if eng.last_alloc is not None:
        eng.last_alloc.check_balanced()
        stats["leaked_groups"] = int(eng.last_alloc.groups_in_use)
    return stats


def _run_wave(model, params, prompts, gens) -> Dict[str, Any]:
    """The wave baseline on a mixed workload: bucket by prompt length
    (its equal-length contract), run buckets back to back; per-request
    latency counts the time until the request's bucket completed."""
    eng = _engine(model, params, "wave", "dense", "fifo")
    buckets: Dict[int, List[int]] = {}
    for i, p in enumerate(prompts):
        buckets.setdefault(len(p), []).append(i)

    def run_all():
        toks: List[Any] = [None] * len(prompts)
        lats: List[float] = [0.0] * len(prompts)
        pf = dc = 0.0
        steps = 0
        t0 = time.time()
        for _, idxs in sorted(buckets.items()):
            res = eng.generate([prompts[i] for i in idxs],
                               [gens[i] for i in idxs])
            done = time.time() - t0
            for j, i in enumerate(idxs):
                toks[i] = res.tokens[j]
                lats[i] = done  # bucket-completion latency
            pf += res.prefill_seconds
            dc += res.decode_seconds
            steps += res.steps
        return toks, lats, pf, dc, steps, time.time() - t0

    run_all()  # warmup
    toks, lats, pf, dc, steps, wall = run_all()
    shim = SimpleNamespace(prefill_seconds=pf, decode_seconds=dc,
                           steps=steps)
    return _arm_stats(toks, shim, wall, lats)


def _arm_stats(tokens, res, wall: float, lats: List[float]) -> Dict[str, Any]:
    n_tok = sum(len(t) for t in tokens)
    return {
        "tokens": tokens,
        "generated": n_tok,
        "decode_s": float(res.decode_seconds),
        "prefill_s": float(res.prefill_seconds),
        "decode_tok_per_s": n_tok / max(res.decode_seconds, 1e-9),
        "wall_s": float(wall),
        "wall_tok_per_s": n_tok / max(wall, 1e-9),
        "steps": int(res.steps),
        "occupancy": n_tok / max(res.steps * SLOTS, 1),
        "p50_latency_s": float(np.percentile(lats, 50)),
        "p95_latency_s": float(np.percentile(lats, 95)),
    }


def bench() -> Dict[str, Any]:
    model, params = _tiny_model()
    prompts, gens = _workload()

    arms: Dict[str, Dict[str, Any]] = {}
    arms["wave_fifo"] = _run_wave(model, params, prompts, gens)
    for sched in ("fifo", "sjf", "interleave"):
        arms[f"continuous_paged_{sched}"] = _run_continuous(
            model, params, "paged", sched, prompts, gens)
    arms["continuous_dense_fifo"] = _run_continuous(
        model, params, "dense", "fifo", prompts, gens)

    # schedule/layout/runtime parity: identical per-request tokens
    ref = arms["wave_fifo"]["tokens"]
    parity = all(arms[a]["tokens"] == ref for a in arms)

    # ---- oversubscribed page-policy arm: equal (small) pool, the
    # reservation policy is the only difference -------------------------
    os_prompts, os_gens = _oversub_workload()
    oversub: Dict[str, Dict[str, Any]] = {}
    for policy in ("reserve", "on_demand"):
        oversub[policy] = _run_continuous(
            model, params, "paged", "fifo", os_prompts, os_gens,
            page_policy=policy, pages=OVERSUB_POOL)
    oversub_parity = oversub["reserve"]["tokens"] == \
        oversub["on_demand"]["tokens"]

    # ---- repeated-shared-prefix arm: equal pool and schedule, the
    # share_prefix knob is the only difference --------------------------
    sh_prompts, sh_gens = _shared_workload()
    sharing: Dict[str, Dict[str, Any]] = {}
    for arm, share in (("unshared", False), ("shared", True)):
        sharing[arm] = _run_continuous(
            model, params, "paged", "fifo", sh_prompts, sh_gens,
            share_prefix=share, chunk=SHARED_PREFILL_CHUNK)
    sharing_parity = sharing["shared"]["tokens"] == \
        sharing["unshared"]["tokens"]

    def _serve_rate(s: Dict[str, Any]) -> float:
        # end-to-end serve rate: generated tokens over prefill+decode time
        # (prefill is exactly what sharing removes, so decode-only rates
        # would hide the win)
        return s["generated"] / max(s["prefill_s"] + s["decode_s"], 1e-9)

    headline = arms["continuous_paged_fifo"]
    baseline = arms["wave_fifo"]
    out = {
        "workload": {"n_requests": N_REQUESTS, "slots": SLOTS,
                     "max_seq": MAX_SEQ, "prefill_chunk": PREFILL_CHUNK,
                     "prompt_lens": [len(p) for p in prompts],
                     "gen_lens": gens, "seed": SEED},
        "arms": {a: {k: v for k, v in s.items() if k != "tokens"}
                 for a, s in arms.items()},
        "token_parity": bool(parity),
        "continuous_over_wave_decode": (headline["decode_tok_per_s"]
                                        / baseline["decode_tok_per_s"]),
        "continuous_over_wave_wall": (headline["wall_tok_per_s"]
                                      / baseline["wall_tok_per_s"]),
        "oversub_workload": {"kv_cache_pages": OVERSUB_POOL,
                             "prompt_lens": [len(p) for p in os_prompts],
                             "gen_lens": os_gens},
        "oversub_arms": {a: {k: v for k, v in s.items() if k != "tokens"}
                         for a, s in oversub.items()},
        "oversub_token_parity": bool(oversub_parity),
        "on_demand_over_reserve_decode": (
            oversub["on_demand"]["decode_tok_per_s"]
            / oversub["reserve"]["decode_tok_per_s"]),
        "oversub_leaked_groups": (oversub["reserve"]["leaked_groups"]
                                  + oversub["on_demand"]["leaked_groups"]),
        "shared_workload": {"prefix_len": SHARED_PREFIX_LEN,
                            "prefill_chunk": SHARED_PREFILL_CHUNK,
                            "prompt_lens": [len(p) for p in sh_prompts],
                            "gen_lens": sh_gens},
        "sharing_arms": {a: {k: v for k, v in s.items() if k != "tokens"}
                         for a, s in sharing.items()},
        "sharing_token_parity": bool(sharing_parity),
        "shared_over_unshared_serve": (_serve_rate(sharing["shared"])
                                       / _serve_rate(sharing["unshared"])),
        "sharing_leaked_groups": (sharing["shared"]["leaked_groups"]
                                  + sharing["unshared"]["leaked_groups"]),
    }
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    return out


def rows_from(result: Dict[str, Any]) -> List[Row]:
    arms = result["arms"]
    rows: List[Row] = []
    for a in ("wave_fifo", "continuous_paged_fifo", "continuous_paged_sjf",
              "continuous_paged_interleave", "continuous_dense_fifo"):
        s = arms[a]
        rows.append((f"serve_{a}", 0.0,
                     f"{s['decode_tok_per_s']:.0f} tok/s "
                     f"p50={s['p50_latency_s']:.3f}s "
                     f"p95={s['p95_latency_s']:.3f}s "
                     f"occ={s['occupancy']:.2f}"))
    rows.append(("serve_continuous_over_wave", 0.0,
                 f"{result['continuous_over_wave_decode']:.2f}x decode "
                 f"({result['continuous_over_wave_wall']:.2f}x wall)"))
    rows.append(("serve_token_parity", 0.0,
                 "ok" if result["token_parity"] else "MISMATCH"))
    for policy in ("reserve", "on_demand"):
        s = result["oversub_arms"][policy]
        rows.append((f"serve_oversub_{policy}", 0.0,
                     f"{s['decode_tok_per_s']:.0f} tok/s "
                     f"steps={s['steps']} preempt={s['preemptions']} "
                     f"occ={s['occupancy']:.2f}"))
    rows.append(("serve_on_demand_over_reserve", 0.0,
                 f"{result['on_demand_over_reserve_decode']:.2f}x decode "
                 f"at {result['oversub_workload']['kv_cache_pages']} pages"))
    rows.append(("serve_oversub_parity", 0.0,
                 "ok" if (result["oversub_token_parity"]
                          and result["oversub_leaked_groups"] == 0)
                 else "MISMATCH"))
    for arm in ("unshared", "shared"):
        s = result["sharing_arms"][arm]
        rows.append((f"serve_prefix_{arm}", 0.0,
                     f"{s['generated'] / max(s['prefill_s'] + s['decode_s'], 1e-9):.0f} tok/s "
                     f"chunks={s['prefill_chunks']} "
                     f"shared={s['shared_prefix_tokens']} "
                     f"cow={s['cow_splits']}"))
    rows.append(("serve_shared_over_unshared", 0.0,
                 f"{result['shared_over_unshared_serve']:.2f}x "
                 "prefill+decode tok/s at equal pool"))
    rows.append(("serve_sharing_parity", 0.0,
                 "ok" if (result["sharing_token_parity"]
                          and result["sharing_leaked_groups"] == 0)
                 else "MISMATCH"))
    return rows


def run() -> List[Row]:
    """benchmarks.run entry point."""
    return rows_from(bench())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if continuous+paged underperforms "
                         "the wave baseline, or token parity breaks")
    args = ap.parse_args(argv)
    result = bench()
    for name, _, derived in rows_from(result):
        print(f"{name},{derived}")
    print(f"wrote {JSON_PATH}")
    if args.check:
        if not result["token_parity"]:
            print("CHECK FAILED: per-request tokens differ across "
                  "runtimes/schedules", file=sys.stderr)
            return 1
        ratio = result["continuous_over_wave_decode"]
        if ratio < 1.0:
            print(f"CHECK FAILED: continuous+paged decode throughput "
                  f"{ratio:.2f}x the wave baseline (< 1.0x)",
                  file=sys.stderr)
            return 1
        if not result["oversub_token_parity"]:
            print("CHECK FAILED: per-request tokens differ across page "
                  "policies on the oversubscribed workload",
                  file=sys.stderr)
            return 1
        if result["oversub_leaked_groups"]:
            print("CHECK FAILED: page groups leaked on the oversubscribed "
                  "workload", file=sys.stderr)
            return 1
        # the noise-free packing signal first: fewer batched decode steps
        # at equal tokens is deterministic, unlike CPU wall-clock
        od_steps = result["oversub_arms"]["on_demand"]["steps"]
        rs_steps = result["oversub_arms"]["reserve"]["steps"]
        if od_steps >= rs_steps:
            print(f"CHECK FAILED: on_demand took {od_steps} decode steps "
                  f"vs reserve's {rs_steps} at equal kv_cache_pages "
                  "(packing gained nothing)", file=sys.stderr)
            return 1
        od_ratio = result["on_demand_over_reserve_decode"]
        if od_ratio <= 1.0:
            print(f"CHECK FAILED: on_demand+preemption decode throughput "
                  f"{od_ratio:.2f}x reserve at equal kv_cache_pages "
                  "(must be > 1.0x)", file=sys.stderr)
            return 1
        if result["oversub_arms"]["on_demand"]["preemptions"] < 1:
            print("CHECK FAILED: oversubscribed arm issued no recompute "
                  "preemptions (the pool is not actually oversubscribed)",
                  file=sys.stderr)
            return 1
        if not result["sharing_token_parity"]:
            print("CHECK FAILED: per-request tokens differ with "
                  "share_prefix on the repeated-prefix workload",
                  file=sys.stderr)
            return 1
        if result["sharing_leaked_groups"]:
            print("CHECK FAILED: page groups leaked on the shared-prefix "
                  "workload", file=sys.stderr)
            return 1
        sh = result["sharing_arms"]["shared"]
        un = result["sharing_arms"]["unshared"]
        # noise-free first: sharing must actually skip prefill dispatches
        if sh["prefill_chunks"] >= un["prefill_chunks"] or \
                sh["shared_prefix_tokens"] <= 0:
            print(f"CHECK FAILED: sharing issued {sh['prefill_chunks']} "
                  f"prefill chunks vs {un['prefill_chunks']} unshared "
                  f"(shared tokens: {sh['shared_prefix_tokens']}) — "
                  "nothing was actually shared", file=sys.stderr)
            return 1
        sh_ratio = result["shared_over_unshared_serve"]
        if sh_ratio < 2.0:
            print(f"CHECK FAILED: shared-prefix serve throughput "
                  f"{sh_ratio:.2f}x unshared at an equal pool "
                  "(must be >= 2.0x)", file=sys.stderr)
            return 1
        print(f"check OK: continuous+paged = {ratio:.2f}x wave decode "
              f"throughput; on_demand = {od_ratio:.2f}x reserve at "
              f"{OVERSUB_POOL} pages; share_prefix = {sh_ratio:.2f}x "
              "unshared on the repeated-prefix arm; token parity holds, "
              "pool balanced")
    return 0


if __name__ == "__main__":
    sys.exit(main())
