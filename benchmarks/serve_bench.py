"""Continuous-batching serve benchmark: continuous (paged) vs wave.

Mixed-length heavy-traffic workload — varied prompt AND generation
lengths, more requests than decode slots — on the reduced surrogate model
(CPU).  The wave runtime must bucket requests by prompt length and holds
every slot until its wave's longest generation finishes; the continuous
runtime admits pending requests into freed slots mid-generation under the
tuned schedule, backed by the paged KV allocator.  Decode tokens/sec is
the headline (slot occupancy is what continuous batching buys); p50/p95
per-request latency rides along, as does the schedule-parity check (the
tokens each request gets must be bit-identical across fifo/sjf/interleave
and vs the wave baseline).

A second, *oversubscribed* workload pins the page-policy claim: at an
equal (small) ``kv_cache_pages`` pool, ``on_demand`` admission (prompt-
size reservations grown per step, recompute preemption on exhaustion)
must complete strictly more decode tokens/sec than worst-case ``reserve``
admission — with bit-identical per-request tokens and a balanced
allocator at exit.

A third, *repeated-shared-prefix* workload pins the prefix-sharing claim:
every request opens with the same long system prompt, so with
``share_prefix`` on, sharers map the donor's resident page groups
(copy-on-write) instead of re-prefilling them.  At an equal pool the
shared arm must clear >= 2x the unshared arm's end-to-end
(prefill+decode) tokens/sec — with bit-identical tokens, strictly fewer
prefill dispatches (the noise-free signal) and a balanced allocator.

A fourth, *drifting* workload pins the online-retuning claim (PR 8): the
request mix starts as the distinct-long-prompt traffic the deployed knobs
were tuned under, then shifts to shared-prefix short-tail bursts.  The
**stale** arm spends its whole tuning budget offline before the drift and
serves the shifted phase on those knobs; the **retune** arm splits the
SAME total budget across an earlier deployment's cached winner (the
nearest-signature donor), an offline phase-A winner, and an online
mid-run retune fed by the live window's MEASURED fingerprint.  The
retuned arm must clear >= 1.15x the stale arm's end-to-end tokens/sec in
strictly fewer decode steps (the noise-free occupancy signal), with
bit-identical tokens across the mid-stream knob swap, the retuned
``spec_accept`` within 0.1 of the measured acceptance rate, and the
online winner persisted under its workload signature.

A standalone drafting-cost row pins the bounded-lookback satellite: with
``draft_window`` the n-gram drafter's per-call cost is flat in history
length (16x longer history < 3x cost) instead of linear.

A fifth, *sharded* arm pins the tensor-parallel serving claims (PR 9) on
8 fake host devices (``XLA_FLAGS`` is set at module import, before jax):
the main workload re-runs at mesh (1,2) (pure TP), (2,1) (replicas) and
(2,2) (grid) with the exact continuous+paged+fifo config.  Per-request
tokens must stay bit-identical to the unsharded arm; the TP arm must
issue EXACTLY the unsharded number of batched decode dispatches (TP
splits each dispatch across devices, it never adds steps); the replica
arm must finish in strictly fewer steps (the data axis widens admission
capacity); and every arm's allocator must exit balanced.

``BENCH_serve.json`` is the cross-PR perf artifact; ``--check`` exits
non-zero if continuous+paged underperforms wave at equal engine config,
if ``on_demand`` loses to ``reserve`` on the oversubscribed arm, if
sharing loses its 2x on the repeated-prefix arm, or if online retuning
loses its 1.15x (or any of its invariants) on the drift arm — wired
into CI.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import shutil
import sys
import tempfile
import time
from types import SimpleNamespace
from typing import Any, Dict, List

import numpy as np

from .common import Row

# the sharded arms need 8 fake host devices; this must land before the
# first (lazy, in-function) jax import anywhere in this process
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

# sharded arms: (data, model) meshes the main workload re-runs under;
# the tiny model's 4 heads / 2 kv_heads divide every model axis here
SHARDED_MESHES = {"d1m2": (1, 2), "d2m1": (2, 1), "d2m2": (2, 2)}

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serve.json")

N_REQUESTS = 24
SLOTS = 4
MAX_SEQ = 48
PREFILL_CHUNK = 8
SEED = 0
# oversubscribed arm: decode-heavy requests (worst-case ~2 groups each at
# PAGE_TOKENS=16) against a pool of 5 usable groups — reserve admission
# can hold ~2 requests resident, on_demand packs all 4 slots and preempts
OVERSUB_POOL = 6
# shared-prefix arm: every request opens with the same 32-token system
# prompt (two full 16-token page groups — fully sharable), then a short
# private tail and a short generation, so prefill dominates the bill.
# Both sharing arms run the finer chunk (equal config; only share_prefix
# differs): per-dispatch overhead is the real cost on the tiny model, and
# sharing's win IS the dispatches it skips
SHARED_PREFIX_LEN = 32
SHARED_PREFILL_CHUNK = 4
# drifting-workload arm: a SMALL pool (7 groups) is what couples the
# knobs to the workload — phase A's 3-group worst-case footprints cap
# residency at 2, so offline tuning on phase A lands on a narrow
# max_batch; the drifted phase's shared-prefix requests shrink to ~1
# private group each, so the retuned winner goes wide (and shares) where
# the stale one keeps admitting 2 at a time.  DRIFT_BUDGET is the
# per-component tuning budget: the stale arm spends 3x offline, the
# retune arm splits the same 3x across donor + offline + online
DRIFT_MAX_SEQ = 48
DRIFT_SLOTS = 8
DRIFT_PAGES = 7
DRIFT_BUDGET = 8
# bounded-drafting row: lookback window vs history lengths, timed reps
DRAFT_WINDOW = 256
DRAFT_SHORT = 1024
DRAFT_LONG = 16384
DRAFT_COST_REPS = 2000


def _tiny_model():
    import jax

    from repro.configs import ModelConfig
    from repro.models import Model

    cfg = ModelConfig(
        name="tiny-serve-bench", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
        param_dtype="float32", compute_dtype="float32",
        vocab_pad_multiple=64, rope_theta=10_000.0)
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(SEED))


def _workload(seed: int = SEED):
    rng = np.random.default_rng(seed)
    plens = rng.integers(3, 25, size=N_REQUESTS)
    gens = rng.integers(2, 17, size=N_REQUESTS)
    prompts = [rng.integers(1, 512, size=n).tolist() for n in plens]
    return prompts, [int(g) for g in gens]


def _oversub_workload(seed: int = SEED):
    """Decode-heavy mixed lengths: generations dominate the footprint, so
    worst-case reservations strand most of what they hold."""
    rng = np.random.default_rng(seed + 1)
    plens = rng.integers(3, 9, size=N_REQUESTS)
    gens = rng.integers(10, 21, size=N_REQUESTS)
    prompts = [rng.integers(1, 512, size=n).tolist() for n in plens]
    return prompts, [int(g) for g in gens]


def _shared_workload(seed: int = SEED):
    """Repeated-system-prompt traffic: one long common prefix, short
    private tails, short generations — the workload prefix sharing is
    for (prefill is most of each request's bill)."""
    rng = np.random.default_rng(seed + 2)
    prefix = rng.integers(1, 512, size=SHARED_PREFIX_LEN).tolist()
    tails = [rng.integers(1, 512, size=int(n)).tolist()
             for n in rng.integers(1, 3, size=N_REQUESTS)]
    gens = [int(g) for g in rng.integers(2, 7, size=N_REQUESTS)]
    return [prefix + t for t in tails], gens


def _engine(model, params, runtime: str, layout: str, schedule: str,
            page_policy: str = "reserve", pages=None,
            share_prefix: bool = False, chunk: int = PREFILL_CHUNK,
            mesh=None):
    from repro.serve import ServeConfig, ServeEngine

    return ServeEngine(model, params, ServeConfig(
        max_seq=MAX_SEQ, batch_slots=SLOTS, prefill_chunk=chunk,
        runtime=runtime, kv_layout=layout, schedule=schedule,
        page_policy=page_policy, kv_cache_pages=pages,
        share_prefix=share_prefix, mesh_shape=mesh))


def _run_continuous(model, params, layout: str, schedule: str,
                    prompts, gens, page_policy: str = "reserve",
                    pages=None, share_prefix: bool = False,
                    chunk: int = PREFILL_CHUNK, mesh=None) -> Dict[str, Any]:
    eng = _engine(model, params, "continuous", layout, schedule,
                  page_policy, pages, share_prefix, chunk, mesh)
    eng.generate(prompts, gens)  # warmup: absorb jit specialization
    t0 = time.time()
    res = eng.generate(prompts, gens)
    wall = time.time() - t0
    stats = _arm_stats(res.tokens, res, wall,
                       [r["latency_s"] for r in res.per_request])
    stats["preemptions"] = int(res.preemptions)
    stats["prefill_chunks"] = int(res.prefill_chunks)
    stats["shared_prefix_tokens"] = int(res.shared_prefix_tokens)
    stats["cow_splits"] = int(res.cow_splits)
    if eng.last_alloc is not None:
        eng.last_alloc.check_balanced()
        stats["leaked_groups"] = int(eng.last_alloc.groups_in_use)
    return stats


def _run_wave(model, params, prompts, gens) -> Dict[str, Any]:
    """The wave baseline on a mixed workload: bucket by prompt length
    (its equal-length contract), run buckets back to back; per-request
    latency counts the time until the request's bucket completed."""
    eng = _engine(model, params, "wave", "dense", "fifo")
    buckets: Dict[int, List[int]] = {}
    for i, p in enumerate(prompts):
        buckets.setdefault(len(p), []).append(i)

    def run_all():
        toks: List[Any] = [None] * len(prompts)
        lats: List[float] = [0.0] * len(prompts)
        pf = dc = 0.0
        steps = 0
        t0 = time.time()
        for _, idxs in sorted(buckets.items()):
            res = eng.generate([prompts[i] for i in idxs],
                               [gens[i] for i in idxs])
            done = time.time() - t0
            for j, i in enumerate(idxs):
                toks[i] = res.tokens[j]
                lats[i] = done  # bucket-completion latency
            pf += res.prefill_seconds
            dc += res.decode_seconds
            steps += res.steps
        return toks, lats, pf, dc, steps, time.time() - t0

    run_all()  # warmup
    toks, lats, pf, dc, steps, wall = run_all()
    shim = SimpleNamespace(prefill_seconds=pf, decode_seconds=dc,
                           steps=steps)
    return _arm_stats(toks, shim, wall, lats)


def _arm_stats(tokens, res, wall: float, lats: List[float]) -> Dict[str, Any]:
    n_tok = sum(len(t) for t in tokens)
    return {
        "tokens": tokens,
        "generated": n_tok,
        "decode_s": float(res.decode_seconds),
        "prefill_s": float(res.prefill_seconds),
        "decode_tok_per_s": n_tok / max(res.decode_seconds, 1e-9),
        "wall_s": float(wall),
        "wall_tok_per_s": n_tok / max(wall, 1e-9),
        "steps": int(res.steps),
        "occupancy": n_tok / max(res.steps * SLOTS, 1),
        "p50_latency_s": float(np.percentile(lats, 50)),
        "p95_latency_s": float(np.percentile(lats, 95)),
    }


def _drifting_workload(seed: int = SEED):
    """Phase A (distinct long prompts, long generations — the traffic the
    deployed knobs were tuned under; 30+12 tokens = 3 worst-case page
    groups), then phase B (shared-prefix short tails, short generations,
    many concurrent) — the drift the online retuner must catch mid-run."""
    rng = np.random.default_rng(seed + 3)
    pa = [rng.integers(1, 512, size=30).tolist() for _ in range(4)]
    head = rng.integers(1, 512, size=32).tolist()
    pb = [head + rng.integers(1, 512, size=3).tolist() for _ in range(28)]
    return pa + pb, [12] * 4 + [6] * 28


def _phase_a_workload(seed: int = SEED):
    """Phase-A-shaped traffic on its own: what both arms tune offline
    against, and the signature the deployed knobs carry.  The SAME
    request count as the drift run, so the measured baseline reflects
    the queue depth and arrival rate the live detector will see while
    the traffic still matches — detection then keys on the workload
    SHAPE shifting, not on deployment conditions mismatching."""
    rng = np.random.default_rng(seed + 4)
    return ([rng.integers(1, 512, size=30).tolist() for _ in range(24)],
            [12] * 24)


def _pilot_workload(seed: int = SEED):
    """An earlier deployment's traffic: shared-head short tails like
    phase B but a different head, different tails and shorter
    generations — its measured signature lands NEAR the live drifted one
    without ever being exact, so the transfer the retune arm gets is the
    nearest-signature kind, not a lookup hit."""
    rng = np.random.default_rng(seed + 5)
    head = rng.integers(1, 512, size=32).tolist()
    return ([head + rng.integers(1, 512, size=2).tolist()
             for _ in range(8)], [4] * 8)


_DRIFT_RETUNE_KW = dict(retune=True, retune_budget=DRIFT_BUDGET,
                        retune_threshold=0.18, retune_window=8,
                        retune_cooldown=200, retune_check_every=2,
                        retune_min_requests=6)


def _drift_engine(model, params, knobs=None, **extra):
    from repro.serve import ServeConfig, ServeEngine

    kw: Dict[str, Any] = dict(
        max_seq=DRIFT_MAX_SEQ, batch_slots=DRIFT_SLOTS, kv_layout="paged",
        kv_cache_pages=DRIFT_PAGES, prefill_chunk=PREFILL_CHUNK,
        seed=SEED)
    if knobs is not None:
        # deploy tuned knobs the way the online swap does: admission
        # width via slot_cap (the compiled dispatch stays at
        # DRIFT_SLOTS lanes in both arms, so decode steps compare
        # apples to apples), everything else directly
        kw.update(slot_cap=min(int(knobs["max_batch"]), DRIFT_SLOTS),
                  prefill_chunk=int(knobs["prefill_chunk"]),
                  schedule=str(knobs["schedule"]),
                  page_policy=str(knobs["page_policy"]),
                  share_prefix=bool(int(knobs["share_prefix"])),
                  draft_len=int(knobs["draft_len"]))
    kw.update(extra)
    return ServeEngine(model, params, ServeConfig(**kw))


def _measured_fingerprint(model, params, prompts, gens):
    """What the engine's own window measures on this traffic: run it with
    the shift detector anchored but inert (threshold no drift reaches)
    and read the anchored baseline back."""
    eng = _drift_engine(model, params,
                        **dict(_DRIFT_RETUNE_KW, retune_threshold=10.0))
    eng.generate(prompts, gens)
    return eng.last_retuner.baseline


def _offline_retune(model, fp, budget, sig_dims=None, seed=SEED):
    """One offline tuning run over the SAME frozen knob space the
    engine's online retuner optimizes (kv pool pinned to the allocated
    one), against the measured fingerprint — with ``sig_dims`` the winner
    is persisted under its workload signature like any tuning session."""
    from repro.serve.space import CotuneParams, serve_knob_space
    from repro.serve.workload import OnlineRetuner

    mcfg = model.cfg
    space = serve_knob_space(DRIFT_MAX_SEQ, max_slots=DRIFT_SLOTS).freeze(
        {"kv_cache_pages": DRIFT_PAGES})
    rt = OnlineRetuner(
        space, CotuneParams.from_model(mcfg, max_seq=DRIFT_MAX_SEQ),
        budget=budget, seed=seed, sig_dims=sig_dims,
        dtype=mcfg.compute_dtype)
    return rt.retune(fp)


def _finite_or_none(obj):
    """json-safe copy: non-finite floats (nan acceptance before any
    draft data) become null instead of bare NaN literals."""
    if isinstance(obj, float) and not math.isfinite(obj):
        return None
    if isinstance(obj, dict):
        return {k: _finite_or_none(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_finite_or_none(v) for v in obj]
    return obj


def _drift_bench(model, params) -> Dict[str, Any]:
    """The drifting-workload comparison at equal total tuning budget."""
    from repro import autotune
    from repro.serve.workload import fingerprint_sig

    mcfg = model.cfg
    dims = {"S": DRIFT_MAX_SEQ, "H": mcfg.padded_heads,
            "KV": mcfg.n_kv_heads, "D": mcfg.head_dim_}
    prompts, gens = _drifting_workload()
    old_cache = os.environ.get("REPRO_AUTOTUNE_CACHE")
    tmp = tempfile.mkdtemp(prefix="repro-drift-bench-")
    cpath = os.path.join(tmp, "cache.json")
    try:
        os.environ["REPRO_AUTOTUNE_CACHE"] = cpath
        autotune.reset_default_cache()

        # the signatures each side tuned under, as the live window
        # measures them (shadow-probe acceptance included)
        fp_a = _measured_fingerprint(model, params, *_phase_a_workload())
        fp_pilot = _measured_fingerprint(model, params, *_pilot_workload())
        sig_a = fingerprint_sig(fp_a)

        # stale arm: the whole budget spent offline, before the drift
        ev_stale = _offline_retune(model, fp_a, 3 * DRIFT_BUDGET)
        # retune arm, same total: an earlier deployment's winner cached
        # under its own signature (the donor nearest-signature transfer
        # will find), an offline phase-A winner to deploy, and the
        # online retune's budget at drift time
        ev_donor = _offline_retune(model, fp_pilot, DRIFT_BUDGET,
                                   sig_dims=dims, seed=SEED + 1)
        ev_init = _offline_retune(model, fp_a, DRIFT_BUDGET)
        with open(cpath, "rb") as f:
            seeded = f.read()  # pre-drift cache: the donor entry only

        def run(knobs, **extra):
            eng = _drift_engine(model, params, knobs, **extra)
            deployed = {f: getattr(eng.cfg, f) for f in
                        ("schedule", "page_policy", "prefill_chunk",
                         "draft_len", "share_prefix")}
            eng.generate(prompts, gens)  # warmup: jit (incl. swap shapes)
            # the warmup run's own retune swapped the engine's live knobs
            # and persisted a winner; each timed repeat starts over from
            # the deployed knobs and the pre-drift cache, so it measures
            # a fresh deployment (with the swap's jit shapes warm).
            # Steps and tokens are deterministic across repeats; the
            # median serve time damps CPU wall-clock noise
            runs = []
            for _ in range(3):
                for field, v in deployed.items():
                    setattr(eng.cfg, field, v)
                with open(cpath, "wb") as fh:
                    fh.write(seeded)
                autotune.reset_default_cache()
                t0 = time.time()
                res = eng.generate(prompts, gens)
                runs.append((time.time() - t0, res))
            runs.sort(key=lambda wr: (wr[1].prefill_seconds
                                      + wr[1].decode_seconds))
            wall, res = runs[len(runs) // 2]
            stats = _arm_stats(res.tokens, res, wall,
                               [r["latency_s"] for r in res.per_request])
            eng.last_alloc.check_balanced()
            stats["leaked_groups"] = int(eng.last_alloc.groups_in_use)
            stats["prefill_chunks"] = int(res.prefill_chunks)
            stats["shared_prefix_tokens"] = int(res.shared_prefix_tokens)
            stats["preemptions"] = int(res.preemptions)
            stats["retunes"] = res.retunes
            return stats

        stale = run(ev_stale["config"])
        retuned = run(ev_init["config"], tuned_signature=sig_a,
                      **_DRIFT_RETUNE_KW)
        cands = autotune.serve_config_candidates(dims, mcfg.compute_dtype)
    finally:
        if old_cache is None:
            os.environ.pop("REPRO_AUTOTUNE_CACHE", None)
        else:
            os.environ["REPRO_AUTOTUNE_CACHE"] = old_cache
        autotune.reset_default_cache()
        shutil.rmtree(tmp, ignore_errors=True)

    parity = stale["tokens"] == retuned["tokens"]
    events = retuned.pop("retunes")
    stale.pop("retunes")
    ev = events[0] if events else {}
    entry = cands.get(ev.get("signature"))
    cached_ok = bool(entry
                     and entry["meta"].get("source") == "online_retune"
                     and entry["config"] == ev.get("config"))

    def _rate(s):
        return s["generated"] / max(s["prefill_s"] + s["decode_s"], 1e-9)

    return {
        "drift_workload": {
            "max_seq": DRIFT_MAX_SEQ, "slots": DRIFT_SLOTS,
            "prompt_lens": [len(p) for p in prompts], "gen_lens": gens,
            "tuned_signature": sig_a,
            "donor_signature": ev_donor["signature"]},
        "drift_arms": {"stale": {k: v for k, v in stale.items()
                                 if k != "tokens"},
                       "retune": {k: v for k, v in retuned.items()
                                  if k != "tokens"}},
        "drift_token_parity": bool(parity),
        "drift_retune_events": [_finite_or_none(e) for e in events],
        "drift_stale_knobs": ev_stale["config"],
        "drift_retune_init_knobs": ev_init["config"],
        "drift_budget": {"stale_offline": int(ev_stale["n_tests"]),
                         "retune_offline": int(ev_init["n_tests"]),
                         "retune_donor": int(ev_donor["n_tests"]),
                         "retune_online": int(ev.get("n_tests", 0))},
        "retune_over_stale_serve": _rate(retuned) / _rate(stale),
        "drift_signature_cached": cached_ok,
        "drift_leaked_groups": (stale["leaked_groups"]
                                + retuned["leaked_groups"]),
    }


def _draft_cost() -> Dict[str, Any]:
    """The bounded-drafting row: ``draft_window`` makes the n-gram
    drafter's per-call cost a function of the window, not the history —
    16x more history must stay under 3x the cost (the unbounded contrast
    column shows what the bound is buying)."""
    from repro.serve import ServeEngine

    rng = np.random.default_rng(SEED)
    hists = {n: rng.integers(0, 8, size=n).tolist()
             for n in (DRAFT_SHORT, DRAFT_LONG)}

    def per_call(hist, window):
        ServeEngine._ngram_draft(hist, 4, window=window)  # warm caches
        t0 = time.perf_counter()
        for _ in range(DRAFT_COST_REPS):
            ServeEngine._ngram_draft(hist, 4, window=window)
        return (time.perf_counter() - t0) / DRAFT_COST_REPS

    bounded = {n: per_call(h, DRAFT_WINDOW) for n, h in hists.items()}
    unbounded_long = per_call(hists[DRAFT_LONG], 0)
    return {
        "window": DRAFT_WINDOW, "reps": DRAFT_COST_REPS,
        "short_len": DRAFT_SHORT, "long_len": DRAFT_LONG,
        "bounded_short_us": bounded[DRAFT_SHORT] * 1e6,
        "bounded_long_us": bounded[DRAFT_LONG] * 1e6,
        "unbounded_long_us": unbounded_long * 1e6,
        "bounded_ratio": (bounded[DRAFT_LONG]
                          / max(bounded[DRAFT_SHORT], 1e-12)),
        "unbounded_over_bounded": (unbounded_long
                                   / max(bounded[DRAFT_LONG], 1e-12)),
    }


def bench() -> Dict[str, Any]:
    model, params = _tiny_model()
    prompts, gens = _workload()

    arms: Dict[str, Dict[str, Any]] = {}
    arms["wave_fifo"] = _run_wave(model, params, prompts, gens)
    for sched in ("fifo", "sjf", "interleave"):
        arms[f"continuous_paged_{sched}"] = _run_continuous(
            model, params, "paged", sched, prompts, gens)
    arms["continuous_dense_fifo"] = _run_continuous(
        model, params, "dense", "fifo", prompts, gens)

    # schedule/layout/runtime parity: identical per-request tokens
    ref = arms["wave_fifo"]["tokens"]
    parity = all(arms[a]["tokens"] == ref for a in arms)

    # ---- sharded arms: the continuous_paged_fifo config re-run over
    # each mesh — sharding is the ONLY difference ------------------------
    import jax

    n_dev = len(jax.devices())
    sharded: Dict[str, Dict[str, Any]] = {}
    for sig, mesh in SHARDED_MESHES.items():
        if mesh[0] * mesh[1] <= n_dev:
            sharded[sig] = _run_continuous(
                model, params, "paged", "fifo", prompts, gens, mesh=mesh)
    sharded_parity = all(s["tokens"] == arms["continuous_paged_fifo"]["tokens"]
                         for s in sharded.values())

    # ---- oversubscribed page-policy arm: equal (small) pool, the
    # reservation policy is the only difference -------------------------
    os_prompts, os_gens = _oversub_workload()
    oversub: Dict[str, Dict[str, Any]] = {}
    for policy in ("reserve", "on_demand"):
        oversub[policy] = _run_continuous(
            model, params, "paged", "fifo", os_prompts, os_gens,
            page_policy=policy, pages=OVERSUB_POOL)
    oversub_parity = oversub["reserve"]["tokens"] == \
        oversub["on_demand"]["tokens"]

    # ---- repeated-shared-prefix arm: equal pool and schedule, the
    # share_prefix knob is the only difference --------------------------
    sh_prompts, sh_gens = _shared_workload()
    sharing: Dict[str, Dict[str, Any]] = {}
    for arm, share in (("unshared", False), ("shared", True)):
        sharing[arm] = _run_continuous(
            model, params, "paged", "fifo", sh_prompts, sh_gens,
            share_prefix=share, chunk=SHARED_PREFILL_CHUNK)
    sharing_parity = sharing["shared"]["tokens"] == \
        sharing["unshared"]["tokens"]

    def _serve_rate(s: Dict[str, Any]) -> float:
        # end-to-end serve rate: generated tokens over prefill+decode time
        # (prefill is exactly what sharing removes, so decode-only rates
        # would hide the win)
        return s["generated"] / max(s["prefill_s"] + s["decode_s"], 1e-9)

    headline = arms["continuous_paged_fifo"]
    baseline = arms["wave_fifo"]
    out = {
        "workload": {"n_requests": N_REQUESTS, "slots": SLOTS,
                     "max_seq": MAX_SEQ, "prefill_chunk": PREFILL_CHUNK,
                     "prompt_lens": [len(p) for p in prompts],
                     "gen_lens": gens, "seed": SEED},
        "arms": {a: {k: v for k, v in s.items() if k != "tokens"}
                 for a, s in arms.items()},
        "token_parity": bool(parity),
        "continuous_over_wave_decode": (headline["decode_tok_per_s"]
                                        / baseline["decode_tok_per_s"]),
        "continuous_over_wave_wall": (headline["wall_tok_per_s"]
                                      / baseline["wall_tok_per_s"]),
        "sharded_devices": n_dev,
        "sharded_arms": {a: {k: v for k, v in s.items() if k != "tokens"}
                         for a, s in sharded.items()},
        "sharded_token_parity": bool(sharded_parity),
        "sharded_leaked_groups": sum(s["leaked_groups"]
                                     for s in sharded.values()),
        "oversub_workload": {"kv_cache_pages": OVERSUB_POOL,
                             "prompt_lens": [len(p) for p in os_prompts],
                             "gen_lens": os_gens},
        "oversub_arms": {a: {k: v for k, v in s.items() if k != "tokens"}
                         for a, s in oversub.items()},
        "oversub_token_parity": bool(oversub_parity),
        "on_demand_over_reserve_decode": (
            oversub["on_demand"]["decode_tok_per_s"]
            / oversub["reserve"]["decode_tok_per_s"]),
        "oversub_leaked_groups": (oversub["reserve"]["leaked_groups"]
                                  + oversub["on_demand"]["leaked_groups"]),
        "shared_workload": {"prefix_len": SHARED_PREFIX_LEN,
                            "prefill_chunk": SHARED_PREFILL_CHUNK,
                            "prompt_lens": [len(p) for p in sh_prompts],
                            "gen_lens": sh_gens},
        "sharing_arms": {a: {k: v for k, v in s.items() if k != "tokens"}
                         for a, s in sharing.items()},
        "sharing_token_parity": bool(sharing_parity),
        "shared_over_unshared_serve": (_serve_rate(sharing["shared"])
                                       / _serve_rate(sharing["unshared"])),
        "sharing_leaked_groups": (sharing["shared"]["leaked_groups"]
                                  + sharing["unshared"]["leaked_groups"]),
        "draft_cost": _draft_cost(),
    }
    out.update(_drift_bench(model, params))
    with open(JSON_PATH, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    return out


def rows_from(result: Dict[str, Any]) -> List[Row]:
    arms = result["arms"]
    rows: List[Row] = []
    for a in ("wave_fifo", "continuous_paged_fifo", "continuous_paged_sjf",
              "continuous_paged_interleave", "continuous_dense_fifo"):
        s = arms[a]
        rows.append((f"serve_{a}", 0.0,
                     f"{s['decode_tok_per_s']:.0f} tok/s "
                     f"p50={s['p50_latency_s']:.3f}s "
                     f"p95={s['p95_latency_s']:.3f}s "
                     f"occ={s['occupancy']:.2f}"))
    rows.append(("serve_continuous_over_wave", 0.0,
                 f"{result['continuous_over_wave_decode']:.2f}x decode "
                 f"({result['continuous_over_wave_wall']:.2f}x wall)"))
    rows.append(("serve_token_parity", 0.0,
                 "ok" if result["token_parity"] else "MISMATCH"))
    for sig, s in sorted(result["sharded_arms"].items()):
        rows.append((f"serve_sharded_{sig}", 0.0,
                     f"{s['decode_tok_per_s']:.0f} tok/s "
                     f"steps={s['steps']} occ={s['occupancy']:.2f}"))
    rows.append(("serve_sharded_parity", 0.0,
                 "ok" if (result["sharded_token_parity"]
                          and result["sharded_leaked_groups"] == 0)
                 else "MISMATCH"))
    for policy in ("reserve", "on_demand"):
        s = result["oversub_arms"][policy]
        rows.append((f"serve_oversub_{policy}", 0.0,
                     f"{s['decode_tok_per_s']:.0f} tok/s "
                     f"steps={s['steps']} preempt={s['preemptions']} "
                     f"occ={s['occupancy']:.2f}"))
    rows.append(("serve_on_demand_over_reserve", 0.0,
                 f"{result['on_demand_over_reserve_decode']:.2f}x decode "
                 f"at {result['oversub_workload']['kv_cache_pages']} pages"))
    rows.append(("serve_oversub_parity", 0.0,
                 "ok" if (result["oversub_token_parity"]
                          and result["oversub_leaked_groups"] == 0)
                 else "MISMATCH"))
    for arm in ("unshared", "shared"):
        s = result["sharing_arms"][arm]
        rows.append((f"serve_prefix_{arm}", 0.0,
                     f"{s['generated'] / max(s['prefill_s'] + s['decode_s'], 1e-9):.0f} tok/s "
                     f"chunks={s['prefill_chunks']} "
                     f"shared={s['shared_prefix_tokens']} "
                     f"cow={s['cow_splits']}"))
    rows.append(("serve_shared_over_unshared", 0.0,
                 f"{result['shared_over_unshared_serve']:.2f}x "
                 "prefill+decode tok/s at equal pool"))
    rows.append(("serve_sharing_parity", 0.0,
                 "ok" if (result["sharing_token_parity"]
                          and result["sharing_leaked_groups"] == 0)
                 else "MISMATCH"))
    for arm in ("stale", "retune"):
        s = result["drift_arms"][arm]
        rows.append((f"serve_drift_{arm}", 0.0,
                     f"{s['generated'] / max(s['prefill_s'] + s['decode_s'], 1e-9):.0f} tok/s "
                     f"steps={s['steps']} occ={s['occupancy']:.2f}"))
    evs = result["drift_retune_events"]
    ev = evs[0] if evs else {}
    rows.append(("serve_retune_over_stale", 0.0,
                 f"{result['retune_over_stale_serve']:.2f}x "
                 f"prefill+decode tok/s at equal tuning budget "
                 f"[{ev.get('warm_source', 'no retune')}"
                 f" @step {ev.get('step', '-')}]"))
    rows.append(("serve_drift_parity", 0.0,
                 "ok" if (result["drift_token_parity"]
                          and result["drift_leaked_groups"] == 0
                          and result["drift_signature_cached"])
                 else "MISMATCH"))
    dc = result["draft_cost"]
    rows.append(("serve_draft_cost_flat", 0.0,
                 f"{dc['bounded_short_us']:.0f}us@{dc['short_len']} vs "
                 f"{dc['bounded_long_us']:.0f}us@{dc['long_len']} "
                 f"(x{dc['bounded_ratio']:.2f} bounded; unbounded "
                 f"x{dc['unbounded_over_bounded']:.1f} dearer)"))
    return rows


def run() -> List[Row]:
    """benchmarks.run entry point."""
    return rows_from(bench())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if continuous+paged underperforms "
                         "the wave baseline, or token parity breaks")
    args = ap.parse_args(argv)
    result = bench()
    for name, _, derived in rows_from(result):
        print(f"{name},{derived}")
    print(f"wrote {JSON_PATH}")
    if args.check:
        if not result["token_parity"]:
            print("CHECK FAILED: per-request tokens differ across "
                  "runtimes/schedules", file=sys.stderr)
            return 1
        ratio = result["continuous_over_wave_decode"]
        if ratio < 1.0:
            print(f"CHECK FAILED: continuous+paged decode throughput "
                  f"{ratio:.2f}x the wave baseline (< 1.0x)",
                  file=sys.stderr)
            return 1
        # ---- sharded arm gates (PR 9) --------------------------------
        if set(result["sharded_arms"]) != set(SHARDED_MESHES):
            print(f"CHECK FAILED: sharded arms missing "
                  f"(got {sorted(result['sharded_arms'])} on "
                  f"{result['sharded_devices']} devices — XLA_FLAGS fake "
                  "devices not in effect?)", file=sys.stderr)
            return 1
        if not result["sharded_token_parity"]:
            print("CHECK FAILED: per-request tokens differ across meshes",
                  file=sys.stderr)
            return 1
        if result["sharded_leaked_groups"]:
            print("CHECK FAILED: page groups leaked on the sharded arms",
                  file=sys.stderr)
            return 1
        # noise-free dispatch invariants: pure TP splits each batched
        # decode dispatch across devices — it must never add steps —
        # while a data axis widens admission and must strictly cut them
        base_steps = result["arms"]["continuous_paged_fifo"]["steps"]
        tp_steps = result["sharded_arms"]["d1m2"]["steps"]
        if tp_steps != base_steps:
            print(f"CHECK FAILED: pure-TP mesh took {tp_steps} decode "
                  f"steps vs {base_steps} unsharded (TP must dispatch "
                  "exactly the same batched steps)", file=sys.stderr)
            return 1
        for sig in ("d2m1", "d2m2"):
            ds = result["sharded_arms"][sig]["steps"]
            if ds >= base_steps:
                print(f"CHECK FAILED: mesh {sig} took {ds} decode steps "
                      f"vs {base_steps} unsharded (the data axis widened "
                      "nothing)", file=sys.stderr)
                return 1
        if not result["oversub_token_parity"]:
            print("CHECK FAILED: per-request tokens differ across page "
                  "policies on the oversubscribed workload",
                  file=sys.stderr)
            return 1
        if result["oversub_leaked_groups"]:
            print("CHECK FAILED: page groups leaked on the oversubscribed "
                  "workload", file=sys.stderr)
            return 1
        # the noise-free packing signal first: fewer batched decode steps
        # at equal tokens is deterministic, unlike CPU wall-clock
        od_steps = result["oversub_arms"]["on_demand"]["steps"]
        rs_steps = result["oversub_arms"]["reserve"]["steps"]
        if od_steps >= rs_steps:
            print(f"CHECK FAILED: on_demand took {od_steps} decode steps "
                  f"vs reserve's {rs_steps} at equal kv_cache_pages "
                  "(packing gained nothing)", file=sys.stderr)
            return 1
        od_ratio = result["on_demand_over_reserve_decode"]
        if od_ratio <= 1.0:
            print(f"CHECK FAILED: on_demand+preemption decode throughput "
                  f"{od_ratio:.2f}x reserve at equal kv_cache_pages "
                  "(must be > 1.0x)", file=sys.stderr)
            return 1
        if result["oversub_arms"]["on_demand"]["preemptions"] < 1:
            print("CHECK FAILED: oversubscribed arm issued no recompute "
                  "preemptions (the pool is not actually oversubscribed)",
                  file=sys.stderr)
            return 1
        if not result["sharing_token_parity"]:
            print("CHECK FAILED: per-request tokens differ with "
                  "share_prefix on the repeated-prefix workload",
                  file=sys.stderr)
            return 1
        if result["sharing_leaked_groups"]:
            print("CHECK FAILED: page groups leaked on the shared-prefix "
                  "workload", file=sys.stderr)
            return 1
        sh = result["sharing_arms"]["shared"]
        un = result["sharing_arms"]["unshared"]
        # noise-free first: sharing must actually skip prefill dispatches
        if sh["prefill_chunks"] >= un["prefill_chunks"] or \
                sh["shared_prefix_tokens"] <= 0:
            print(f"CHECK FAILED: sharing issued {sh['prefill_chunks']} "
                  f"prefill chunks vs {un['prefill_chunks']} unshared "
                  f"(shared tokens: {sh['shared_prefix_tokens']}) — "
                  "nothing was actually shared", file=sys.stderr)
            return 1
        sh_ratio = result["shared_over_unshared_serve"]
        if sh_ratio < 2.0:
            print(f"CHECK FAILED: shared-prefix serve throughput "
                  f"{sh_ratio:.2f}x unshared at an equal pool "
                  "(must be >= 2.0x)", file=sys.stderr)
            return 1
        # ---- drifting-workload arm gates (PR 8) ----------------------
        if not result["drift_token_parity"]:
            print("CHECK FAILED: per-request tokens differ across the "
                  "mid-stream retune knob swap", file=sys.stderr)
            return 1
        if result["drift_leaked_groups"]:
            print("CHECK FAILED: page groups leaked on the drifting "
                  "workload", file=sys.stderr)
            return 1
        evs = result["drift_retune_events"]
        if len(evs) != 1:
            print(f"CHECK FAILED: expected exactly one online retune on "
                  f"the drift arm, got {len(evs)}", file=sys.stderr)
            return 1
        ev = evs[0]
        if not str(ev.get("warm_source", "")).startswith("near("):
            print(f"CHECK FAILED: the online retune was not warm-started "
                  f"by nearest-signature transfer "
                  f"(warm_source={ev.get('warm_source')!r})",
                  file=sys.stderr)
            return 1
        sa, ma = ev.get("spec_accept"), ev.get("measured_accept")
        if sa is None or ma is None or abs(sa - ma) > 0.1:
            print(f"CHECK FAILED: retuned spec_accept {sa} is not within "
                  f"0.1 of the measured acceptance rate {ma}",
                  file=sys.stderr)
            return 1
        # noise-free first: the retuned knobs must finish the same
        # tokens in strictly fewer batched decode steps
        rt_steps = result["drift_arms"]["retune"]["steps"]
        st_steps = result["drift_arms"]["stale"]["steps"]
        if rt_steps >= st_steps:
            print(f"CHECK FAILED: online retuning took {rt_steps} decode "
                  f"steps vs the stale winner's {st_steps} "
                  "(the swap gained nothing)", file=sys.stderr)
            return 1
        dr_ratio = result["retune_over_stale_serve"]
        if dr_ratio < 1.15:
            print(f"CHECK FAILED: online retuning served {dr_ratio:.2f}x "
                  "the stale offline winner at equal total tuning budget "
                  "(must be >= 1.15x)", file=sys.stderr)
            return 1
        if not result["drift_signature_cached"]:
            print("CHECK FAILED: the online winner was not persisted "
                  "under its workload signature", file=sys.stderr)
            return 1
        b = result["drift_budget"]
        spent = (b["retune_offline"] + b["retune_donor"]
                 + b["retune_online"])
        if b["stale_offline"] != spent:
            print(f"CHECK FAILED: tuning budgets differ — stale "
                  f"{b['stale_offline']} tests vs retune arm "
                  f"{spent}", file=sys.stderr)
            return 1
        dc_ratio = result["draft_cost"]["bounded_ratio"]
        if dc_ratio >= 3.0:
            print(f"CHECK FAILED: bounded n-gram drafting cost grew "
                  f"{dc_ratio:.2f}x from {DRAFT_SHORT} to {DRAFT_LONG} "
                  "tokens of history (must stay < 3x: the lookback "
                  "bound is not bounding)", file=sys.stderr)
            return 1
        rep_steps = result["sharded_arms"]["d2m1"]["steps"]
        print(f"check OK: continuous+paged = {ratio:.2f}x wave decode "
              f"throughput; on_demand = {od_ratio:.2f}x reserve at "
              f"{OVERSUB_POOL} pages; share_prefix = {sh_ratio:.2f}x "
              f"unshared on the repeated-prefix arm; online retune = "
              f"{dr_ratio:.2f}x the stale winner at equal budget "
              f"({st_steps}->{rt_steps} steps, drafting cost flat at "
              f"{dc_ratio:.2f}x); sharded meshes hold parity (TP steps "
              f"{tp_steps}=={base_steps}, replicas {rep_steps}<"
              f"{base_steps}); token parity holds, pool balanced")
    return 0


if __name__ == "__main__":
    sys.exit(main())
