"""§5.2 / Table 1 reproduction: ACTS on a fully-utilized Tomcat server.

The paper's Table 1: Txns/s 978→1018 (+4.07%), Hits/s 3235→3620 (+11.91%),
passed txns +6.19%, failed −12.73%, errors −8.11% — small but across-the-
board gains on a saturated deployment (the "eliminate 1 VM in every 26"
result: 1/26 ≈ the throughput gain).
"""
from __future__ import annotations

import time
from typing import List

from repro.core import TomcatSurrogate, Tuner

from .common import Row


def run() -> List[Row]:
    sut = TomcatSurrogate(fully_utilized=True)
    t0 = time.time()
    rep = Tuner(sut.space(), sut, budget=120, seed=3).run()
    us = (time.time() - t0) * 1e6 / rep.n_tests
    d, b = rep.default_metric.metrics, rep.best_metric.metrics
    imp = rep.improvement - 1.0

    def pct(key, lower_better=False):
        delta = (b[key] - d[key]) / d[key] * 100
        return f"{delta:+.2f}%"

    vms = int(round(1.0 / imp)) if imp > 0 else -1
    return [
        ("tomcat_txns_per_sec", us, f"{d['txns_per_sec']:.0f}->"
                                    f"{b['txns_per_sec']:.0f} ({pct('txns_per_sec')})"),
        ("tomcat_hits_per_sec", us, pct("hits_per_sec")),
        ("tomcat_passed_txns", us, pct("passed_txns")),
        ("tomcat_failed_txns", us, pct("failed_txns")),
        ("tomcat_errors", us, pct("errors")),
        ("tomcat_vm_eliminated_1_in", us, vms),
    ]
