"""End-to-end ACTS on the *real* JAX runtime with measured wall-clock.

The SUT is an actual tiny-LM training deployment on this host (CPU): each
test re-jits the train step under the candidate execution knobs and measures
steps/sec — the paper's full loop (apply config → restart → run workload →
measure) with nothing simulated.  Derived metric: tuned/default throughput.
"""
from __future__ import annotations

import time
from typing import List

from repro.configs import get_config, reduced
from repro.core.sut_jax import JaxMeasuredSUT
from repro.core.tuner import Tuner

from .common import Row

BUDGET = 10


def run() -> List[Row]:
    cfg = reduced(get_config("gemma-7b"))
    sut = JaxMeasuredSUT(cfg, seq_len=128, global_batch=8, steps=4, warmup=2)
    t0 = time.time()
    rep = Tuner(sut.space(), sut, budget=BUDGET, seed=0).run()
    us = (time.time() - t0) * 1e6 / rep.n_tests
    return [
        ("real_default_tokens_per_sec", us,
         f"{rep.default_metric.value:.0f}"),
        ("real_tuned_tokens_per_sec", us, f"{rep.best_metric.value:.0f}"),
        ("real_improvement", us, f"{rep.improvement:.2f}x"),
        ("real_best_config", us,
         str(rep.best_config).replace(",", ";")),
    ]
