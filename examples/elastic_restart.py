"""Fault tolerance demo: crash mid-training, resume from the atomic
checkpoint, and verify the resumed run reaches the same state as an
uninterrupted one (deterministic data pipeline + checkpointed optimizer).

  PYTHONPATH=src python examples/elastic_restart.py
"""
import shutil

import jax
import numpy as np

from repro.configs import ModelConfig
from repro.optim import OptimizerConfig
from repro.train import RunKnobs, SimulatedFailure, TrainLoopConfig, train

CFG = ModelConfig(
    name="elastic-demo", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32", vocab_pad_multiple=64,
)

CKPT = "results/elastic_ckpt"


def loop(**kw):
    base = dict(steps=20, seq_len=32, global_batch=4, log_every=5,
                opt=OptimizerConfig(learning_rate=3e-3, warmup_steps=2,
                                    total_steps=40),
                knobs=RunKnobs(rules_preset="dp", remat="none",
                               microbatches=1, loss_chunk=0))
    base.update(kw)
    return TrainLoopConfig(**base)


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    print("=== reference: uninterrupted 20-step run ===")
    ref = train(CFG, loop())

    print("\n=== run 2: crash injected at step 12 (ckpt every 5) ===")
    try:
        train(CFG, loop(ckpt_dir=CKPT, ckpt_every=5, fail_at_step=12))
    except SimulatedFailure as e:
        print(f"!! node failure: {e}")

    print("\n=== run 3: restart — auto-resumes from step 10 ===")
    resumed = train(CFG, loop(ckpt_dir=CKPT, ckpt_every=5))

    diffs = jax.tree_util.tree_map(
        lambda a, b: float(np.max(np.abs(np.asarray(a, np.float32) -
                                         np.asarray(b, np.float32)))),
        ref["params"], resumed["params"])
    worst = max(jax.tree_util.tree_leaves(diffs))
    print(f"\nmax |param diff| vs uninterrupted run: {worst:.2e}")
    assert worst < 1e-4, "resumed training diverged!"
    print("fault-tolerant resume verified ✓")


if __name__ == "__main__":
    main()
