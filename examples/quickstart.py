"""Quickstart: train a ~110M-parameter LM end to end on this host.

The full run (default args) trains 300 steps of a 12-layer/768-wide model —
the deliverable-(b) end-to-end driver.  On a laptop-class CPU each step is
seconds; pass ``--fast`` for a 2-minute sanity run (tiny model, 30 steps).

  PYTHONPATH=src python examples/quickstart.py            # the real thing
  PYTHONPATH=src python examples/quickstart.py --fast     # CI-sized
"""
import argparse

from repro.configs import ModelConfig
from repro.models import count_params
from repro.optim import OptimizerConfig
from repro.train import RunKnobs, TrainLoopConfig, train

REPRO_110M = ModelConfig(
    name="repro-110m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=3072, vocab_size=32000,
    activation="swiglu", rope_theta=10_000.0, tie_embeddings=True,
    param_dtype="float32", compute_dtype="float32",
)

REPRO_TINY = ModelConfig(
    name="repro-tiny", family="dense", n_layers=4, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=2048, head_dim=32,
    param_dtype="float32", compute_dtype="float32", vocab_pad_multiple=256,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="results/quickstart_ckpt")
    args = ap.parse_args()

    cfg = REPRO_TINY if args.fast else REPRO_110M
    steps = args.steps or (30 if args.fast else 300)
    seq_len = 128 if args.fast else 256
    print(f"model: {cfg.name} ({count_params(cfg) / 1e6:.1f}M params), "
          f"{steps} steps @ seq {seq_len}")

    loop = TrainLoopConfig(
        steps=steps, seq_len=seq_len, global_batch=8, log_every=10,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(steps // 3, 10),
        opt=OptimizerConfig(learning_rate=3e-4, warmup_steps=20,
                            total_steps=steps),
        knobs=RunKnobs(rules_preset="dp", remat="none", microbatches=1,
                       loss_chunk=0),
    )
    out = train(cfg, loop)
    h = out["history"]
    print(f"\nloss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over "
          f"{out['final_step']} steps "
          f"({sum(x['tokens_per_sec'] for x in h) / len(h):.0f} tok/s avg)")
    print(f"checkpoints in {args.ckpt_dir} (resume by re-running)")


if __name__ == "__main__":
    main()
