"""Serve a small model with batched requests: prefill + KV-cache decode
through the same ``serve_step`` the decode dry-run cells lower.

  PYTHONPATH=src python examples/serve_batched.py [--requests 12]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--arch", default="gemma3-12b",
                    help="served at its reduced config on CPU")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, ServeConfig(max_seq=128,
                                                    batch_slots=4))

    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab_size,
                           size=(args.requests, args.prompt_len)).tolist()
    print(f"serving {args.requests} requests on {cfg.name} "
          f"(slots=4, prompt={args.prompt_len}, max_new={args.max_new})")
    res = engine.generate(prompts, max_new_tokens=args.max_new)
    for i, toks in enumerate(res.tokens[:4]):
        print(f"req {i}: {toks[:12]}{'...' if len(toks) > 12 else ''}")
    print(f"prefill {res.prefill_seconds:.2f}s, decode "
          f"{res.decode_seconds:.2f}s, "
          f"{res.decode_tokens_per_sec:.1f} tok/s decode throughput")


if __name__ == "__main__":
    main()
