"""ACTS applied to THIS framework with real measured wall-clock: tune the
train-step execution knobs (remat / microbatching / loss chunking / buffer
donation) of a small LM on this host.  Every test re-jits and times actual
training steps — the paper's full apply→restart→measure loop, nothing
simulated.

  PYTHONPATH=src python examples/tune_runtime.py [--budget 10]
"""
import argparse

from repro.configs import get_config, reduced
from repro.core.sut_jax import JaxMeasuredSUT
from repro.core.tuner import Tuner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=10)
    ap.add_argument("--arch", default="gemma-7b")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    sut = JaxMeasuredSUT(cfg, seq_len=128, global_batch=8, steps=4, warmup=2)
    rep = Tuner(sut.space(), sut, budget=args.budget, seed=0,
                verbose=True).run()
    print(f"\nSUT: {sut.name}")
    print(f"default knobs: {rep.default_metric.value:8.0f} tokens/s  "
          f"{rep.default_config}")
    print(f"tuned knobs:   {rep.best_metric.value:8.0f} tokens/s  "
          f"({rep.improvement:.2f}x)  {rep.best_config}")


if __name__ == "__main__":
    main()
