"""ACTS on the paper's MySQL scenario (§5.1): LHS + RRS vs the default
configuration, 200-test resource limit.

  PYTHONPATH=src python examples/tune_surrogate.py [--budget 200]
"""
import argparse

from repro.core import MySQLSurrogate, Tuner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=int, default=200)
    ap.add_argument("--workload", default="uniform_read",
                    choices=("uniform_read", "zipfian_rw"))
    ap.add_argument("--optimizer", default="rrs",
                    choices=("rrs", "random", "shc", "lhs_only"))
    args = ap.parse_args()

    sut = MySQLSurrogate(args.workload)
    tuner = Tuner(sut.space(), sut, budget=args.budget,
                  optimizer=args.optimizer, seed=1)
    rep = tuner.run()
    print(f"\nSUT: {sut.name}  (resource limit: {args.budget} tests)")
    print(f"default: {rep.default_metric.value:10.0f} ops/s")
    print(f"tuned:   {rep.best_metric.value:10.0f} ops/s  "
          f"({rep.improvement:.2f}x — paper reports >11x)")
    print("best configuration:")
    for k, v in sorted(rep.best_config.items()):
        print(f"  {k} = {v}")


if __name__ == "__main__":
    main()
