#!/usr/bin/env bash
# CI entry point: tier-1 test suite + batched-tuning smoke benchmark.
#
#   scripts/ci.sh            # full tier-1 suite, then the smoke bench
#   scripts/ci.sh --fast     # skip the slow subprocess/dry-run tests
#
# The smoke benchmark runs the batched-vs-sequential evaluation engine
# comparison (RRS on the MySQL surrogate, budget 500) and fails CI if the
# engines diverge; its speedup line is the perf-trajectory signal.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-k "not subprocess and not DryRun and not TuneCLI and not collectives_counted")
fi

echo "=== tier-1: python -m pytest ${PYTEST_ARGS[*]} ==="
python -m pytest "${PYTEST_ARGS[@]}"

echo "=== smoke: batched tuning engine (budget 500, ~seconds) ==="
timeout 30 python - <<'EOF'
import benchmarks.rrs_convergence as rc

rows = rc._bench_batched_engine()
for name, us, derived in rows:
    print(f"{name},{us:.1f},{derived}")
speedup = float(rows[2][2].rstrip("x"))
assert speedup > 1.0, f"batched engine slower than sequential ({speedup}x)"
EOF

echo "=== smoke: joint co-tuning (--joint, tiny budget, surrogate) ==="
CI_TMP="$(mktemp -d)"
trap 'rm -rf "$CI_TMP"' EXIT
REPRO_AUTOTUNE_CACHE="$CI_TMP/autotune.json" timeout 30 \
    python -m repro.launch.tune --arch xlstm-350m --shape decode_32k \
    --joint --surrogate --budget 16 --out-dir "$CI_TMP/tune" > /dev/null
echo "joint smoke OK"

echo "=== check: joint >= independent tuning at equal budget ==="
timeout 120 python -m benchmarks.cotune_bench --check

echo "CI OK"
