#!/usr/bin/env bash
# CI entry point: tier-1 test suite + batched-tuning smoke benchmark.
#
#   scripts/ci.sh            # full tier-1 suite, then the smoke bench
#   scripts/ci.sh --fast     # skip the slow subprocess/dry-run tests
#
# The smoke benchmark runs the batched-vs-sequential evaluation engine
# comparison (RRS on the MySQL surrogate, budget 500) and fails CI if the
# engines diverge; its speedup line is the perf-trajectory signal.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

PYTEST_ARGS=(-x -q)
if [[ "${1:-}" == "--fast" ]]; then
    PYTEST_ARGS+=(-k "not subprocess and not DryRun and not TuneCLI and not collectives_counted")
fi

# Post-PR10 baseline: CI fails if the collected count ever drops below it
# (a silently skipped/broken test file must not read as green).
MIN_COLLECTED=684
echo "=== check: collected test count >= ${MIN_COLLECTED} ==="
COLLECT_OUT=$(python -m pytest -q --collect-only 2>&1 | tail -5 || true)
COLLECTED=$(tail -1 <<<"$COLLECT_OUT" | grep -oE '^[0-9]+' || true)
echo "collected: ${COLLECTED:-<collection failed>}"
if [[ -z "$COLLECTED" ]] || (( COLLECTED < MIN_COLLECTED )); then
    echo "$COLLECT_OUT"
    echo "FAIL: test collection below the ${MIN_COLLECTED} baseline (or broken)"
    exit 1
fi

echo "=== tier-1: python -m pytest ${PYTEST_ARGS[*]} ==="
python -m pytest "${PYTEST_ARGS[@]}"

echo "=== determinism matrix: every optimizer × dispatch mode × seed ==="
python -m pytest -q tests/test_determinism_matrix.py

echo "=== lint gate: jit/Pallas/allocator + interprocedural dataflow ==="
# Machine-readable AST lint over the whole package (repro.analysis.lint):
# jit retrace hazards, pallas_call arity contracts, allocator unwind
# discipline, plus the PR 10 dataflow families — determinism-taint,
# jit-trace-capture/host-effect, cache lock-discipline — run over the
# module-level call graph.  Exits non-zero on ANY finding; the committed
# baseline is zero, so a new finding is a regression, not noise.
python -m repro.analysis.lint --check src/repro
echo "lint gate OK (zero findings)"

echo "=== smoke: dataflow lint recall (planted fixtures must fire) ==="
# Zero findings on src/repro must mean "analyzed and clean", not
# "analysis silently off": each PR 10 rule family must fire on its
# planted fixture (7 taint + 3 capture + 2 host-effect + 3 lock = 15)
# and the pragma fixture must stay silent.  lint_bench re-times the full
# gate and writes BENCH_lint.json (wall-time per pass + planted recall).
timeout 120 python - <<'EOF'
from pathlib import Path

from repro.analysis import lint as L

FIX = Path("tests/fixtures/lint")
want = {
    "bad_taint.py": {"determinism-taint": 7},
    "bad_trace_capture.py": {"jit-trace-capture": 3, "jit-host-effect": 2},
    "bad_cache_lock.py": {"cache-lock-discipline": 3},
}
for name, expect in want.items():
    got = {}
    for f in L.lint_file(FIX / name):
        got[f.rule] = got.get(f.rule, 0) + 1
    assert got == expect, f"{name}: planted {expect}, lint saw {got}"
assert L.lint_file(FIX / "pragma_ok.py") == [], "pragmas stopped working"
print("dataflow recall smoke OK (15 planted findings caught, pragmas ok)")
EOF
timeout 120 python -m benchmarks.lint_bench --check

echo "=== smoke: static feasibility pruning (zero-budget infeasible) ==="
# A kernel tune over a shape whose biggest tiles blow VMEM: infeasible
# configs must be pruned WITHOUT charging budget (counted instead), every
# charged trial must be statically feasible and finitely scored, and the
# pruned trial stream must reproduce under its seed.
timeout 60 python - <<'EOF'
import math

from repro.analysis.feasibility import kernel_feasibility
from repro.autotune.sut import KernelSUT
from repro.core.tuner import Tuner

DIMS = {"ROWS": 8192, "D": 6144}  # block_rows >= 512 exceeds VMEM

def run():
    sut = KernelSUT("rmsnorm", DIMS, mode="model")
    return Tuner(sut.space(), sut, budget=24, optimizer="rrs",
                 seed=0).run()

rep, rep2 = run(), run()
model = kernel_feasibility("rmsnorm", DIMS, "float32")
assert rep.n_infeasible_pruned > 0, "pruning never engaged"
assert all(model(t.config) for t in rep.history[1:]), \
    "an infeasible config was charged a test"
assert all(math.isfinite(t.value) for t in rep.history[1:]), \
    "a charged trial scored inf"
trace = lambda r: [(sorted(t.config.items()), t.value) for t in r.history]
assert trace(rep) == trace(rep2) \
    and rep.n_infeasible_pruned == rep2.n_infeasible_pruned, \
    "pruning broke seeded determinism"
print(f"pruning smoke OK ({rep.n_infeasible_pruned} pruned for free, "
      f"{rep.n_tests} charged, best={rep.best_config})")
EOF

echo "=== smoke: batched tuning engine (budget 500, ~seconds) ==="
timeout 30 python - <<'EOF'
import benchmarks.rrs_convergence as rc

rows = rc._bench_batched_engine()
for name, us, derived in rows:
    print(f"{name},{us:.1f},{derived}")
speedup = float(rows[2][2].rstrip("x"))
assert speedup > 1.0, f"batched engine slower than sequential ({speedup}x)"
EOF

echo "=== smoke: joint co-tuning (--joint, tiny budget, surrogate) ==="
CI_TMP="$(mktemp -d)"
trap 'rm -rf "$CI_TMP"' EXIT
REPRO_AUTOTUNE_CACHE="$CI_TMP/autotune.json" timeout 30 \
    python -m repro.launch.tune --arch xlstm-350m --shape decode_32k \
    --joint --surrogate --budget 16 --out-dir "$CI_TMP/tune" > /dev/null
echo "joint smoke OK"

echo "=== smoke: LIVE joint co-tuning (--joint --real, tiny model, ~30s) ==="
# Wall-clocks the real ServeEngine + train step per trial (reduced
# gemma-7b, budget 4, single timed repeat) and must persist all three
# winners — kernel, serve_engine, train_step — in one cache file.
REPRO_AUTOTUNE_CACHE="$CI_TMP/autotune_real.json" timeout 90 \
    python -m repro.launch.tune --arch gemma-7b --shape decode_32k \
    --joint --real --budget 4 --real-repeats 1 \
    --out-dir "$CI_TMP/tune_real" > /dev/null
python - "$CI_TMP/autotune_real.json" <<'EOF'
import json, sys

systems = {k.split("|")[1] for k in json.load(open(sys.argv[1]))}
missing = {"decode_attention", "serve_engine", "train_step"} - systems
assert not missing, f"cache missing joint winners: {missing}"
print("real joint smoke OK (kernel + serve_engine + train_step persisted)")
EOF

echo "=== check: joint >= independent tuning at equal budget ==="
timeout 120 python -m benchmarks.cotune_bench --check

echo "=== smoke: continuous batching (3 schedules x paged+dense, ~30s) ==="
# Mixed-length workload through the REAL continuous engine under every
# schedule and both KV layouts; per-request tokens must be identical
# everywhere (the schedule knob moves timing, never content) and the
# paged allocator must end balanced.
timeout 120 python - <<'EOF'
import jax, numpy as np
from repro.configs import ModelConfig
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine

cfg = ModelConfig(
    name="ci-tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32", vocab_pad_multiple=64,
    rope_theta=10_000.0)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompts = [rng.integers(1, 512, size=n).tolist()
           for n in rng.integers(2, 20, size=10)]
gens = [int(g) for g in rng.integers(1, 9, size=10)]
ref = None
for layout in ("paged", "dense"):
    for sched in ("fifo", "sjf", "interleave"):
        eng = ServeEngine(model, params, ServeConfig(
            max_seq=32, batch_slots=3, runtime="continuous",
            kv_layout=layout, schedule=sched, prefill_chunk=4))
        res = eng.generate(prompts, gens)
        if ref is None:
            ref = res.tokens
        assert res.tokens == ref, f"{layout}/{sched} diverged"
        if layout == "paged":
            assert eng.last_alloc.groups_in_use == 0, "page leak"
            eng.last_alloc.check_balanced()
print("continuous smoke OK (6 runtime combos, identical tokens, no leaks)")
EOF

echo "=== smoke: oversubscription + recompute preemption (~20s) ==="
# Decode-heavy workload on a pool too small for worst-case reservations:
# on_demand MUST preempt (recompute), tokens must match the reserve run
# bit-for-bit, and no page group may outlive the run.
timeout 120 python - <<'EOF'
import jax, numpy as np
from repro.configs import ModelConfig
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine

cfg = ModelConfig(
    name="ci-tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32", vocab_pad_multiple=64,
    rope_theta=10_000.0)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(1)
prompts = [rng.integers(1, 512, size=n).tolist()
           for n in rng.integers(3, 9, size=8)]
gens = [int(g) for g in rng.integers(10, 17, size=8)]
out = {}
for policy in ("reserve", "on_demand"):
    eng = ServeEngine(model, params, ServeConfig(
        max_seq=32, batch_slots=3, runtime="continuous", kv_layout="paged",
        kv_cache_pages=4, page_policy=policy, prefill_chunk=4))
    res = eng.generate(prompts, gens)
    assert eng.last_alloc.groups_in_use == 0, f"{policy}: page leak"
    eng.last_alloc.check_balanced()
    out[policy] = res
assert out["on_demand"].preemptions > 0, "tiny pool never preempted"
assert out["reserve"].preemptions == 0
assert out["on_demand"].tokens == out["reserve"].tokens, \
    "preemption changed generated tokens"
assert out["on_demand"].steps < out["reserve"].steps, \
    "on_demand packing did not reduce decode steps"
print(f"oversubscription smoke OK ({out['on_demand'].preemptions} "
      f"preemptions, identical tokens, "
      f"{out['on_demand'].steps} vs {out['reserve'].steps} decode steps, "
      "no leaks)")
EOF

echo "=== smoke: prefix sharing (CoW) + speculative decoding (~30s) ==="
# Repeated shared-prefix workload: sharing MUST skip prefill dispatches
# and split at least one group copy-on-write; speculation MUST draft and
# accept tokens in fewer decode dispatches. Tokens are bit-identical to
# the plain run in every arm, and no page group may outlive a run.
timeout 120 python - <<'EOF'
import jax, jax.numpy as jnp
from repro.configs import ModelConfig
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine

cfg = ModelConfig(
    name="ci-tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32", vocab_pad_multiple=64,
    rope_theta=10_000.0)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
# A long donor, a short filler that frees a slot, then two sharers: an
# exact copy (coverage capped at prompt-1 lands mid-group -> CoW) and a
# mid-group prefix. The donor generates long enough to stay resident.
donor = [((i * 37) % 509) + 1 for i in range(32)]
prompts = [donor, [1, 2, 3], list(donor), donor[:20]]
gens = [26, 2, 5, 4]

def run(p, **kw):
    eng = ServeEngine(model, p, ServeConfig(
        max_seq=64, batch_slots=2, runtime="continuous",
        kv_layout="paged", prefill_chunk=4, **kw))
    res = eng.generate(prompts, gens)
    assert eng.last_alloc.groups_in_use == 0, f"{kw}: page leak"
    eng.last_alloc.check_balanced()
    return res

plain = run(params)
shared = run(params, share_prefix=True)
assert shared.tokens == plain.tokens, "sharing changed generated tokens"
assert shared.shared_prefix_tokens > 0, "shared-prefix workload never shared"
assert shared.cow_splits > 0, "no copy-on-write split ever happened"
assert shared.prefill_chunks < plain.prefill_chunks, \
    "sharing did not skip prefill dispatches"
both = run(params, share_prefix=True, draft_len=4)
assert both.tokens == plain.tokens, "sharing+speculation changed tokens"
# Zeroed params give repetitive argmax output, so n-gram drafts MUST
# land: fewer decode dispatches for the same (trivial) tokens.
zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
zplain = run(zeros)
zspec = run(zeros, draft_len=4)
assert zspec.tokens == zplain.tokens, "speculation changed generated tokens"
assert zspec.drafted > 0 and zspec.accepted > 0, "speculation never accepted"
assert zspec.steps < zplain.steps, \
    "accepted drafts did not reduce decode dispatches"
print(f"sharing+speculation smoke OK ({shared.shared_prefix_tokens} shared "
      f"tokens, {shared.cow_splits} CoW splits, "
      f"{shared.prefill_chunks} vs {plain.prefill_chunks} prefill chunks, "
      f"{zspec.accepted}/{zspec.drafted} drafts accepted, "
      f"{zspec.steps} vs {zplain.steps} decode dispatches, identical "
      "tokens, no leaks)")
EOF

echo "=== smoke: online workload-aware retuning (~30s) ==="
# A drifting workload (distinct long prompts, then shared-prefix short
# tails) through the live engine with --retune semantics: the shift
# detector MUST fire exactly once, the mid-run knob swap MUST leave
# generated tokens bit-identical to a never-retuned run, the measured
# draft acceptance MUST reach the retune's surrogate (spec_accept within
# 0.1), and the winner MUST persist under its workload signature.
REPRO_AUTOTUNE_CACHE="$CI_TMP/retune_smoke.json" timeout 120 python - <<'EOF'
import math

import jax, numpy as np
from repro import autotune
from repro.configs import ModelConfig
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine
from repro.serve.workload import fingerprint_sig

cfg = ModelConfig(
    name="ci-tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32", vocab_pad_multiple=64,
    rope_theta=10_000.0)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
BASE = dict(max_seq=48, batch_slots=8, kv_layout="paged", seed=0,
            prefill_chunk=8, slot_cap=3)
RETUNE = dict(retune=True, retune_budget=8, retune_threshold=0.3,
              retune_window=10, retune_cooldown=200,
              retune_check_every=2, retune_min_requests=6)

# the signature the deployed knobs were (notionally) tuned under:
# measured from a phase-A-only run with the detector anchored but inert
rng = np.random.default_rng(0)
pa = [rng.integers(1, 500, size=20).tolist() for _ in range(6)]
eng = ServeEngine(model, params, ServeConfig(
    **BASE, retune=True, retune_threshold=10.0, retune_min_requests=6,
    retune_window=10))
eng.generate(pa, [12] * 6)
sig_a = fingerprint_sig(eng.last_retuner.baseline)

# phase A then a shift to shared-prefix short-tail bursts
rng = np.random.default_rng(0)
prompts = [rng.integers(1, 500, size=20).tolist() for _ in range(3)]
shared = rng.integers(1, 500, size=32).tolist()
prompts += [shared + rng.integers(1, 500, size=3).tolist()
            for _ in range(12)]
gens = [12] * 3 + [6] * 12

autotune.reset_default_cache()
eng = ServeEngine(model, params, ServeConfig(
    **BASE, tuned_signature=sig_a, **RETUNE))
res = eng.generate(prompts, gens)
eng.last_alloc.check_balanced()
base = ServeEngine(model, params,
                   ServeConfig(**BASE)).generate(prompts, gens)
assert len(res.retunes) == 1, f"retune fired {len(res.retunes)}x, not once"
ev = res.retunes[0]
assert ev["applied"], "the retune moved no knob"
assert res.tokens == base.tokens, "knob swap changed generated tokens"
assert math.isfinite(ev["measured_accept"]) and \
    abs(ev["spec_accept"] - ev["measured_accept"]) <= 0.1, \
    "measured acceptance never reached the retune surrogate"
cands = autotune.serve_config_candidates(
    {"S": 48, "H": cfg.padded_heads, "KV": cfg.n_kv_heads,
     "D": cfg.head_dim_}, cfg.compute_dtype)
entry = cands.get(ev["signature"])
assert entry is not None, "winner not cached under its workload signature"
assert entry["config"] == ev["config"]
assert entry["meta"]["source"] == "online_retune"
moved = ", ".join(f"{k} {o}->{n}" for k, (o, n) in ev["applied"].items())
print(f"retune smoke OK (drift {ev['distance']:.2f} @step {ev['step']} "
      f"[{ev['warm_source']}] -> {moved}; accept "
      f"{ev['measured_accept']:.2f}, identical tokens, winner cached)")
EOF

echo "=== smoke: sharded serving (8 fake devices, TP + replicas, ~60s) ==="
# Tensor-parallel decode over a (data, model) mesh: per-request tokens
# must be bit-identical across meshes, pure TP must dispatch EXACTLY the
# unsharded number of batched decode steps (it splits each dispatch, it
# never adds one), a data axis must strictly cut them (capacity widens
# x data), and the paged pool must end balanced.  Runs in its own
# interpreter so XLA_FLAGS can fake 8 host devices before jax loads —
# this is also the only sharded-engine coverage in --fast runs, which
# skip the subprocess tier-1 tests.
XLA_FLAGS="--xla_force_host_platform_device_count=8" timeout 180 python - <<'EOF'
import jax, numpy as np
from repro.configs import ModelConfig
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine

assert len(jax.devices()) == 8, jax.devices()
cfg = ModelConfig(
    name="ci-tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=512, head_dim=16,
    param_dtype="float32", compute_dtype="float32", vocab_pad_multiple=64,
    rope_theta=10_000.0)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompts = [rng.integers(1, 512, size=n).tolist()
           for n in rng.integers(2, 20, size=10)]
gens = [int(g) for g in rng.integers(1, 9, size=10)]

def run(mesh):
    eng = ServeEngine(model, params, ServeConfig(
        max_seq=32, batch_slots=3, runtime="continuous", kv_layout="paged",
        prefill_chunk=4, mesh_shape=mesh))
    res = eng.generate(prompts, gens)
    assert eng.last_alloc.groups_in_use == 0, f"{mesh}: page leak"
    eng.last_alloc.check_balanced()
    return res

base = run(None)
tp, rep, grid = run((1, 2)), run((4, 1)), run((2, 2))
for name, r in (("tp 1x2", tp), ("replicas 4x1", rep), ("grid 2x2", grid)):
    assert r.tokens == base.tokens, f"{name}: tokens diverged"
assert tp.steps == base.steps, "pure TP changed the dispatch count"
assert rep.steps < base.steps, "replica widening cut no decode steps"
assert grid.steps < base.steps, "grid data axis cut no decode steps"
print(f"sharded smoke OK (tokens identical on 1x2/4x1/2x2; TP steps "
      f"{tp.steps}=={base.steps}, replicas {rep.steps}<{base.steps}, "
      "no leaks)")
EOF

echo "=== smoke: sharded joint tuning (--max-devices 8, mesh-keyed winner) ==="
# The widened serve subspace (mesh_devices / tp_vs_replicas / rules
# preset) through the real --joint path: the tuned winner must be
# deployable AND persist under its mesh topology key, never under the
# single-device key.
REPRO_AUTOTUNE_CACHE="$CI_TMP/autotune_sharded.json" timeout 90 \
    python -m repro.launch.tune --arch xlstm-350m --shape decode_32k \
    --joint --surrogate --budget 16 --max-devices 8 \
    --out-dir "$CI_TMP/tune_sharded" > /dev/null
python - "$CI_TMP/autotune_sharded.json" <<'EOF'
import json, re, sys

keys = [k for k in json.load(open(sys.argv[1]))
        if k.split("|")[1] == "serve_engine"]
assert keys, "no serve_engine winner persisted"
mesh_keys = [k for k in keys if re.search(r"\|d\d+m\d+$", k)]
assert mesh_keys, f"serve winner not mesh-keyed: {keys}"
print(f"sharded joint smoke OK (serve winner cached under "
      f"{mesh_keys[0].split('|')[-1]})")
EOF

echo "=== check: continuous+paged >= wave; on_demand >= reserve; shared >= 2x;"
echo "===        online retune >= 1.15x stale winner; sharded parity ==="
timeout 450 python -m benchmarks.serve_bench --check

echo "CI OK"
