"""repro: ACTS (Zhu et al., APSys'17) as a production multi-pod JAX framework.

Subpackages: core (the ACTS tuner/LHS/RRS), models, configs, dist, kernels,
optim, data, checkpoint, train, serve, launch, utils.
"""
__version__ = "1.0.0"
