"""Static analysis for the tuning stack: feasibility models + the repo lint.

Two halves, both *static* in the ACTS sense — they spend zero test budget:

* ``repro.analysis.feasibility`` — declarative per-space feasibility
  models.  The kernel predicates are the SAME functions the roofline cost
  models evaluate (VMEM tile footprint vs ``VMEM_BYTES``), so "statically
  infeasible" and "cost == inf" can never drift apart; the serve
  predicates encode the ``apply_serve_knobs`` deployability floor so the
  config the tuner scores is the config that deploys.  ``BudgetedRun``
  consumes these models to prune candidates *without charging budget*.
* ``repro.analysis.lint`` — a stdlib-``ast`` lint over the repo's own
  runtime invariants: jit retrace hazards, ``pallas_call`` contract
  arity, allocator acquire/release balance, plus interprocedural
  dataflow rules (PR 10) built on ``repro.analysis.dataflow``'s call
  graph + taint engine: determinism-taint into tuning decisions, jit
  trace-capture/host-effect, and cache lock-discipline.  ``python -m
  repro.analysis.lint --check src/repro`` is the CI gate.
"""
from .feasibility import (
    CompositeFeasibility,
    FeasibilityModel,
    Predicate,
    Violation,
    kernel_feasibility,
    serve_feasibility,
)

__all__ = [
    "Predicate",
    "Violation",
    "FeasibilityModel",
    "CompositeFeasibility",
    "kernel_feasibility",
    "serve_feasibility",
]
