"""Interprocedural dataflow layer under the repo lint (pure stdlib ``ast``).

Two pieces, both *resolve-or-skip* (PR 7's contract: an opaque callee is
skipped, never guessed — precision over recall, so the zero-findings
baseline on ``src/repro`` stays meaningful):

``Project`` / ``Resolver``
    A module-level call graph over an arbitrary fileset.  Modules are
    named by their package chain (``__init__.py`` walk); calls resolve
    through imports, local aliases, ``functools.partial``, conditional
    aliases (``IfExp`` whose branches agree), class construction
    (``C(...)`` → ``C.__init__``) and methods via receiver-type
    inference from parameter annotations, ``self`` attribute
    constructor-sites and return annotations.

``TaintAnalysis``
    A forward taint engine on that graph: labeled sources propagate
    through assignments, arithmetic, containers, returns and call
    arguments to labeled sinks.  Per-function summaries
    (param→return, return-sources, param→sink) are iterated to a
    fixpoint, so a source can reach a sink through any resolved chain
    of helpers.  Constructing a *metric boundary* type (``PerfMetric``,
    ``TuningReport``, …) launders taint by design: a timer flowing into
    a perf record is the accepted pattern; taint must reach a decision.

Everything here is deterministic by construction — modules, functions
and findings are iterated in sorted order — because the lint must
satisfy the invariant it checks.

The concrete rule families (determinism-taint, jit-trace-capture,
cache-lock-discipline) live in ``repro.analysis.lint``; this module
knows nothing about jax, schedulers or caches beyond what callers
register.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import (Any, Dict, FrozenSet, Iterable, List, Optional, Sequence,
                    Set, Tuple)

__all__ = [
    "Project", "ModuleInfo", "ClassInfo", "FunctionInfo", "Resolver",
    "CallTarget", "TaintSource", "SinkSpec", "TaintFinding", "TaintAnalysis",
    "build_project",
]

# resolution recursion fuel: deep enough for every real chain in the
# repo (alias → partial → alias → def), shallow enough that adversarial
# self-referential modules terminate instantly.
_MAX_DEPTH = 8
# fixpoint passes over all function summaries; call chains in this repo
# are < 5 frames deep, 12 leaves generous headroom.
_MAX_ITERS = 12


# --------------------------------------------------------------------------
# project index
# --------------------------------------------------------------------------

@dataclass
class FunctionInfo:
    """One ``def`` anywhere in the project (top-level, method or nested)."""

    name: str
    qname: str                      # "pkg.mod:Class.meth" / "pkg.mod:outer.<locals>.inner"
    node: Any                       # ast.FunctionDef | ast.AsyncFunctionDef
    module: "ModuleInfo"
    cls: Optional["ClassInfo"] = None
    parent: Optional["FunctionInfo"] = None  # lexically enclosing def
    # lazily built caches (Resolver owns their lifecycle)
    _local_env: Optional[Dict[str, Any]] = field(default=None, repr=False)

    def params(self) -> List[str]:
        """Positional + keyword-only parameter names, ``self``/``cls``
        included when present (index 0 for methods)."""
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        return names

    def annotation_for(self, pname: str) -> Optional[ast.AST]:
        a = self.node.args
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            if p.arg == pname:
                return p.annotation
        return None

    @property
    def is_method(self) -> bool:
        return self.cls is not None and self.parent is None


@dataclass
class ClassInfo:
    name: str
    qname: str
    node: ast.ClassDef
    module: "ModuleInfo"
    methods: Dict[str, FunctionInfo] = field(default_factory=dict)
    bases: List[str] = field(default_factory=list)      # dotted base names
    # self.<attr> -> annotation or value expr (from __init__ / class body)
    attr_types: Dict[str, ast.AST] = field(default_factory=dict)


@dataclass
class ModuleInfo:
    name: str                       # dotted module name ("repro.autotune.api")
    path: str
    tree: ast.Module
    functions: Dict[str, FunctionInfo] = field(default_factory=dict)
    classes: Dict[str, ClassInfo] = field(default_factory=dict)
    assigns: Dict[str, ast.AST] = field(default_factory=dict)
    # alias -> (module_name, symbol | None).  symbol None = the module object.
    imports: Dict[str, Tuple[str, Optional[str]]] = field(default_factory=dict)
    all_functions: List[FunctionInfo] = field(default_factory=list)

    @property
    def package(self) -> str:
        """The package this module's relative imports resolve against."""
        if self.path.endswith("__init__.py"):
            return self.name
        return self.name.rpartition(".")[0]


def _module_name(path: str) -> str:
    """Dotted module name from the ``__init__.py`` package chain.

    A file outside any package (no ``__init__.py`` beside it) is a
    standalone module named after its stem — this is how single-file
    fixture lints still get a working (intra-module) call graph.
    """
    import os

    path = os.path.abspath(path)
    parts = [os.path.splitext(os.path.basename(path))[0]]
    d = os.path.dirname(path)
    while os.path.isfile(os.path.join(d, "__init__.py")):
        parts.append(os.path.basename(d))
        nd = os.path.dirname(d)
        if nd == d:
            break
        d = nd
    parts.reverse()
    if parts[-1] == "__init__":
        parts.pop()
    return ".".join(parts) if parts else "__main__"


class _Indexer(ast.NodeVisitor):
    """Single pass that records defs, classes, imports and assigns."""

    def __init__(self, mod: ModuleInfo) -> None:
        self.mod = mod
        self.cls_stack: List[ClassInfo] = []
        self.fn_stack: List[FunctionInfo] = []

    # -- scoping helpers ---------------------------------------------------
    def _qname(self, name: str) -> str:
        bits: List[str] = []
        for f in self.fn_stack:
            bits.append(f.name + ".<locals>")
        if self.cls_stack and not self.fn_stack:
            bits.append(self.cls_stack[-1].name)
        bits.append(name)
        return f"{self.mod.name}:{'.'.join(bits)}"

    # -- defs --------------------------------------------------------------
    def _handle_def(self, node: Any) -> None:
        cls = self.cls_stack[-1] if (self.cls_stack and not self.fn_stack) else None
        fi = FunctionInfo(name=node.name, qname=self._qname(node.name),
                          node=node, module=self.mod, cls=cls,
                          parent=self.fn_stack[-1] if self.fn_stack else None)
        self.mod.all_functions.append(fi)
        if cls is not None:
            cls.methods[node.name] = fi
        elif not self.fn_stack and not self.cls_stack:
            self.mod.functions[node.name] = fi
        self.fn_stack.append(fi)
        for child in node.body:
            self.visit(child)
        self.fn_stack.pop()

    visit_FunctionDef = _handle_def
    visit_AsyncFunctionDef = _handle_def

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        if self.fn_stack or self.cls_stack:
            # nested classes: indexed shallowly enough to resolve-or-skip
            for child in node.body:
                self.visit(child)
            return
        ci = ClassInfo(name=node.name, qname=self._qname(node.name),
                       node=node, module=self.mod,
                       bases=[d for d in map(_dotted, node.bases) if d])
        self.mod.classes[node.name] = ci
        self.cls_stack.append(ci)
        for child in node.body:
            if isinstance(child, ast.AnnAssign) and isinstance(child.target, ast.Name):
                ci.attr_types.setdefault(child.target.id,
                                         child.annotation or child.value)
            self.visit(child)
        self.cls_stack.pop()
        # mine __init__ for `self.x = EXPR` constructor-sites
        init = ci.methods.get("__init__")
        if init is not None:
            for stmt in ast.walk(init.node):
                tgt = None
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                    tgt, val = stmt.targets[0], stmt.value
                elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                    tgt, val = stmt.target, (stmt.annotation or stmt.value)
                else:
                    continue
                if (isinstance(tgt, ast.Attribute)
                        and isinstance(tgt.value, ast.Name)
                        and tgt.value.id == "self"):
                    ci.attr_types.setdefault(tgt.attr, val)

    # -- imports -----------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        if self.fn_stack or self.cls_stack:
            return
        for alias in node.names:
            if alias.asname:
                self.mod.imports[alias.asname] = (alias.name, None)
            else:
                root = alias.name.split(".", 1)[0]
                self.mod.imports[root] = (root, None)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if self.fn_stack or self.cls_stack:
            return
        if node.level:
            base = self.mod.package
            for _ in range(node.level - 1):
                base = base.rpartition(".")[0]
            target = f"{base}.{node.module}" if node.module else base
        else:
            target = node.module or ""
        if not target:
            return
        for alias in node.names:
            if alias.name == "*":
                continue
            self.mod.imports[alias.asname or alias.name] = (target, alias.name)

    # -- module assigns ----------------------------------------------------
    def visit_Assign(self, node: ast.Assign) -> None:
        if not self.fn_stack and not self.cls_stack:
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self.mod.assigns[tgt.id] = node.value

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if (not self.fn_stack and not self.cls_stack
                and isinstance(node.target, ast.Name) and node.value is not None):
            self.mod.assigns[node.target.id] = node.value


@dataclass
class Project:
    """An indexed fileset: dotted-name → module, plus lookup helpers."""

    modules: Dict[str, ModuleInfo] = field(default_factory=dict)
    by_path: Dict[str, ModuleInfo] = field(default_factory=dict)

    def sorted_modules(self) -> List[ModuleInfo]:
        return [self.modules[k] for k in sorted(self.modules)]

    def sorted_functions(self) -> List[FunctionInfo]:
        out: List[FunctionInfo] = []
        for mod in self.sorted_modules():
            out.extend(sorted(mod.all_functions, key=lambda f: f.qname))
        return out

    # -- symbol lookup -----------------------------------------------------
    def module_symbol(self, mod: ModuleInfo, name: str,
                      depth: int = _MAX_DEPTH) -> Optional[Tuple[str, Any]]:
        """Resolve a module-scope name to ("func"|"class"|"module"|"assign", obj).

        Follows re-export chains (``from .api import x`` inside an
        ``__init__``) up to the depth budget.  None = opaque.
        """
        if depth <= 0:
            return None
        if name in mod.functions:
            return ("func", mod.functions[name])
        if name in mod.classes:
            return ("class", mod.classes[name])
        if name in mod.imports:
            target, symbol = mod.imports[name]
            if symbol is None:
                sub = self.modules.get(target)
                return ("module", sub) if sub is not None else None
            submod = self.modules.get(f"{target}.{symbol}")
            if submod is not None:
                return ("module", submod)
            tmod = self.modules.get(target)
            if tmod is None:
                return None
            return self.module_symbol(tmod, symbol, depth - 1)
        if name in mod.assigns:
            return ("assign", mod.assigns[name])
        return None

    def resolve_class_named(self, mod: ModuleInfo, dotted: str,
                            depth: int = _MAX_DEPTH) -> Optional[ClassInfo]:
        """Resolve a (possibly dotted) class name as seen from ``mod``."""
        if depth <= 0 or not dotted:
            return None
        head, _, rest = dotted.partition(".")
        got = self.module_symbol(mod, head, depth)
        while got is not None and rest:
            kind, obj = got
            if kind != "module":
                return None
            head, _, rest = rest.partition(".")
            got = self.module_symbol(obj, head, depth - 1)
        if got is None:
            return None
        kind, obj = got
        return obj if kind == "class" else None

    def class_method(self, ci: ClassInfo, name: str,
                     depth: int = _MAX_DEPTH) -> Optional[FunctionInfo]:
        """Method lookup through project-resolvable bases (MRO-ish)."""
        if depth <= 0:
            return None
        if name in ci.methods:
            return ci.methods[name]
        for base in ci.bases:
            bci = self.resolve_class_named(ci.module, base, depth - 1)
            if bci is not None and bci is not ci:
                m = self.class_method(bci, name, depth - 1)
                if m is not None:
                    return m
        return None


def build_project(files: Sequence[str]) -> Project:
    """Parse + index a fileset.  Unparseable files are skipped (the
    per-file lint reports those as ``syntax-error`` already)."""
    proj = Project()
    for path in sorted(str(p) for p in files):
        try:
            with open(path, "r", encoding="utf-8") as fh:
                tree = ast.parse(fh.read())
        except (OSError, SyntaxError, ValueError):
            continue
        mod = ModuleInfo(name=_module_name(path), path=path, tree=tree)
        _Indexer(mod).visit(tree)
        # duplicate dotted names (two loose files both named "fixture")
        # keep the first, sorted order makes the winner deterministic
        proj.modules.setdefault(mod.name, mod)
        proj.by_path[path] = mod
    return proj


# --------------------------------------------------------------------------
# shared AST helpers (duplicated shape-wise with lint.py on purpose:
# dataflow must stay importable standalone)
# --------------------------------------------------------------------------

def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _last(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _unwrap_annotation(node: Optional[ast.AST]) -> Optional[ast.AST]:
    """Strip Optional[...]/Union[..., None]/string quoting to the payload."""
    if node is None:
        return None
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        try:
            return _unwrap_annotation(ast.parse(node.value, mode="eval").body)
        except SyntaxError:
            return None
    if isinstance(node, ast.Subscript):
        head = _last(node.value)
        if head in ("Optional", "Union"):
            inner = node.slice
            elts = inner.elts if isinstance(inner, ast.Tuple) else [inner]
            payload = [e for e in elts
                       if not (isinstance(e, ast.Constant) and e.value is None)]
            if len(payload) == 1:
                return _unwrap_annotation(payload[0])
            return None
    return node


# --------------------------------------------------------------------------
# resolver
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class CallTarget:
    """A resolved callee: the def plus how many leading positional
    params / which keywords are pre-bound (self-binding, partial)."""

    fn: FunctionInfo
    bound_pos: int = 0
    bound_kw: FrozenSet[str] = frozenset()


class Resolver:
    """Resolve-or-skip name/receiver resolution over a :class:`Project`."""

    def __init__(self, project: Project) -> None:
        self.project = project

    # -- per-function local environments ----------------------------------
    def local_env(self, fi: FunctionInfo) -> Dict[str, Any]:
        """name -> value-expr | FunctionInfo (nested def) for simple
        module-of-truth assignments inside ``fi`` (nested def bodies are
        opaque to the enclosing scope)."""
        if fi._local_env is not None:
            return fi._local_env
        env: Dict[str, Any] = {}

        def scan(stmts: Iterable[ast.stmt]) -> None:
            for stmt in stmts:
                if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    for cand in fi.module.all_functions:
                        if cand.node is stmt:
                            env[stmt.name] = cand
                            break
                    continue
                if isinstance(stmt, ast.ClassDef):
                    continue
                if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                        and isinstance(stmt.targets[0], ast.Name):
                    name = stmt.targets[0].id
                    # reassignment = ambiguous -> opaque (resolve-or-skip)
                    env[name] = stmt.value if name not in env else None
                elif isinstance(stmt, ast.AnnAssign) \
                        and isinstance(stmt.target, ast.Name) \
                        and stmt.value is not None:
                    env[stmt.target.id] = stmt.value
                scan([s for s in ast.iter_child_nodes(stmt)
                      if isinstance(s, ast.stmt)])

        scan(fi.node.body)
        fi._local_env = env
        return env

    # -- callable resolution ----------------------------------------------
    def resolve_call(self, call: ast.Call,
                     ctx: Optional[FunctionInfo],
                     mod: Optional[ModuleInfo] = None,
                     depth: int = _MAX_DEPTH) -> Optional[CallTarget]:
        mod = mod or (ctx.module if ctx is not None else None)
        if mod is None:
            return None
        return self.resolve_callable(call.func, ctx, mod, depth)

    def resolve_callable(self, expr: ast.AST, ctx: Optional[FunctionInfo],
                         mod: ModuleInfo,
                         depth: int = _MAX_DEPTH) -> Optional[CallTarget]:
        if depth <= 0:
            return None
        if isinstance(expr, ast.Name):
            return self._resolve_name(expr.id, ctx, mod, depth)
        if isinstance(expr, ast.Attribute):
            return self._resolve_attribute(expr, ctx, mod, depth)
        if isinstance(expr, ast.Call):
            # functools.partial(f, ...) used as a callable expression
            return self._resolve_partial(expr, ctx, mod, depth)
        if isinstance(expr, ast.IfExp):
            a = self.resolve_callable(expr.body, ctx, mod, depth - 1)
            b = self.resolve_callable(expr.orelse, ctx, mod, depth - 1)
            if a is not None and b is not None and a == b:
                return a
            return None
        return None

    def _resolve_name(self, name: str, ctx: Optional[FunctionInfo],
                      mod: ModuleInfo, depth: int) -> Optional[CallTarget]:
        frame = ctx
        while frame is not None:
            if name in frame.params():
                return None  # opaque: a parameter shadows everything
            env = self.local_env(frame)
            if name in env:
                val = env[name]
                if isinstance(val, FunctionInfo):
                    return CallTarget(val)
                if val is None:
                    return None
                return self._resolve_value(val, frame, mod, depth - 1)
            frame = frame.parent
        got = self.project.module_symbol(mod, name, depth)
        if got is None:
            return None
        kind, obj = got
        if kind == "func":
            return CallTarget(obj)
        if kind == "class":
            init = self.project.class_method(obj, "__init__", depth - 1)
            if init is not None:
                return CallTarget(init, bound_pos=1)
            return None
        if kind == "assign":
            return self._resolve_value(obj, None, mod, depth - 1)
        return None

    def _resolve_attribute(self, expr: ast.Attribute,
                           ctx: Optional[FunctionInfo], mod: ModuleInfo,
                           depth: int) -> Optional[CallTarget]:
        base = expr.value
        # module attribute: autotune.ensure_tuned(...)
        bmod = self.resolve_module(base, ctx, mod, depth - 1)
        if bmod is not None:
            got = self.project.module_symbol(bmod, expr.attr, depth - 1)
            if got is None:
                return None
            kind, obj = got
            if kind == "func":
                return CallTarget(obj)
            if kind == "class":
                init = self.project.class_method(obj, "__init__", depth - 1)
                return CallTarget(init, bound_pos=1) if init else None
            return None
        # class-attribute access: SlotScheduler.select_victim(...)
        dotted_base = _dotted(base)
        if dotted_base is not None and not self._is_shadowed(
                dotted_base.split(".")[0], ctx):
            ci = self.project.resolve_class_named(mod, dotted_base,
                                                  depth - 1)
            if ci is not None:
                meth = self.project.class_method(ci, expr.attr, depth - 1)
                if meth is not None:
                    decos = {_last(d) for d in meth.node.decorator_list}
                    # classmethods bind cls; static/instance methods
                    # accessed through the class bind nothing
                    bound = 1 if "classmethod" in decos else 0
                    return CallTarget(meth, bound_pos=bound)
        # method on an inferred receiver type: self-binding consumes
        # the leading positional param
        ci = self.infer_type(base, ctx, mod, depth - 1)
        if ci is not None:
            meth = self.project.class_method(ci, expr.attr, depth - 1)
            if meth is not None:
                is_static = any(_last(d) == "staticmethod"
                                for d in meth.node.decorator_list)
                return CallTarget(meth, bound_pos=0 if is_static else 1)
        return None

    def _is_shadowed(self, name: str, ctx: Optional[FunctionInfo]) -> bool:
        frame = ctx
        while frame is not None:
            if name in frame.params() or name in self.local_env(frame):
                return True
            frame = frame.parent
        return False

    def resolve_module(self, expr: ast.AST, ctx: Optional[FunctionInfo],
                       mod: ModuleInfo, depth: int) -> Optional[ModuleInfo]:
        if depth <= 0:
            return None
        if isinstance(expr, ast.Name):
            frame = ctx
            while frame is not None:
                if expr.id in frame.params() or expr.id in self.local_env(frame):
                    return None
                frame = frame.parent
            got = self.project.module_symbol(mod, expr.id, depth)
            if got is not None and got[0] == "module":
                return got[1]
            return None
        if isinstance(expr, ast.Attribute):
            parent = self.resolve_module(expr.value, ctx, mod, depth - 1)
            if parent is None:
                return None
            got = self.project.module_symbol(parent, expr.attr, depth - 1)
            if got is not None and got[0] == "module":
                return got[1]
            return None
        return None

    def _resolve_value(self, val: ast.AST, ctx: Optional[FunctionInfo],
                       mod: ModuleInfo, depth: int) -> Optional[CallTarget]:
        if depth <= 0 or val is None:
            return None
        if isinstance(val, (ast.Name, ast.Attribute, ast.IfExp)):
            return self.resolve_callable(val, ctx, mod, depth)
        if isinstance(val, ast.Call):
            return self._resolve_partial(val, ctx, mod, depth)
        return None

    def _resolve_partial(self, call: ast.Call, ctx: Optional[FunctionInfo],
                         mod: ModuleInfo, depth: int) -> Optional[CallTarget]:
        if _last(call.func) != "partial" or not call.args:
            return None
        inner = self.resolve_callable(call.args[0], ctx, mod, depth - 1)
        if inner is None:
            return None
        return CallTarget(inner.fn,
                          bound_pos=inner.bound_pos + len(call.args) - 1,
                          bound_kw=inner.bound_kw
                          | frozenset(k.arg for k in call.keywords if k.arg))

    # -- receiver type inference ------------------------------------------
    def infer_type(self, expr: ast.AST, ctx: Optional[FunctionInfo],
                   mod: ModuleInfo, depth: int = _MAX_DEPTH
                   ) -> Optional[ClassInfo]:
        if depth <= 0:
            return None
        if isinstance(expr, ast.Name):
            if expr.id in ("self", "cls") and ctx is not None:
                frame = ctx
                while frame is not None and frame.cls is None:
                    frame = frame.parent
                return frame.cls if frame is not None else None
            frame = ctx
            while frame is not None:
                ann = frame.annotation_for(expr.id)
                if ann is not None:
                    return self._class_from_annotation(ann, frame.module, depth)
                if expr.id in frame.params():
                    return None
                env = self.local_env(frame)
                if expr.id in env:
                    val = env[expr.id]
                    if val is None or isinstance(val, FunctionInfo):
                        return None
                    return self._infer_value_type(val, frame, mod, depth - 1)
                frame = frame.parent
            got = self.project.module_symbol(mod, expr.id, depth)
            if got is not None and got[0] == "assign":
                return self._infer_value_type(got[1], None, mod, depth - 1)
            return None
        if isinstance(expr, ast.Attribute):
            if isinstance(expr.value, ast.Name) and expr.value.id == "self" \
                    and ctx is not None:
                frame = ctx
                while frame is not None and frame.cls is None:
                    frame = frame.parent
                if frame is not None and frame.cls is not None:
                    hint = frame.cls.attr_types.get(expr.attr)
                    if hint is not None:
                        ci = self._class_from_annotation(hint, frame.cls.module,
                                                         depth)
                        if ci is not None:
                            return ci
                        return self._infer_value_type(hint, frame, mod,
                                                      depth - 1)
            return None
        if isinstance(expr, ast.Call):
            return self._infer_value_type(expr, ctx, mod, depth)
        if isinstance(expr, ast.IfExp):
            a = self.infer_type(expr.body, ctx, mod, depth - 1)
            b = self.infer_type(expr.orelse, ctx, mod, depth - 1)
            if a is not None and a is b:
                return a
            # the repo's `x = default() if x is None else x` pattern:
            # one branch is the annotated param itself
            return a or b if (a is None) != (b is None) else None
        return None

    def _infer_value_type(self, val: ast.AST, ctx: Optional[FunctionInfo],
                          mod: ModuleInfo, depth: int) -> Optional[ClassInfo]:
        if depth <= 0:
            return None
        if isinstance(val, ast.Call):
            # class construction — works for dataclasses too, where no
            # explicit __init__ def exists to resolve
            if isinstance(val.func, (ast.Name, ast.Attribute)):
                dotted = _dotted(val.func)
                if dotted and not self._is_shadowed(dotted.split(".")[0],
                                                    ctx):
                    ci = self.project.resolve_class_named(mod, dotted,
                                                          depth - 1)
                    if ci is not None:
                        return ci
            tgt = self.resolve_callable(val.func, ctx, mod, depth - 1)
            if tgt is not None:
                if tgt.fn.name == "__init__" and tgt.fn.cls is not None:
                    return tgt.fn.cls
                ret = _unwrap_annotation(tgt.fn.node.returns)
                if ret is not None:
                    return self._class_from_annotation(ret, tgt.fn.module,
                                                       depth - 1)
            return None
        if isinstance(val, (ast.Name, ast.Attribute, ast.IfExp)):
            return self.infer_type(val, ctx, mod, depth - 1)
        return None

    def _class_from_annotation(self, ann: ast.AST, mod: ModuleInfo,
                               depth: int) -> Optional[ClassInfo]:
        ann = _unwrap_annotation(ann)
        if ann is None:
            return None
        dotted = _dotted(ann)
        if dotted is None:
            return None
        return self.project.resolve_class_named(mod, dotted, depth)

    # -- call graph --------------------------------------------------------
    def call_sites(self, fi: FunctionInfo) -> List[Tuple[ast.Call, Optional[CallTarget]]]:
        """Every call lexically in ``fi`` (nested def bodies excluded),
        with its resolution (or None)."""
        out: List[Tuple[ast.Call, Optional[CallTarget]]] = []
        for call in _own_nodes(fi.node, ast.Call):
            out.append((call, self.resolve_call(call, fi)))
        return out

    def call_graph(self) -> Dict[str, List[str]]:
        """qname -> sorted unique callee qnames, resolved edges only."""
        graph: Dict[str, List[str]] = {}
        for fi in self.project.sorted_functions():
            edges = {t.fn.qname for _, t in self.call_sites(fi) if t is not None}
            graph[fi.qname] = sorted(edges)
        return graph


def _own_nodes(fn_node: Any, kind: Any) -> List[Any]:
    """ast.walk restricted to ``fn_node``'s own body (nested defs opaque)."""
    out: List[Any] = []
    stack: List[ast.AST] = list(fn_node.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if isinstance(node, kind):
            out.append(node)
        stack.extend(ast.iter_child_nodes(node))
    out.sort(key=lambda n: (getattr(n, "lineno", 0), getattr(n, "col_offset", 0)))
    return out


# --------------------------------------------------------------------------
# taint engine
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class TaintSource:
    kind: str           # "wall-clock" | "global-rng" | "os-entropy" | "set-order"
    desc: str           # human-readable, e.g. "time.time()"
    path: str
    line: int


@dataclass(frozen=True)
class SinkSpec:
    """A labeled sink: calls whose listed parameters must stay clean.

    ``name``
        last attribute/name segment the call must match.
    ``category``
        finding taxonomy bucket (scheduler-decision, retune-trigger, ...).
    ``params``
        parameter names that are sinks; None = every argument.
    ``qname_suffix``
        when set, the call must RESOLVE to a def whose qname ends with
        this — generic names (``put``, ``key``) only sink on the real
        target.  When None the bare name is distinctive enough to match
        unresolved calls too.
    ``decision``
        the sink is a control-flow decision: reaching it *under a
        tainted branch condition* is a finding even with clean args.
    """

    name: str
    category: str
    params: Optional[FrozenSet[str]] = None
    qname_suffix: Optional[str] = None
    decision: bool = False


@dataclass(frozen=True)
class TaintFinding:
    path: str
    line: int
    col: int
    message: str


# abstract taint values: ("src", TaintSource) | ("param", index)
_Taint = Tuple[str, Any]


@dataclass
class _Summary:
    ret_params: Set[int] = field(default_factory=set)
    ret_sources: Set[TaintSource] = field(default_factory=set)
    # param index -> {(category, sink name, via-description)}
    param_sinks: Dict[int, Set[Tuple[str, str, str]]] = field(default_factory=dict)

    def snapshot(self) -> Tuple:
        return (frozenset(self.ret_params), frozenset(self.ret_sources),
                frozenset((k, frozenset(v)) for k, v in self.param_sinks.items()))


# builtins that are order-insensitive reductions: consuming a set through
# them does NOT leak iteration order
_ORDER_SANITIZERS = frozenset({"sorted", "len", "sum", "min", "max", "any",
                               "all", "frozenset", "set"})
# builtins that materialize iteration order: set in, order-leak out
_ORDER_CARRIERS = frozenset({"list", "tuple", "iter", "enumerate", "next",
                             "reversed", "join", "map", "filter", "zip"})
_SET_CTORS = frozenset({"set", "frozenset"})


class TaintAnalysis:
    """Forward taint with per-function summaries to a fixpoint.

    ``classify_source(call, target) -> Optional[TaintSource]`` labels
    source calls; ``sinks`` maps a last-segment name to its SinkSpecs;
    ``boundaries`` is the set of metric-record type names whose
    construction launders taint.
    """

    def __init__(self, project: Project, resolver: Resolver,
                 classify_source: Any, sinks: Dict[str, List[SinkSpec]],
                 boundaries: FrozenSet[str]) -> None:
        self.project = project
        self.resolver = resolver
        self.classify_source = classify_source
        self.sinks = sinks
        self.boundaries = boundaries
        self.summaries: Dict[str, _Summary] = {}
        self.findings: List[TaintFinding] = []

    # -- driver ------------------------------------------------------------
    def run(self) -> List[TaintFinding]:
        funcs = self.project.sorted_functions()
        for fi in funcs:
            self.summaries[fi.qname] = _Summary()
        for _ in range(_MAX_ITERS):
            changed = False
            for fi in funcs:
                before = self.summaries[fi.qname].snapshot()
                _FunctionPass(self, fi, report=False).run()
                if self.summaries[fi.qname].snapshot() != before:
                    changed = True
            if not changed:
                break
        # reporting pass with stable summaries
        seen: Set[Tuple] = set()
        for fi in funcs:
            fpass = _FunctionPass(self, fi, report=True)
            fpass.run()
            for f in fpass.findings:
                key = (f.path, f.line, f.col, f.message)
                if key not in seen:
                    seen.add(key)
                    self.findings.append(f)
        self.findings.sort(key=lambda f: (f.path, f.line, f.col, f.message))
        return self.findings

    # param index for a callee, self excluded for bound calls
    @staticmethod
    def effective_params(target: CallTarget) -> List[str]:
        return target.fn.params()[target.bound_pos:]


class _FunctionPass:
    """One flow-sensitive forward pass over a function body."""

    def __init__(self, analysis: TaintAnalysis, fi: FunctionInfo,
                 report: bool) -> None:
        self.a = analysis
        self.fi = fi
        self.report = report
        self.summary = analysis.summaries[fi.qname]
        self.env: Dict[str, Set[_Taint]] = {}
        self.set_typed: Set[str] = set()
        self.cond_stack: List[Set[_Taint]] = []
        self.findings: List[TaintFinding] = []
        params = fi.params()
        skip_self = 1 if (fi.is_method and params and params[0] in ("self", "cls")) else 0
        self.param_index = {p: i for i, p in enumerate(params[skip_self:])}
        self.self_name = params[0] if skip_self else None

    def run(self) -> None:
        self.visit_block(self.fi.node.body)

    # -- statements --------------------------------------------------------
    def visit_block(self, stmts: Iterable[ast.stmt]) -> None:
        for stmt in stmts:
            self.visit_stmt(stmt)

    def visit_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return
        if isinstance(stmt, ast.Assign):
            t = self.eval(stmt.value)
            is_set = self._is_set_expr(stmt.value)
            for tgt in stmt.targets:
                self.assign(tgt, t, is_set)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self.assign(stmt.target, self.eval(stmt.value),
                            self._is_set_expr(stmt.value))
        elif isinstance(stmt, ast.AugAssign):
            t = self.eval(stmt.value) | self.eval(stmt.target)
            self.assign(stmt.target, t, False)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                for kind, payload in self.eval(stmt.value):
                    if kind == "src":
                        self.summary.ret_sources.add(payload)
                    else:
                        self.summary.ret_params.add(payload)
        elif isinstance(stmt, ast.Expr):
            self.eval(stmt.value)
        elif isinstance(stmt, (ast.If,)):
            t = self.eval(stmt.test)
            self.cond_stack.append(t)
            self.visit_block(stmt.body)
            self.visit_block(stmt.orelse)
            self.cond_stack.pop()
        elif isinstance(stmt, ast.While):
            t = self.eval(stmt.test)
            self.cond_stack.append(t)
            for _ in range(2):  # two passes: propagate through the back edge
                self.visit_block(stmt.body)
            self.visit_block(stmt.orelse)
            self.cond_stack.pop()
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            it = self.eval(stmt.iter)
            if self._is_set_expr(stmt.iter):
                it = it | {("src", TaintSource(
                    "set-order", "set iteration order", self.fi.module.path,
                    stmt.iter.lineno))}
            for _ in range(2):
                self.assign(stmt.target, it, False)
                self.visit_block(stmt.body)
            self.visit_block(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                t = self.eval(item.context_expr)
                if item.optional_vars is not None:
                    self.assign(item.optional_vars, t, False)
            self.visit_block(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.visit_block(stmt.body)
            for h in stmt.handlers:
                self.visit_block(h.body)
            self.visit_block(stmt.orelse)
            self.visit_block(stmt.finalbody)
        elif isinstance(stmt, (ast.Assert,)):
            self.eval(stmt.test)
        elif isinstance(stmt, ast.Raise):
            if stmt.exc is not None:
                self.eval(stmt.exc)
        elif isinstance(stmt, ast.Delete):
            for tgt in stmt.targets:
                self.eval(tgt)
        # Pass/Break/Continue/Global/Nonlocal/Import: no taint flow

    def assign(self, tgt: ast.AST, taints: Set[_Taint], is_set: bool) -> None:
        if isinstance(tgt, ast.Name):
            self.env[tgt.id] = set(taints)
            if is_set:
                self.set_typed.add(tgt.id)
            else:
                self.set_typed.discard(tgt.id)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for e in tgt.elts:
                self.assign(e, taints, False)
        elif isinstance(tgt, ast.Starred):
            self.assign(tgt.value, taints, False)
        elif isinstance(tgt, (ast.Attribute, ast.Subscript)):
            root = tgt
            while isinstance(root, (ast.Attribute, ast.Subscript)):
                root = root.value
            if isinstance(root, ast.Name) and taints:
                # field-insensitive: storing taint into obj.x taints obj —
                # except `self`, whose cross-method state the per-function
                # summaries deliberately do not model
                if root.id != self.self_name:
                    self.env[root.id] = self.env.get(root.id, set()) | taints

    # -- expressions -------------------------------------------------------
    def eval(self, e: Optional[ast.AST]) -> Set[_Taint]:
        if e is None:
            return set()
        if isinstance(e, ast.Name):
            if e.id in self.env:
                return set(self.env[e.id])
            if e.id in self.param_index:
                return {("param", self.param_index[e.id])}
            return self._module_level_taint(e.id)
        if isinstance(e, ast.Constant):
            return set()
        if isinstance(e, ast.Call):
            return self.eval_call(e)
        if isinstance(e, ast.Attribute):
            return self.eval(e.value)
        if isinstance(e, ast.Subscript):
            return self.eval(e.value) | self.eval(e.slice)
        if isinstance(e, ast.BinOp):
            return self.eval(e.left) | self.eval(e.right)
        if isinstance(e, ast.BoolOp):
            out: Set[_Taint] = set()
            for v in e.values:
                out |= self.eval(v)
            return out
        if isinstance(e, ast.UnaryOp):
            return self.eval(e.operand)
        if isinstance(e, ast.Compare):
            out = self.eval(e.left)
            for c in e.comparators:
                out |= self.eval(c)
            return out
        if isinstance(e, ast.IfExp):
            return self.eval(e.body) | self.eval(e.orelse)
        if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            out = set()
            for v in e.elts:
                out |= self.eval(v)
            return out
        if isinstance(e, ast.Dict):
            out = set()
            for k in e.keys:
                out |= self.eval(k)
            for v in e.values:
                out |= self.eval(v)
            return out
        if isinstance(e, ast.JoinedStr):
            out = set()
            for v in e.values:
                out |= self.eval(v)
            return out
        if isinstance(e, ast.FormattedValue):
            return self.eval(e.value)
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                          ast.DictComp)):
            out = set()
            for gen in e.generators:
                out |= self.eval(gen.iter)
                if self._is_set_expr(gen.iter):
                    out.add(("src", TaintSource(
                        "set-order", "set iteration order",
                        self.fi.module.path, gen.iter.lineno)))
            if isinstance(e, ast.DictComp):
                out |= self.eval(e.key) | self.eval(e.value)
            else:
                out |= self.eval(e.elt)
            return out
        if isinstance(e, ast.Starred):
            return self.eval(e.value)
        if isinstance(e, ast.Await):
            return self.eval(e.value)
        if isinstance(e, ast.Lambda):
            return set()
        if isinstance(e, ast.Slice):
            return self.eval(e.lower) | self.eval(e.upper) | self.eval(e.step)
        if isinstance(e, ast.NamedExpr):
            t = self.eval(e.value)
            self.assign(e.target, t, self._is_set_expr(e.value))
            return t
        return set()

    def _module_level_taint(self, name: str) -> Set[_Taint]:
        """A free name bound at module scope to a source expression
        (``T0 = time.time()`` read inside a function)."""
        val = self.fi.module.assigns.get(name)
        if isinstance(val, ast.Call):
            tgt = self.a.resolver.resolve_call(val, None, self.fi.module)
            src = self.a.classify_source(val, tgt, self.fi.module.path)
            if src is not None:
                return {("src", src)}
        return set()

    # -- calls -------------------------------------------------------------
    def eval_call(self, call: ast.Call) -> Set[_Taint]:
        fname = _last(call.func)
        arg_taints = [self.eval(a) for a in call.args]
        kw_taints = {k.arg: self.eval(k.value) for k in call.keywords}
        all_args: Set[_Taint] = set()
        for t in arg_taints:
            all_args |= t
        for t in kw_taints.values():
            all_args |= t

        target = self.a.resolver.resolve_call(call, self.fi)

        # 1. source?
        src = self.a.classify_source(call, target, self.fi.module.path)
        if src is not None:
            return all_args | {("src", src)}

        # 2. metric boundary: constructing a perf record absorbs taint
        if fname in self.a.boundaries or (
                target is not None and target.fn.cls is not None
                and target.fn.name == "__init__"
                and target.fn.cls.name in self.a.boundaries):
            return set()

        # 3. set-order mechanics
        if fname in _ORDER_SANITIZERS:
            return {t for t in all_args
                    if not (t[0] == "src" and t[1].kind == "set-order")}
        if fname == "pop" and isinstance(call.func, ast.Attribute) \
                and self._is_set_expr(call.func.value):
            return all_args | {("src", TaintSource(
                "set-order", "set.pop() (arbitrary element)",
                self.fi.module.path, call.lineno))}
        if fname in _ORDER_CARRIERS and call.args \
                and self._is_set_expr(call.args[0]):
            return all_args | {("src", TaintSource(
                "set-order", "set iteration order",
                self.fi.module.path, call.args[0].lineno))}

        # 4. sink check
        self._check_sinks(call, fname, target, arg_taints, kw_taints)

        # 5. propagate through the callee summary (or pass-through)
        recv_taint: Set[_Taint] = set()
        if isinstance(call.func, ast.Attribute):
            recv_taint = self.eval(call.func.value)
        if target is not None:
            summ = self.a.summaries.get(target.fn.qname)
            if summ is not None:
                out: Set[_Taint] = set()
                out |= {("src", s) for s in summ.ret_sources}
                mapping = self._map_args(call, target, arg_taints, kw_taints)
                if mapping is not None:
                    for idx in summ.ret_params:
                        out |= mapping.get(idx, set())
                else:
                    if summ.ret_params:
                        out |= all_args | recv_taint
                return out
        # opaque callee: conservative pass-through of argument +
        # receiver taint (str(t), math.floor(t), t.total_seconds(), ...)
        return all_args | recv_taint

    def _map_args(self, call: ast.Call, target: CallTarget,
                  arg_taints: List[Set[_Taint]],
                  kw_taints: Dict[Optional[str], Set[_Taint]]
                  ) -> Optional[Dict[int, Set[_Taint]]]:
        """Call-site arg taints keyed by callee param index (self-relative).
        None when *args/**kwargs make the mapping ambiguous."""
        if any(isinstance(a, ast.Starred) for a in call.args) \
                or any(k.arg is None for k in call.keywords):
            return None
        callee = target.fn
        params = callee.params()
        skip = 1 if (callee.is_method and params
                     and params[0] in ("self", "cls")
                     and target.bound_pos >= 1) else 0
        eff = params[skip:]
        # positional slots consumed by partial-style pre-binding
        pre = target.bound_pos - skip
        if pre < 0 or pre > len(eff):
            return None
        out: Dict[int, Set[_Taint]] = {}
        for i, t in enumerate(arg_taints):
            slot = pre + i
            if slot >= len(eff):
                return None  # swallowed by *args — ambiguous
            out[slot] = t
        name_to_idx = {p: i for i, p in enumerate(eff)}
        for kname, t in kw_taints.items():
            if kname is None:
                return None
            if kname in name_to_idx:
                out[name_to_idx[kname]] = t
            # unknown kw swallowed by **kw: drop (no param to bind)
        return out

    def _check_sinks(self, call: ast.Call, fname: Optional[str],
                     target: Optional[CallTarget],
                     arg_taints: List[Set[_Taint]],
                     kw_taints: Dict[Optional[str], Set[_Taint]]) -> None:
        if fname is None:
            return
        specs = self.a.sinks.get(fname)
        direct_specs: List[SinkSpec] = []
        if specs:
            for spec in specs:
                if spec.qname_suffix is not None:
                    if target is None or \
                            not target.fn.qname.endswith(spec.qname_suffix):
                        continue
                direct_specs.append(spec)
        if not direct_specs:
            # summary-carried sinks: tainted arg into a helper whose
            # param eventually reaches a sink
            self._check_summary_sinks(call, target, arg_taints, kw_taints)
            return
        for spec in direct_specs:
            self._apply_spec(call, spec, target, arg_taints, kw_taints)
        self._check_summary_sinks(call, target, arg_taints, kw_taints)

    def _apply_spec(self, call: ast.Call, spec: SinkSpec,
                    target: Optional[CallTarget],
                    arg_taints: List[Set[_Taint]],
                    kw_taints: Dict[Optional[str], Set[_Taint]]) -> None:
        # which argument expressions are sink-relevant?
        checked: List[Tuple[str, Set[_Taint]]] = []
        if spec.params is None:
            for i, t in enumerate(arg_taints):
                checked.append((f"arg{i}", t))
            for k, t in kw_taints.items():
                checked.append((k or "**", t))
        else:
            if target is not None:
                mapping = self._map_args(call, target, arg_taints, kw_taints)
                eff = TaintAnalysis.effective_params(target)
                if mapping is not None:
                    for idx, t in mapping.items():
                        if idx < len(eff) and eff[idx] in spec.params:
                            checked.append((eff[idx], t))
            else:
                # unresolved + param-filtered: positional mapping unknown,
                # keywords still name their params
                for k, t in kw_taints.items():
                    if k in spec.params:
                        checked.append((k, t))
        for pname, taints in checked:
            for kind, payload in taints:
                if kind == "src":
                    self._emit(call, spec, payload, pname)
                else:  # param taint -> callee summary, caller re-checks
                    self.summary.param_sinks.setdefault(payload, set()).add(
                        (spec.category, spec.name,
                         f"argument {pname!r} of {spec.name}()"))
        # control-dependence: a *decision* sink fired under a tainted branch
        if spec.decision:
            for cond in self.cond_stack:
                for kind, payload in cond:
                    if kind == "src":
                        self._emit(call, spec, payload, None, controls=True)
                    else:
                        self.summary.param_sinks.setdefault(payload, set()).add(
                            (spec.category, spec.name,
                             f"branch condition guarding {spec.name}()"))

    def _check_summary_sinks(self, call: ast.Call,
                             target: Optional[CallTarget],
                             arg_taints: List[Set[_Taint]],
                             kw_taints: Dict[Optional[str], Set[_Taint]]) -> None:
        if target is None:
            return
        summ = self.a.summaries.get(target.fn.qname)
        if summ is None or not summ.param_sinks:
            return
        mapping = self._map_args(call, target, arg_taints, kw_taints)
        if mapping is None:
            return
        eff = TaintAnalysis.effective_params(target)
        for idx, taints in mapping.items():
            entries = summ.param_sinks.get(idx)
            if not entries:
                continue
            for kind, payload in taints:
                for category, sink_name, via in sorted(entries):
                    if kind == "src":
                        pname = eff[idx] if idx < len(eff) else f"arg{idx}"
                        self._emit_via(call, category, sink_name, payload,
                                       pname, target.fn.qname, via)
                    else:
                        self.summary.param_sinks.setdefault(payload, set()).add(
                            (category, sink_name,
                             f"via {target.fn.qname.split(':')[-1]}(): {via}"))

    # -- finding emission --------------------------------------------------
    def _emit(self, call: ast.Call, spec: SinkSpec, src: TaintSource,
              pname: Optional[str], controls: bool = False) -> None:
        if not self.report:
            return
        if controls:
            msg = (f"nondeterministic value ({src.desc}, line {src.line}) "
                   f"controls the branch reaching {spec.category} sink "
                   f"{spec.name}()")
        else:
            msg = (f"nondeterministic value ({src.desc}, line {src.line}) "
                   f"reaches {spec.category} sink {spec.name}() via "
                   f"parameter {pname!r}")
        self.findings.append(TaintFinding(self.fi.module.path, call.lineno,
                                          call.col_offset, msg))

    def _emit_via(self, call: ast.Call, category: str, sink_name: str,
                  src: TaintSource, pname: str, callee_qname: str,
                  via: str) -> None:
        if not self.report:
            return
        callee = callee_qname.split(":")[-1]
        msg = (f"nondeterministic value ({src.desc}, line {src.line}) "
               f"reaches {category} sink {sink_name}() interprocedurally: "
               f"{callee}({pname}=...) -> {via}")
        self.findings.append(TaintFinding(self.fi.module.path, call.lineno,
                                          call.col_offset, msg))

    # -- set-typedness -----------------------------------------------------
    def _is_set_expr(self, e: Optional[ast.AST]) -> bool:
        if isinstance(e, (ast.Set, ast.SetComp)):
            return True
        if isinstance(e, ast.Name):
            return e.id in self.set_typed
        if isinstance(e, ast.Call):
            f = _last(e.func)
            if f in _SET_CTORS:
                return True
            if f in ("union", "intersection", "difference",
                     "symmetric_difference", "copy") \
                    and isinstance(e.func, ast.Attribute) \
                    and self._is_set_expr(e.func.value):
                return True
        if isinstance(e, ast.BinOp) and isinstance(e.op, (ast.BitOr, ast.BitAnd,
                                                          ast.Sub)):
            return self._is_set_expr(e.left) and self._is_set_expr(e.right)
        return False
