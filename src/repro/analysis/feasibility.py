"""Declarative feasibility models for tuning spaces.

A ``FeasibilityModel`` is a named bag of ``Predicate``s over concrete
configs.  ``error``-severity predicates define feasibility (the tuner
prunes violators before they reach the SUT, charging no budget);
``warn``-severity predicates surface quality hazards — e.g. sublane
misalignment, which the cost model penalizes *finitely* — without
excluding the config, so the invariant

    ``model(config)  ⇔  cost_model(config) < inf``

holds exactly for the kernel models (pinned by the property test in
``tests/test_feasibility.py``).

The kernel predicates are built on the SAME per-kernel VMEM-footprint
functions the roofline cost models call (``KernelDef.vmem_footprint``) —
one predicate, two consumers, no drift.  The serve predicates encode the
``apply_serve_knobs``/``min_pages_for`` deployability floor: a config
below the floor would be silently mutated at deployment (tuned !=
deployed), so fresh tuning runs never score one.  ``CompositeFeasibility``
composes member models under the composite space's prefixed keys.

Everything here is numpy/stdlib-only and imports jax-touching modules
lazily, so building a model never initializes an accelerator backend.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

__all__ = [
    "Predicate",
    "Violation",
    "FeasibilityModel",
    "CompositeFeasibility",
    "kernel_feasibility",
    "serve_feasibility",
]

Config = Dict[str, Any]

# A predicate check returns None when the config passes and a human-readable
# reason string when it does not.
CheckFn = Callable[[Config], Optional[str]]


@dataclass(frozen=True)
class Violation:
    predicate: str
    reason: str
    severity: str = "error"  # "error" => infeasible; "warn" => hazard only


@dataclass(frozen=True)
class Predicate:
    name: str
    check: CheckFn
    severity: str = "error"

    def __post_init__(self):
        if self.severity not in ("error", "warn"):
            raise ValueError(f"severity must be error|warn, "
                             f"got {self.severity!r}")


class FeasibilityModel:
    """Named predicates over one parameter space's concrete configs.

    Calling the model answers the tuner's question — is this config worth
    a test? — from the ``error`` predicates alone.  ``check`` returns every
    violation (warnings included) for reporting and for the lint-style
    ``explain`` string.
    """

    def __init__(self, name: str, predicates: Sequence[Predicate]):
        self.name = name
        self.predicates = tuple(predicates)
        names = [p.name for p in self.predicates]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate predicate names in {name!r}: "
                             f"{names}")

    def __call__(self, config: Mapping[str, Any]) -> bool:
        return all(p.check(dict(config)) is None
                   for p in self.predicates if p.severity == "error")

    def check(self, config: Mapping[str, Any]) -> List[Violation]:
        cfg = dict(config)
        out: List[Violation] = []
        for p in self.predicates:
            reason = p.check(cfg)
            if reason is not None:
                out.append(Violation(p.name, reason, p.severity))
        return out

    def explain(self, config: Mapping[str, Any]) -> str:
        vs = self.check(config)
        if not vs:
            return f"{self.name}: feasible"
        return "\n".join(f"{self.name}.{v.predicate} [{v.severity}]: "
                         f"{v.reason}" for v in vs)

    def __repr__(self) -> str:
        return (f"FeasibilityModel({self.name!r}, "
                f"{[p.name for p in self.predicates]})")


class CompositeFeasibility:
    """Member feasibility models composed under prefixed keys.

    Mirrors ``CompositeSpace``: a joint config's ``f"{member}{sep}{knob}"``
    keys are routed to each member's model with the prefix stripped, and
    violations come back with the member prefix on the predicate name.
    Joint feasibility is the conjunction of member feasibilities — a
    member with no model constrains nothing.
    """

    def __init__(self, members: Mapping[str, FeasibilityModel],
                 sep: str = "."):
        if not members:
            raise ValueError("CompositeFeasibility needs at least one "
                             "member model")
        self.members = dict(members)
        self.sep = sep
        self.name = "+".join(self.members)

    def _split(self, config: Mapping[str, Any]) -> Dict[str, Config]:
        out: Dict[str, Config] = {n: {} for n in self.members}
        for key, v in config.items():
            name, _, knob = key.partition(self.sep)
            if knob and name in out:
                out[name][knob] = v
        return out

    def __call__(self, config: Mapping[str, Any]) -> bool:
        parts = self._split(config)
        return all(model(parts[name])
                   for name, model in self.members.items())

    def check(self, config: Mapping[str, Any]) -> List[Violation]:
        parts = self._split(config)
        out: List[Violation] = []
        for name, model in self.members.items():
            for v in model.check(parts[name]):
                out.append(Violation(f"{name}{self.sep}{v.predicate}",
                                     v.reason, v.severity))
        return out

    def explain(self, config: Mapping[str, Any]) -> str:
        vs = self.check(config)
        if not vs:
            return f"{self.name}: feasible"
        return "\n".join(f"{v.predicate} [{v.severity}]: {v.reason}"
                         for v in vs)


# ---------------------------------------------------------------------------
# kernel models: predicates factored out of the roofline cost models
# ---------------------------------------------------------------------------

# Which (knob, clamp dim) pairs each kernel's cost model runs through
# _align_penalty — the warn-severity alignment predicates read the exact
# same clamped block the penalty term sees.  paged_attention tiles in
# PAGE_TOKENS multiples, so its block is always sublane-aligned and it
# carries no alignment predicate.
_ALIGN_KNOBS: Dict[str, Sequence] = {
    "flash_attention": (("block_q", "S"), ("block_kv", "SK")),
    "decode_attention": (("block_kv", "S"),),
    "gla": (("chunk", "S"),),
    "rmsnorm": (("block_rows", "ROWS"),),
    "paged_attention": (),
}


def kernel_feasibility(kernel: str, dims: Mapping[str, int],
                       dtype: str = "float32") -> FeasibilityModel:
    """The feasibility model of one kernel × problem signature.

    * ``vmem_fits`` (error) — the tile set's VMEM footprint, computed by
      the SAME ``KernelDef.vmem_footprint`` function the roofline cost
      model uses, must fit ``VMEM_BYTES``.  This is the *only* source of
      ``inf`` in the cost model, which is what makes the model's boolean
      agree exactly with cost finiteness.
    * ``sublane_aligned`` (warn) — blocks off the Mosaic (sublane, 128)
      tile grid waste fractional-tile compute; the cost model charges a
      finite ``_align_penalty``, so this is a hazard, not infeasibility.
    """
    from repro.autotune.space import (
        KERNELS, VMEM_BYTES, KernelSpace, _align_penalty, _sublane)

    kdef = KERNELS[kernel]  # KeyError on unknown kernel is the right error
    d = KernelSpace(kernel).validate_dims(dict(dims))

    def vmem_fits(cfg: Config) -> Optional[str]:
        v = float(kdef.vmem_footprint(cfg, d, dtype))
        if v > VMEM_BYTES:
            return (f"VMEM tile footprint {v / 2**20:.1f} MiB exceeds the "
                    f"{VMEM_BYTES / 2**20:.0f} MiB budget "
                    f"(cost model returns inf)")
        return None

    def sublane_aligned(cfg: Config) -> Optional[str]:
        sub = _sublane(dtype)
        bad = []
        for knob, dim_key in _ALIGN_KNOBS[kernel]:
            block = min(int(cfg[knob]), d[dim_key])
            if _align_penalty(block, dtype) > 1.0:
                bad.append(f"{knob}={block} not a multiple of the "
                           f"{dtype} sublane {sub}")
        return "; ".join(bad) or None

    return FeasibilityModel(
        f"kernel[{kernel}]",
        [Predicate("vmem_fits", vmem_fits),
         Predicate("sublane_aligned", sublane_aligned, severity="warn")])


# ---------------------------------------------------------------------------
# serve model: the apply_serve_knobs deployability floor
# ---------------------------------------------------------------------------
def serve_feasibility(max_seq: int = 2048, *, runtime: str = "continuous",
                      kv_layout: str = "paged",
                      kv_page_block: int = 1) -> FeasibilityModel:
    """The serve knob space's deployability predicates.

    ``kv_pages_floor`` (error) encodes exactly the floor
    ``apply_serve_knobs`` raises ``kv_cache_pages`` to when building a
    ``ServeConfig``: under the paged continuous runtime one ``max_seq``
    request (+ the scratch group) must fit (``min_pages_for``); dense
    layouts allocate the full ``slots × max_seq`` footprint.  A config
    below the floor would be silently mutated at deployment — the tuner
    would score one config and deploy another — so it is statically
    infeasible and never charged a test.

    Parameterized on the deployment base's layout fields (not on a
    ``ServeConfig``) so the model stays numpy-only and jax-free.
    """
    from repro.serve.paging import PAGE_TOKENS, min_pages_for

    paged = runtime == "continuous" and kv_layout == "paged"

    def kv_pages_floor(cfg: Config) -> Optional[str]:
        pages = int(cfg["kv_cache_pages"])
        slots = int(cfg["max_batch"])
        if paged:
            floor = min_pages_for(max_seq, kv_page_block)
        else:
            floor = -(-slots * max_seq // PAGE_TOKENS)
        if pages < floor:
            return (f"kv_cache_pages={pages} below the deployable floor "
                    f"{floor} for max_seq={max_seq} "
                    f"({runtime}/{kv_layout}): apply_serve_knobs would "
                    f"raise it, so tuned != deployed")
        return None

    return FeasibilityModel(
        "serve", [Predicate("kv_pages_floor", kv_pages_floor)])
