"""Declarative feasibility models for tuning spaces.

A ``FeasibilityModel`` is a named bag of ``Predicate``s over concrete
configs.  ``error``-severity predicates define feasibility (the tuner
prunes violators before they reach the SUT, charging no budget);
``warn``-severity predicates surface quality hazards — e.g. sublane
misalignment, which the cost model penalizes *finitely* — without
excluding the config, so the invariant

    ``model(config)  ⇔  cost_model(config) < inf``

holds exactly for the kernel models (pinned by the property test in
``tests/test_feasibility.py``).

The kernel predicates are built on the SAME per-kernel VMEM-footprint
functions the roofline cost models call (``KernelDef.vmem_footprint``) —
one predicate, two consumers, no drift.  The serve predicates encode the
``apply_serve_knobs``/``min_pages_for`` deployability floor: a config
below the floor would be silently mutated at deployment (tuned !=
deployed), so fresh tuning runs never score one.  ``CompositeFeasibility``
composes member models under the composite space's prefixed keys.

Everything here is numpy/stdlib-only and imports jax-touching modules
lazily, so building a model never initializes an accelerator backend.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

__all__ = [
    "Predicate",
    "Violation",
    "FeasibilityModel",
    "CompositeFeasibility",
    "kernel_feasibility",
    "serve_feasibility",
]

Config = Dict[str, Any]

# A predicate check returns None when the config passes and a human-readable
# reason string when it does not.
CheckFn = Callable[[Config], Optional[str]]


@dataclass(frozen=True)
class Violation:
    predicate: str
    reason: str
    severity: str = "error"  # "error" => infeasible; "warn" => hazard only


@dataclass(frozen=True)
class Predicate:
    name: str
    check: CheckFn
    severity: str = "error"

    def __post_init__(self):
        if self.severity not in ("error", "warn"):
            raise ValueError(f"severity must be error|warn, "
                             f"got {self.severity!r}")


class FeasibilityModel:
    """Named predicates over one parameter space's concrete configs.

    Calling the model answers the tuner's question — is this config worth
    a test? — from the ``error`` predicates alone.  ``check`` returns every
    violation (warnings included) for reporting and for the lint-style
    ``explain`` string.
    """

    def __init__(self, name: str, predicates: Sequence[Predicate]):
        self.name = name
        self.predicates = tuple(predicates)
        names = [p.name for p in self.predicates]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate predicate names in {name!r}: "
                             f"{names}")

    def __call__(self, config: Mapping[str, Any]) -> bool:
        return all(p.check(dict(config)) is None
                   for p in self.predicates if p.severity == "error")

    def check(self, config: Mapping[str, Any]) -> List[Violation]:
        cfg = dict(config)
        out: List[Violation] = []
        for p in self.predicates:
            reason = p.check(cfg)
            if reason is not None:
                out.append(Violation(p.name, reason, p.severity))
        return out

    def explain(self, config: Mapping[str, Any]) -> str:
        vs = self.check(config)
        if not vs:
            return f"{self.name}: feasible"
        return "\n".join(f"{self.name}.{v.predicate} [{v.severity}]: "
                         f"{v.reason}" for v in vs)

    def __repr__(self) -> str:
        return (f"FeasibilityModel({self.name!r}, "
                f"{[p.name for p in self.predicates]})")


class CompositeFeasibility:
    """Member feasibility models composed under prefixed keys.

    Mirrors ``CompositeSpace``: a joint config's ``f"{member}{sep}{knob}"``
    keys are routed to each member's model with the prefix stripped, and
    violations come back with the member prefix on the predicate name.
    Joint feasibility is the conjunction of member feasibilities — a
    member with no model constrains nothing.
    """

    def __init__(self, members: Mapping[str, FeasibilityModel],
                 sep: str = "."):
        if not members:
            raise ValueError("CompositeFeasibility needs at least one "
                             "member model")
        self.members = dict(members)
        self.sep = sep
        self.name = "+".join(self.members)

    def _split(self, config: Mapping[str, Any]) -> Dict[str, Config]:
        out: Dict[str, Config] = {n: {} for n in self.members}
        for key, v in config.items():
            name, _, knob = key.partition(self.sep)
            if knob and name in out:
                out[name][knob] = v
        return out

    def __call__(self, config: Mapping[str, Any]) -> bool:
        parts = self._split(config)
        return all(model(parts[name])
                   for name, model in self.members.items())

    def check(self, config: Mapping[str, Any]) -> List[Violation]:
        parts = self._split(config)
        out: List[Violation] = []
        for name, model in self.members.items():
            for v in model.check(parts[name]):
                out.append(Violation(f"{name}{self.sep}{v.predicate}",
                                     v.reason, v.severity))
        return out

    def explain(self, config: Mapping[str, Any]) -> str:
        vs = self.check(config)
        if not vs:
            return f"{self.name}: feasible"
        return "\n".join(f"{v.predicate} [{v.severity}]: {v.reason}"
                         for v in vs)


# ---------------------------------------------------------------------------
# kernel models: predicates factored out of the roofline cost models
# ---------------------------------------------------------------------------

# Which (knob, clamp dim) pairs each kernel's cost model runs through
# _align_penalty — the warn-severity alignment predicates read the exact
# same clamped block the penalty term sees.  paged_attention tiles in
# PAGE_TOKENS multiples, so its block is always sublane-aligned and it
# carries no alignment predicate.
_ALIGN_KNOBS: Dict[str, Sequence] = {
    "flash_attention": (("block_q", "S"), ("block_kv", "SK")),
    "decode_attention": (("block_kv", "S"),),
    "gla": (("chunk", "S"),),
    "rmsnorm": (("block_rows", "ROWS"),),
    "paged_attention": (),
}


def kernel_feasibility(kernel: str, dims: Mapping[str, int],
                       dtype: str = "float32") -> FeasibilityModel:
    """The feasibility model of one kernel × problem signature.

    * ``vmem_fits`` (error) — the tile set's VMEM footprint, computed by
      the SAME ``KernelDef.vmem_footprint`` function the roofline cost
      model uses, must fit ``VMEM_BYTES``.  This is the *only* source of
      ``inf`` in the cost model, which is what makes the model's boolean
      agree exactly with cost finiteness.
    * ``sublane_aligned`` (warn) — blocks off the Mosaic (sublane, 128)
      tile grid waste fractional-tile compute; the cost model charges a
      finite ``_align_penalty``, so this is a hazard, not infeasibility.
    """
    from repro.autotune.space import (
        KERNELS, VMEM_BYTES, KernelSpace, _align_penalty, _sublane)

    kdef = KERNELS[kernel]  # KeyError on unknown kernel is the right error
    d = KernelSpace(kernel).validate_dims(dict(dims))

    def vmem_fits(cfg: Config) -> Optional[str]:
        v = float(kdef.vmem_footprint(cfg, d, dtype))
        if v > VMEM_BYTES:
            return (f"VMEM tile footprint {v / 2**20:.1f} MiB exceeds the "
                    f"{VMEM_BYTES / 2**20:.0f} MiB budget "
                    f"(cost model returns inf)")
        return None

    def sublane_aligned(cfg: Config) -> Optional[str]:
        sub = _sublane(dtype)
        bad = []
        for knob, dim_key in _ALIGN_KNOBS[kernel]:
            block = min(int(cfg[knob]), d[dim_key])
            if _align_penalty(block, dtype) > 1.0:
                bad.append(f"{knob}={block} not a multiple of the "
                           f"{dtype} sublane {sub}")
        return "; ".join(bad) or None

    return FeasibilityModel(
        f"kernel[{kernel}]",
        [Predicate("vmem_fits", vmem_fits),
         Predicate("sublane_aligned", sublane_aligned, severity="warn")])


# ---------------------------------------------------------------------------
# serve model: the apply_serve_knobs deployability floor
# ---------------------------------------------------------------------------
def serve_feasibility(max_seq: int = 2048, *, runtime: str = "continuous",
                      kv_layout: str = "paged",
                      kv_page_block: int = 1,
                      n_devices: Optional[int] = None,
                      n_heads: Optional[int] = None,
                      n_kv_heads: Optional[int] = None) -> FeasibilityModel:
    """The serve knob space's deployability predicates.

    ``kv_pages_floor`` (error) encodes exactly the floor
    ``apply_serve_knobs`` raises ``kv_cache_pages`` to when building a
    ``ServeConfig``: under the paged continuous runtime one ``max_seq``
    request (+ the scratch group) must fit (``min_pages_for``); dense
    layouts allocate the full ``slots × max_seq`` footprint.  A config
    below the floor would be silently mutated at deployment — the tuner
    would score one config and deploy another — so it is statically
    infeasible and never charged a test.

    The sharding subspace (``mesh_devices`` / ``tp_vs_replicas``, absent
    in single-device spaces — absent knobs pass) adds:

    * ``mesh_fits`` (error) — the tuned device count must divide the
      host's ``n_devices``: ``ServeEngine`` refuses to build any other
      mesh, so fresh tunes must never persist one.
    * ``heads_divide`` (error) — under ``tp`` the model axis must divide
      ``n_heads``; otherwise ``spec_for_shape``'s divisibility fallback
      replicates attention and the deployed engine silently is NOT the
      tensor-parallel config the tuner scored.
    * ``kv_heads_shardable`` (warn) — under ``tp`` a model axis that
      doesn't divide ``n_kv_heads`` leaves the paged KV pool replicated
      per device (``repro.kernels.paged_attention.shardable_kv_heads``):
      deployable and token-correct, but without the pool-memory win —
      a hazard worth surfacing, not infeasibility.

    Parameterized on the deployment base's layout/topology fields (not on
    a ``ServeConfig``) so the model stays numpy-only and jax-free;
    ``None`` topology fields skip their predicates (unknown ≠ violated).
    """
    from repro.serve.paging import PAGE_TOKENS, min_pages_for

    paged = runtime == "continuous" and kv_layout == "paged"

    def kv_pages_floor(cfg: Config) -> Optional[str]:
        pages = int(cfg["kv_cache_pages"])
        slots = int(cfg["max_batch"])
        if paged:
            floor = min_pages_for(max_seq, kv_page_block)
        else:
            floor = -(-slots * max_seq // PAGE_TOKENS)
        if pages < floor:
            return (f"kv_cache_pages={pages} below the deployable floor "
                    f"{floor} for max_seq={max_seq} "
                    f"({runtime}/{kv_layout}): apply_serve_knobs would "
                    f"raise it, so tuned != deployed")
        return None

    def _mesh(cfg: Config) -> int:
        return int(cfg.get("mesh_devices", 1))

    def _is_tp(cfg: Config) -> bool:
        return str(cfg.get("tp_vs_replicas", "tp")) == "tp"

    def mesh_fits(cfg: Config) -> Optional[str]:
        dev = _mesh(cfg)
        if dev <= 1 or n_devices is None:
            return None
        if dev > n_devices or n_devices % dev:
            return (f"mesh_devices={dev} does not divide the host's "
                    f"{n_devices} devices: ServeEngine refuses to build "
                    f"this mesh")
        return None

    def heads_divide(cfg: Config) -> Optional[str]:
        dev = _mesh(cfg)
        if dev <= 1 or not _is_tp(cfg) or n_heads is None:
            return None
        if n_heads % dev:
            return (f"{dev}-way model axis does not divide n_heads="
                    f"{n_heads}: spec_for_shape would replicate attention "
                    f"and deploy an engine the tuner never scored")
        return None

    def kv_heads_shardable(cfg: Config) -> Optional[str]:
        dev = _mesh(cfg)
        if dev <= 1 or not _is_tp(cfg) or n_kv_heads is None:
            return None
        try:  # the kernel's own divisibility gate when jax is importable
            from repro.kernels.paged_attention import shardable_kv_heads
            ok = shardable_kv_heads(n_kv_heads, dev)
        except ImportError:  # jax-free caller: same arithmetic inline
            ok = n_kv_heads % dev == 0
        if not ok:
            return (f"{dev}-way model axis does not divide n_kv_heads="
                    f"{n_kv_heads}: the paged KV pool replicates per "
                    f"device (deployable, but no pool-memory win)")
        return None

    return FeasibilityModel(
        "serve",
        [Predicate("kv_pages_floor", kv_pages_floor),
         Predicate("mesh_fits", mesh_fits),
         Predicate("heads_divide", heads_divide),
         Predicate("kv_heads_shardable", kv_heads_shardable,
                   severity="warn")])
