"""Repo-wide static lint for jit/Pallas/allocator discipline.

Pure stdlib-``ast`` analysis — nothing here imports jax or executes repo
code, so the lint runs in CI before any accelerator is touched.  Three
rule families, each encoding a contract this codebase actually relies on:

jit retrace hazards (the engine holds 11 jit sites; a retrace per step
silently turns a served model into a compiler benchmark):

* ``jit-static-missing``    — a name listed in ``static_argnames`` that is
  not a parameter of the jitted function: jax raises only when the arg is
  passed, so the typo hides until a call site exercises it.
* ``jit-static-mutable-default`` — a static parameter whose default is a
  mutable literal (list/dict/set): unhashable the first time the default
  is used, and a shared-state bug besides.
* ``jit-traced-str-default`` — a parameter *not* marked static whose
  default is a ``str`` literal: strings cannot be traced, so the default
  aborts at trace time (or forces a retrace per distinct value when
  threaded through).

``pallas_call`` contract checks (Mosaic reports arity mismatches as deep
lowering errors, long after the mistake):

* ``pallas-operand-arity``  — the immediate call's operand count must be
  ``num_scalar_prefetch + len(in_specs)``.
* ``pallas-index-map-arity`` — every ``BlockSpec`` index_map lambda must
  take ``len(grid) + num_scalar_prefetch`` arguments.
* ``pallas-kernel-arity``   — the kernel's positional (ref) parameters
  must number ``num_scalar_prefetch + n_in + n_out + n_scratch``
  (``functools.partial`` keyword bindings and keyword-only config
  parameters are excluded; positional partial bindings consume leading
  slots).
* ``pallas-vmem-scratch``   — (warning) constant-shaped ``pltpu.VMEM``
  scratch totalling more than the per-core VMEM budget.

Allocator discipline (a page group leaked on an error path silently
shrinks every later run's pool):

* ``alloc-try-no-release``  — an acquire call (``reserve`` / ``extend`` /
  ``share`` / ``try_alloc`` / ``cow_split``) on an allocator-looking
  receiver, lexically inside a ``try`` body whose handlers/finally never
  call ``release``/``release_all``.

Mesh/sharding discipline (the serve engine jits against whatever mesh is
active; sharding mistakes surface as silent replication, not errors):

* ``jit-mesh-closure``      — a jitted function closing over a
  module-level name bound to a concrete ``Mesh`` / ``NamedSharding`` /
  ``make_mesh(...)``: the jit cache never keys on the closure, so a
  topology change silently reuses executables compiled for the old
  grid.  Pass the mesh (or shardings derived from it) as an argument.
* ``constrain-unknown-axis`` — a string logical-axis name passed to
  ``constrain(...)`` / ``spec_for_shape(...)`` that no entry of
  ``repro.dist.sharding.RULE_PRESETS`` (or the deliberate
  ``REPLICATED_AXES`` set) knows: every preset drops the axis, so the
  dimension silently replicates on every mesh — the typo class
  ``spec_for_shape``'s drop-unknown semantics can never raise on.

Interprocedural dataflow rules (built on ``repro.analysis.dataflow``'s
call graph + taint engine; PR 10):

* ``determinism-taint``     — a nondeterministic value (wall clock,
  global RNG, ``os.urandom``, set iteration order) reaches a *decision*
  sink: scheduler admission/victim choices, retune triggers, optimizer
  candidate generation, sampling keys, cache-key construction.  Timers
  that only accumulate into metric records (``PerfMetric``,
  ``TuningReport``, ``GenerationResult``, …) are the accepted pattern
  and stay clean — the taint must reach a decision, interprocedurally.
* ``jit-trace-capture``     — a jitted (or ``pallas_call``-wrapped)
  function closes over mutable module state that the module actually
  mutates, or over an ambient ``*Config(...)`` object; or a *bound
  method of a shared object* is jitted in a file that builds meshes
  (the PR 9 footgun: bound methods of one shared model hash equal, so
  the jit cache silently reuses jaxprs traced under another engine's
  mesh — wrap in a per-instance closure, as ``_jit_mesh_keyed`` does).
* ``jit-host-effect``       — host-side effects under trace: bare
  ``print`` (use ``jax.debug.print``), ``open``/stdout writes,
  ``global`` rebinding, or mutation of a closed-over container — all
  run once at trace time, then never again.
* ``cache-lock-discipline`` — a cache-state mutation or cache-file
  write not dominated by the sidecar-``flock`` acquire
  (``_file_lock``), checked interprocedurally: an unlocked helper is
  clean only when *every* resolved call site holds the lock
  (``put``/``put_serve_config``/``put_train_config`` → ``_save``).

Every check is *resolve-or-skip*: when a piece (grid length, spec list,
kernel def, static names) is not statically resolvable, the site is
skipped rather than guessed at — findings are high-confidence by
construction.  False positives are suppressed per line with a same-line
pragma::

    alloc.reserve(rid, n)  # lint: ignore[alloc-try-no-release]
    risky_call()           # lint: ignore          (all rules)

Usage (machine-readable JSON on stdout; byte-identical across runs —
findings and keys are sorted)::

    python -m repro.analysis.lint src/repro            # report
    python -m repro.analysis.lint --check src/repro    # CI gate: exit 1
                                                       # on any finding
    python -m repro.analysis.lint --format github src  # CI annotations
"""
from __future__ import annotations

import argparse
import ast
import json
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Tuple

try:  # package-relative (python -m repro.analysis.lint)
    from . import dataflow as _df
except ImportError:  # pragma: no cover - direct script invocation
    import dataflow as _df  # type: ignore[no-redef]

__all__ = ["Finding", "RULES", "lint_file", "lint_paths", "main"]

# rule -> (severity, one-line description)
RULES: Dict[str, Tuple[str, str]] = {
    "jit-static-missing": (
        "error", "static_argnames entry is not a parameter of the "
                 "jitted function"),
    "jit-static-mutable-default": (
        "error", "static parameter has a mutable (unhashable) default"),
    "jit-traced-str-default": (
        "error", "traced parameter has a str default (untraceable; "
                 "retrace hazard)"),
    "pallas-operand-arity": (
        "error", "pallas_call operand count != num_scalar_prefetch + "
                 "len(in_specs)"),
    "pallas-index-map-arity": (
        "error", "index_map arity != len(grid) + num_scalar_prefetch"),
    "pallas-kernel-arity": (
        "error", "kernel positional params != prefetch + inputs + "
                 "outputs + scratch"),
    "pallas-vmem-scratch": (
        "warning", "constant VMEM scratch shapes exceed the per-core "
                   "VMEM budget"),
    "alloc-try-no-release": (
        "error", "allocator acquire inside try with no release on the "
                 "unwind path"),
    "jit-mesh-closure": (
        "error", "jitted function closes over a concrete "
                 "Mesh/NamedSharding instead of taking it as an "
                 "argument"),
    "constrain-unknown-axis": (
        "error", "logical axis name that no sharding rules preset maps "
                 "(the dimension would silently replicate)"),
    "determinism-taint": (
        "error", "nondeterministic value (wall clock / global RNG / "
                 "set order) reaches a scheduling, retune, sampling or "
                 "cache-key decision"),
    "jit-trace-capture": (
        "error", "jitted function captures mutable module state, an "
                 "ambient config object, or is a bound method of a "
                 "shared object under an ambient mesh"),
    "jit-host-effect": (
        "error", "host-side effect (print/IO/global or closure "
                 "mutation) inside a traced function runs only at "
                 "trace time"),
    "cache-lock-discipline": (
        "error", "cache mutation or cache-file write reachable without "
                 "holding the _file_lock sidecar flock"),
}

try:  # single source of truth when the package is importable
    from repro.autotune.space import VMEM_BYTES
except Exception:  # pragma: no cover - standalone invocation
    VMEM_BYTES = 16 * 2 ** 20

try:  # the axis registry the constrain-unknown-axis rule checks against
    from repro.dist.sharding import KNOWN_LOGICAL_AXES
except Exception:  # pragma: no cover - standalone invocation
    KNOWN_LOGICAL_AXES = frozenset({
        "batch", "cap", "conv_dim", "embed", "embed_fsdp", "expert_ff",
        "experts", "ff", "head_dim", "heads", "kv_heads", "seq",
        "seq_res", "vocab"})

_ACQUIRE = frozenset({"reserve", "extend", "share", "try_alloc",
                      "cow_split"})
_RELEASE = frozenset({"release", "release_all"})

# constructors whose module-level result a jitted function must not
# close over (jit-mesh-closure)
_MESH_CTORS = frozenset({"Mesh", "NamedSharding", "make_mesh"})

# ---------------------------------------------------------------------------
# determinism-taint configuration (sources / sinks / boundaries)
# ---------------------------------------------------------------------------
# wall-clock reads, dotted (module call) and bare (from-import) forms
_CLOCK_DOTTED = frozenset({
    "time.time", "time.perf_counter", "time.monotonic", "time.time_ns",
    "time.perf_counter_ns", "time.monotonic_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow"})
_CLOCK_BARE = frozenset({"perf_counter", "monotonic", "time_ns",
                         "perf_counter_ns", "monotonic_ns"})
# stdlib `random` module-level functions (the shared global generator)
_PY_RANDOM_FNS = frozenset({
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "betavariate",
    "expovariate", "triangular", "lognormvariate", "vonmisesvariate",
    "paretovariate", "weibullvariate", "getrandbits", "randbytes"})
# np.random legacy module-level functions (the shared global RandomState)
_NP_RANDOM_FNS = frozenset({
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "choice", "shuffle", "permutation", "uniform", "normal",
    "standard_normal", "beta", "binomial", "bytes", "exponential",
    "gamma", "geometric", "gumbel", "laplace", "logistic", "lognormal",
    "poisson"})

# metric-record types whose construction absorbs taint: a timer flowing
# into a perf record is the repo's accepted pattern (engine.py holds
# ~20 such sites); taint must reach a *decision* to be a finding
_TAINT_BOUNDARIES = frozenset({
    "PerfMetric", "Trial", "TuningResult", "TuningReport",
    "GenerationResult", "RequestStats", "StepStats"})


def _classify_taint_source(call: ast.Call, target, path: str):
    """Label a call that injects nondeterminism (None = clean)."""
    dotted = _dotted(call.func) or ""
    name = _last(call.func)
    if dotted in _CLOCK_DOTTED or (isinstance(call.func, ast.Name)
                                   and name in _CLOCK_BARE):
        return _df.TaintSource("wall-clock", f"{dotted or name}()",
                               path, call.lineno)
    if dotted == "os.urandom" or dotted in ("uuid.uuid1", "uuid.uuid4"):
        return _df.TaintSource("os-entropy", f"{dotted}()", path,
                               call.lineno)
    parts = dotted.split(".")
    if len(parts) == 2 and parts[0] == "random" \
            and parts[1] in _PY_RANDOM_FNS:
        return _df.TaintSource("global-rng", f"{dotted}() (global "
                               "generator)", path, call.lineno)
    if len(parts) >= 3 and parts[-2] == "random" \
            and parts[-1] in _NP_RANDOM_FNS:
        return _df.TaintSource("global-rng", f"{dotted}() (global "
                               "RandomState)", path, call.lineno)
    if name == "default_rng" and not call.args and not call.keywords:
        return _df.TaintSource("global-rng", "default_rng() without a "
                               "seed", path, call.lineno)
    return None


def _taint_sinks() -> Dict[str, List["_df.SinkSpec"]]:
    """The sink registry, grouped by last-segment call name.

    Distinctive names match bare (unresolved) calls too; generic names
    (``put``, ``get``, ``key``, ``submit``, ``pop``) sink only when the
    call RESOLVES to the real target (qname suffix) — resolve-or-skip.
    ``decision=True`` sinks additionally fire when reached under a
    branch whose condition is tainted.
    """
    S = _df.SinkSpec
    specs = [
        # scheduler admission / victim decisions
        S("admission_order", "scheduler-decision", decision=True),
        S("pop_first_fit", "scheduler-decision", decision=True),
        S("select_victim", "scheduler-decision", decision=True),
        S("submit", "scheduler-decision",
          qname_suffix=":SlotScheduler.submit", decision=True),
        S("resubmit", "scheduler-decision",
          qname_suffix=":SlotScheduler.resubmit", decision=True),
        S("pop", "scheduler-decision",
          qname_suffix=":SlotScheduler.pop", decision=True),
        S("set_policy", "scheduler-decision",
          qname_suffix=":SlotScheduler.set_policy", decision=True),
        S("set_page_policy", "scheduler-decision",
          qname_suffix=":SlotScheduler.set_page_policy", decision=True),
        # retune triggers (PR 8 made these step-counted on purpose)
        S("maybe_retune", "retune-trigger", decision=True),
        S("should_retune", "retune-trigger", decision=True),
        # optimizer candidate generation
        S("lhs", "candidate-generation"),
        S("lhs_unit", "candidate-generation"),
        S("random_config", "candidate-generation"),
        S("Tuner", "candidate-generation",
          params=frozenset({"seed", "budget"})),
        # sampling keys
        S("PRNGKey", "sampling-key"),
        S("fold_in", "sampling-key"),
        S("default_rng", "sampling-key"),
        # cache-key construction
        S("shape_sig", "cache-key"),
        S("mesh_sig", "cache-key"),
        S("fingerprint_sig", "cache-key"),
        S("key", "cache-key", qname_suffix=":AutotuneCache.key"),
        S("put", "cache-key",
          params=frozenset({"kernel", "sig", "dtype", "backend",
                            "workload", "mesh"}),
          qname_suffix=":AutotuneCache.put"),
        S("get", "cache-key",
          params=frozenset({"kernel", "sig", "dtype", "backend",
                            "workload", "mesh"}),
          qname_suffix=":AutotuneCache.get"),
        S("get_config", "cache-key",
          params=frozenset({"kernel", "sig", "dtype", "backend",
                            "workload", "mesh"}),
          qname_suffix=":AutotuneCache.get_config"),
        S("put_serve_config", "cache-key",
          params=frozenset({"sig_dims", "dtype", "backend", "workload",
                            "mesh"})),
        S("put_train_config", "cache-key",
          params=frozenset({"sig_dims", "dtype", "backend"})),
    ]
    out: Dict[str, List[_df.SinkSpec]] = {}
    for s in specs:
        out.setdefault(s.name, []).append(s)
    return out


_SINKS = _taint_sinks()

# module-level container constructors that make a captured name
# "mutable module state" for jit-trace-capture
_MUTABLE_CTORS = frozenset({"list", "dict", "set", "defaultdict",
                            "OrderedDict", "Counter", "deque"})
# container-mutating method names (trace-capture mutation evidence and
# jit-host-effect closure mutation)
_MUTATOR_METHODS = frozenset({"append", "add", "update", "extend",
                              "insert", "setdefault", "pop", "popitem",
                              "remove", "discard", "clear", "write",
                              "writelines", "appendleft"})
# cache-file write + mapping-mutator surface for cache-lock-discipline
_CACHE_MUTATORS = frozenset({"update", "setdefault", "pop", "popitem",
                             "clear", "__setitem__"})

_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
}

_PRAGMA_RE = re.compile(r"#\s*lint:\s*ignore(?:\[([^\]]*)\])?")


@dataclass(frozen=True)
class Finding:
    rule: str
    severity: str
    path: str
    line: int
    col: int
    message: str

    def to_dict(self) -> Dict[str, Any]:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line, "col": self.col,
                "message": self.message}


# ---------------------------------------------------------------------------
# small AST helpers (resolve-or-None everywhere)
# ---------------------------------------------------------------------------
def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.jit' for Attribute chains / Names; None when unresolvable."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def _last(node: ast.AST) -> Optional[str]:
    d = _dotted(node)
    return d.rsplit(".", 1)[-1] if d else None


def _segments(node: ast.AST) -> List[str]:
    """All name segments along an attribute chain, skipping opaque parts
    (calls, subscripts) — 'self._alloc[i].reserve' -> [self, _alloc]."""
    out: List[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            out.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Name):
            out.append(node.id)
            return out
        elif isinstance(node, (ast.Subscript, ast.Call)):
            node = node.value if isinstance(node, ast.Subscript) \
                else node.func
        else:
            return out


def _str_elements(node: ast.AST) -> Optional[List[str]]:
    """A str literal or tuple/list of str literals -> the names."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant)
                    and isinstance(e.value, str)):
                return None
            out.append(e.value)
        return out
    return None


def _int_elements(node: ast.AST) -> Optional[List[int]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant)
                    and isinstance(e.value, int)):
                return None
            out.append(e.value)
        return out
    return None


def _positional_params(fn: ast.FunctionDef) -> List[str]:
    return [a.arg for a in fn.args.posonlyargs + fn.args.args]


def _all_params(fn: ast.FunctionDef) -> List[str]:
    return (_positional_params(fn)
            + [a.arg for a in fn.args.kwonlyargs])


def _defaults_by_name(fn: ast.FunctionDef) -> Dict[str, ast.AST]:
    out: Dict[str, ast.AST] = {}
    pos = fn.args.posonlyargs + fn.args.args
    for name, default in zip([a.arg for a in pos[-len(fn.args.defaults):]]
                             if fn.args.defaults else [],
                             fn.args.defaults):
        out[name] = default
    for a, d in zip(fn.args.kwonlyargs, fn.args.kw_defaults):
        if d is not None:
            out[a.arg] = d
    return out


def _bound_names(fn: ast.FunctionDef) -> set:
    """Every name the function binds locally (params, assignment and
    loop targets, nested defs, imports, lambda params): a reference to
    anything else reads the enclosing scope — a closure."""
    bound = set(_all_params(fn))
    for a in (fn.args.vararg, fn.args.kwarg):
        if a is not None:
            bound.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) \
                and isinstance(node.ctx, (ast.Store, ast.Del)):
            bound.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            bound.add(node.name)
            if not isinstance(node, ast.ClassDef):
                bound.update(_all_params(node))
        elif isinstance(node, ast.Lambda):
            bound.update(a.arg for a in node.args.posonlyargs
                         + node.args.args + node.args.kwonlyargs)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            bound.update((alias.asname or alias.name).split(".")[0]
                         for alias in node.names)
        elif isinstance(node, ast.ExceptHandler) and node.name:
            bound.add(node.name)
    return bound


def _fn_own_walk(fn: ast.FunctionDef):
    """Walk a function's own body, not nested def/class bodies (those
    are separate trace scopes — resolve-or-skip, never guess)."""
    stack: List[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _axis_literals(node: ast.AST) -> List[Tuple[str, ast.AST]]:
    """(name, node) for every string literal in an axes argument,
    descending into tuple/list entries; non-literal elements are
    skipped (resolve-or-skip, per element)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [(node.value, node)]
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[Tuple[str, ast.AST]] = []
        for e in node.elts:
            out.extend(_axis_literals(e))
        return out
    return []


def _pragmas(source: str) -> Dict[int, Optional[FrozenSet[str]]]:
    """line (1-based) -> frozenset of suppressed rules, or None = all."""
    out: Dict[int, Optional[FrozenSet[str]]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        if "lint:" not in text:
            continue
        m = _PRAGMA_RE.search(text)
        if not m:
            continue
        rules = m.group(1)
        if rules is None:
            out[i] = None
        else:
            out[i] = frozenset(
                r.strip() for r in rules.split(",") if r.strip())
    return out


# ---------------------------------------------------------------------------
# per-file linter
# ---------------------------------------------------------------------------
class _FileLinter:
    def __init__(self, path: str, tree: ast.Module, source: str):
        self.path = path
        self.tree = tree
        self.pragmas = _pragmas(source)
        self.findings: List[Finding] = []
        # name -> def / simple-assignment value, for resolve-by-name.
        # File-global and last-wins: a heuristic, but resolution failure
        # only ever *skips* a check, and kernel names are file-unique.
        self.defs: Dict[str, ast.FunctionDef] = {}
        self.assigns: Dict[str, ast.AST] = {}
        # names bound by imports (module objects / imported symbols):
        # a receiver rooted at one of these is not a shared instance
        self.import_names: set = set()
        # module-level simple assigns only (trace-capture looks at
        # genuine module state, not last-wins function locals)
        self.module_assigns: Dict[str, ast.AST] = {}
        for stmt in tree.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                self.module_assigns[stmt.targets[0].id] = stmt.value
            elif isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name) \
                    and stmt.value is not None:
                self.module_assigns[stmt.target.id] = stmt.value
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs[node.name] = node
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                self.assigns[node.targets[0].id] = node.value
            elif isinstance(node, (ast.Import, ast.ImportFrom)):
                self.import_names.update(
                    (alias.asname or alias.name).split(".")[0]
                    for alias in node.names)

    # -- plumbing ----------------------------------------------------------
    def report(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 1)
        suppressed = self.pragmas.get(line, frozenset())
        if suppressed is None or rule in suppressed:
            return
        self.findings.append(Finding(
            rule=rule, severity=RULES[rule][0], path=self.path,
            line=line, col=getattr(node, "col_offset", 0),
            message=message))

    def run(self) -> List[Finding]:
        self._check_jit_sites()
        self._check_pallas_sites()
        self._check_alloc_discipline()
        self._check_mesh_closure()
        self._check_constrain_axes()
        self._check_trace_capture()
        self._check_host_effects()
        self.findings.sort(key=lambda f: (f.line, f.col, f.rule))
        return self.findings

    # -- jit rules ---------------------------------------------------------
    def _jit_sites(self):
        """Yield (jitted FunctionDef, static-names set | None, site node).

        statics None means the site had no resolvable static spec and
        only the bare-jit checks apply; unresolvable *targets* are not
        yielded at all.
        """
        for fn in self.defs.values():
            for deco in fn.decorator_list:
                statics = self._statics_from_decorator(deco, fn)
                if statics is not None:
                    yield fn, statics, deco
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call)
                    and _last(node.func) == "jit"
                    and node.args):
                continue
            target = node.args[0]
            fn = None
            if isinstance(target, ast.Name):
                fn = self.defs.get(target.id)
            if fn is None:
                continue  # attribute/call targets: skip, don't guess
            statics = self._parse_statics(node.keywords, fn)
            if statics is not None:
                yield fn, statics, node

    def _statics_from_decorator(self, deco, fn):
        # @jax.jit
        if _last(deco) == "jit":
            return set()
        if not isinstance(deco, ast.Call):
            return None
        # @functools.partial(jax.jit, static_argnames=...)
        if _last(deco.func) == "partial" and deco.args \
                and _last(deco.args[0]) == "jit":
            return self._parse_statics(deco.keywords, fn)
        # @jax.jit(static_argnames=...)  (decorator-factory form)
        if _last(deco.func) == "jit":
            return self._parse_statics(deco.keywords, fn)
        return None

    def _parse_statics(self, keywords, fn):
        """static names from jit(...) keywords; None = unresolvable."""
        names: set = set()
        positional = _positional_params(fn)
        for kw in keywords:
            if kw.arg == "static_argnames":
                got = _str_elements(kw.value)
                if got is None:
                    return None
                names.update(got)
            elif kw.arg == "static_argnums":
                nums = _int_elements(kw.value)
                if nums is None:
                    return None
                for n in nums:
                    if 0 <= n < len(positional):
                        names.add(positional[n])
                    else:
                        return None  # out of range: let jax complain
        return names

    def _check_jit_sites(self) -> None:
        seen = set()
        for fn, statics, site in self._jit_sites():
            key = (fn.name, id(site))
            if key in seen:
                continue
            seen.add(key)
            params = set(_all_params(fn))
            has_var = fn.args.vararg is not None \
                or fn.args.kwarg is not None
            defaults = _defaults_by_name(fn)
            for s in sorted(statics):
                if s not in params and not has_var:
                    self.report(
                        "jit-static-missing", site,
                        f"static_argnames entry {s!r} is not a "
                        f"parameter of {fn.name}()")
            for name, default in defaults.items():
                if name in statics and isinstance(
                        default, (ast.List, ast.Dict, ast.Set)):
                    self.report(
                        "jit-static-mutable-default", default,
                        f"static parameter {name!r} of {fn.name}() has "
                        "a mutable default (unhashable under jit)")
                if name not in statics \
                        and isinstance(default, ast.Constant) \
                        and isinstance(default.value, str):
                    self.report(
                        "jit-traced-str-default", default,
                        f"parameter {name!r} of {fn.name}() defaults "
                        f"to str {default.value!r} but is not in "
                        "static_argnames")

    # -- pallas rules ------------------------------------------------------
    def _check_pallas_sites(self) -> None:
        immediate: Dict[int, ast.Call] = {}
        pallas_calls: List[ast.Call] = []
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            if _last(node.func) == "pallas_call":
                pallas_calls.append(node)
            elif isinstance(node.func, ast.Call) \
                    and _last(node.func.func) == "pallas_call":
                immediate[id(node.func)] = node
        for pc in pallas_calls:
            self._check_one_pallas(pc, immediate.get(id(pc)))

    def _grid_spec_fields(self, pc: ast.Call):
        """(k, grid_node, in_specs, out_specs, out_shape, scratch) with
        None for any field that is absent or unresolvable; k None means
        the whole spec is opaque."""
        fields = {kw.arg: kw.value for kw in pc.keywords if kw.arg}
        k: Optional[int] = 0
        spec = fields.get("grid_spec")
        if spec is not None:
            if not (isinstance(spec, ast.Call)
                    and _last(spec.func) == "PrefetchScalarGridSpec"):
                return None, None, None, None, None, None
            inner = {kw.arg: kw.value for kw in spec.keywords if kw.arg}
            n = inner.get("num_scalar_prefetch")
            if isinstance(n, ast.Constant) and isinstance(n.value, int):
                k = n.value
            elif n is not None:
                k = None
            fields = dict(fields)
            fields.update(inner)
        return (k, fields.get("grid"), fields.get("in_specs"),
                fields.get("out_specs"), fields.get("out_shape"),
                fields.get("scratch_shapes"))

    @staticmethod
    def _spec_count(node: Optional[ast.AST]) -> Optional[int]:
        if node is None:
            return None
        if isinstance(node, (ast.List, ast.Tuple)):
            return len(node.elts)
        if isinstance(node, ast.Call):  # single BlockSpec / SDS
            return 1
        return None

    @staticmethod
    def _index_maps(node: Optional[ast.AST]) -> List[ast.Lambda]:
        """index_map lambdas of the BlockSpec(s) in node."""
        specs: List[ast.AST] = []
        if isinstance(node, (ast.List, ast.Tuple)):
            specs = list(node.elts)
        elif isinstance(node, ast.Call):
            specs = [node]
        out: List[ast.Lambda] = []
        for s in specs:
            if not (isinstance(s, ast.Call)
                    and _last(s.func) == "BlockSpec"):
                continue
            cand: Optional[ast.AST] = None
            if len(s.args) > 1:
                cand = s.args[1]
            else:
                for kw in s.keywords:
                    if kw.arg == "index_map":
                        cand = kw.value
            if isinstance(cand, ast.Lambda):
                out.append(cand)
        return out

    def _resolve_kernel(self, node: ast.AST, depth: int = 0):
        """(FunctionDef, n_positional_bound, keyword-bound names) | None."""
        if depth > 4:
            return None
        if isinstance(node, ast.Name):
            if node.id in self.defs:
                return self.defs[node.id], 0, set()
            target = self.assigns.get(node.id)
            return None if target is None \
                else self._resolve_kernel(target, depth + 1)
        if isinstance(node, ast.Call) and _last(node.func) == "partial" \
                and node.args:
            inner = self._resolve_kernel(node.args[0], depth + 1)
            if inner is None:
                return None
            fn, n_pos, kw_names = inner
            return (fn, n_pos + len(node.args) - 1,
                    kw_names | {kw.arg for kw in node.keywords
                                if kw.arg})
        return None

    def _scratch_bytes(self, node: Optional[ast.AST]) -> Optional[int]:
        """Total bytes of VMEM scratch, when every shape is constant."""
        if not isinstance(node, (ast.List, ast.Tuple)) or not node.elts:
            return None
        total = 0
        for e in node.elts:
            if not (isinstance(e, ast.Call) and _last(e.func) == "VMEM"
                    and len(e.args) >= 2):
                return None
            dims = _int_elements(e.args[0])
            dtype = _last(e.args[1])
            if dims is None or dtype not in _DTYPE_BYTES:
                return None
            n = _DTYPE_BYTES[dtype]
            for d in dims:
                n *= d
            total += n
        return total

    def _check_one_pallas(self, pc: ast.Call,
                          operands: Optional[ast.Call]) -> None:
        k, grid, in_specs, out_specs, out_shape, scratch = \
            self._grid_spec_fields(pc)
        grid_len = len(grid.elts) \
            if isinstance(grid, (ast.Tuple, ast.List)) else None
        n_in = self._spec_count(in_specs)
        n_out = self._spec_count(out_specs)
        if n_out is None:
            n_out = self._spec_count(out_shape)
        n_scratch = self._spec_count(scratch)
        if n_scratch is None and scratch is None:
            n_scratch = 0

        # pallas-index-map-arity
        if k is not None and grid_len is not None:
            want = grid_len + k
            for lam in (self._index_maps(in_specs)
                        + self._index_maps(out_specs)):
                if lam.args.vararg is not None:
                    continue
                got = len(lam.args.posonlyargs) + len(lam.args.args)
                if got != want:
                    self.report(
                        "pallas-index-map-arity", lam,
                        f"index_map takes {got} args; grid has "
                        f"{grid_len} dims + {k} scalar-prefetch "
                        f"operands = {want} expected")

        # pallas-operand-arity
        if operands is not None and k is not None and n_in is not None \
                and not any(isinstance(a, ast.Starred)
                            for a in operands.args) \
                and not operands.keywords:
            want = k + n_in
            got = len(operands.args)
            if got != want:
                self.report(
                    "pallas-operand-arity", operands,
                    f"pallas_call invoked with {got} operands; "
                    f"{k} scalar-prefetch + {n_in} in_specs = "
                    f"{want} expected")

        # pallas-kernel-arity
        if pc.args and None not in (k, n_in, n_out, n_scratch):
            resolved = self._resolve_kernel(pc.args[0])
            if resolved is not None:
                fn, n_bound, kw_bound = resolved
                if fn.args.vararg is None:
                    slots = [p for p in _positional_params(fn)
                             if p not in kw_bound][n_bound:]
                    want = k + n_in + n_out + n_scratch
                    if len(slots) != want:
                        self.report(
                            "pallas-kernel-arity", pc,
                            f"kernel {fn.name}() exposes {len(slots)} "
                            f"positional ref params; {k} prefetch + "
                            f"{n_in} in + {n_out} out + {n_scratch} "
                            f"scratch = {want} expected")

        # pallas-vmem-scratch (warning)
        total = self._scratch_bytes(scratch)
        if total is not None and total > VMEM_BYTES:
            self.report(
                "pallas-vmem-scratch", scratch,
                f"VMEM scratch totals {total / 2**20:.1f} MiB, over "
                f"the {VMEM_BYTES / 2**20:.0f} MiB per-core budget")

    # -- mesh/sharding rules -----------------------------------------------
    def _mesh_value(self, name: str, depth: int = 0) -> Optional[ast.Call]:
        """The Mesh/NamedSharding/make_mesh constructor call a
        module-level name resolves to, through simple aliasing, or
        None."""
        if depth > 4:
            return None
        val = self.assigns.get(name)
        if isinstance(val, ast.Call) and _last(val.func) in _MESH_CTORS:
            return val
        if isinstance(val, ast.Name):
            return self._mesh_value(val.id, depth + 1)
        return None

    def _check_mesh_closure(self) -> None:
        seen = set()
        for fn, _statics, _site in self._jit_sites():
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            bound = _bound_names(fn)
            flagged: set = set()
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Name)
                        and isinstance(node.ctx, ast.Load)
                        and node.id not in bound
                        and node.id not in flagged):
                    continue
                val = self._mesh_value(node.id)
                if val is not None:
                    flagged.add(node.id)
                    self.report(
                        "jit-mesh-closure", node,
                        f"jitted {fn.name}() closes over {node.id!r}, "
                        f"a concrete {_last(val.func)}(...) built at "
                        "module scope; the jit cache never keys on a "
                        "closure, so a topology change reuses stale "
                        "executables — pass it as an argument")

    def _check_constrain_axes(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _last(node.func)
            if name == "constrain":
                axis_args = node.args[1:]
            elif name == "spec_for_shape" and len(node.args) >= 2:
                axis_args = [node.args[1]]
            else:
                continue
            for arg in axis_args:
                for axis, anode in _axis_literals(arg):
                    if axis not in KNOWN_LOGICAL_AXES:
                        self.report(
                            "constrain-unknown-axis", anode,
                            f"logical axis {axis!r} is in no "
                            "RULE_PRESETS entry (nor REPLICATED_AXES): "
                            "every preset would drop it and the "
                            "dimension silently replicates")

    # -- trace-capture / host-effect rules (PR 10) ------------------------
    def _traced_fns(self):
        """Every function whose body runs under trace: resolved jit
        targets plus resolved pallas kernels.  Deduplicated, in source
        order for deterministic reporting."""
        seen: Dict[int, ast.FunctionDef] = {}
        for fn, _statics, _site in self._jit_sites():
            seen.setdefault(id(fn), fn)
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) \
                    and _last(node.func) == "pallas_call" and node.args:
                resolved = self._resolve_kernel(node.args[0])
                if resolved is not None:
                    seen.setdefault(id(resolved[0]), resolved[0])
        return sorted(seen.values(), key=lambda f: (f.lineno, f.name))

    def _mutation_sites(self, name: str) -> List[ast.AST]:
        """Statements anywhere in the file that mutate ``name`` in
        place (mutator-method call, subscript store/del, augmented
        subscript assign) — the evidence that a captured module
        container is live state, not a constant table."""
        out: List[ast.AST] = []
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _MUTATOR_METHODS \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id == name:
                out.append(node)
            elif isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
                targets = node.targets if isinstance(node, (ast.Assign,
                                                            ast.Delete)) \
                    else [node.target]
                for tgt in targets:
                    if isinstance(tgt, ast.Subscript) \
                            and isinstance(tgt.value, ast.Name) \
                            and tgt.value.id == name:
                        out.append(node)
        return out

    def _is_plain_jax_jit(self, func: ast.AST) -> bool:
        """The callee is jax.jit itself — not a local alias that
        resolves elsewhere (resolve-or-skip: ``jit = jax.jit if ... else
        self._jit_mesh_keyed`` is skipped, never guessed)."""
        dotted = _dotted(func)
        if dotted == "jax.jit":
            return True
        if isinstance(func, ast.Name) and func.id == "jit":
            # bare `jit`: only when nothing in the file rebinds it
            # (a from-import leaves no assignment)
            return func.id not in self.assigns and func.id not in self.defs
        return False

    def _file_has_mesh_context(self) -> bool:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) \
                    and _last(node.func) in (_MESH_CTORS | {"axis_rules"}):
                return True
        return False

    def _check_trace_capture(self) -> None:
        # (a) captured mutable module state / ambient config objects
        for fn in self._traced_fns():
            bound = _bound_names(fn)
            flagged: set = set()
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Name)
                        and isinstance(node.ctx, ast.Load)
                        and node.id not in bound
                        and node.id not in flagged):
                    continue
                val = self.module_assigns.get(node.id)
                if val is None:
                    continue
                mutable = isinstance(val, (ast.List, ast.Dict, ast.Set,
                                           ast.ListComp, ast.DictComp,
                                           ast.SetComp)) \
                    or (isinstance(val, ast.Call)
                        and _last(val.func) in _MUTABLE_CTORS)
                if mutable and self._mutation_sites(node.id):
                    flagged.add(node.id)
                    self.report(
                        "jit-trace-capture", node,
                        f"jitted {fn.name}() closes over {node.id!r}, "
                        "mutable module state that this module mutates "
                        "elsewhere; the traced value is frozen at "
                        "trace time — pass it as an argument")
                elif isinstance(val, ast.Call) \
                        and (_last(val.func) or "").endswith("Config"):
                    flagged.add(node.id)
                    self.report(
                        "jit-trace-capture", node,
                        f"jitted {fn.name}() closes over {node.id!r}, "
                        f"an ambient {_last(val.func)}(...) built at "
                        "module scope; config changes never retrace — "
                        "pass the fields you need as arguments")
        # (b) the PR 9 footgun: jitting a bound method of a shared
        # object in a file that builds meshes.  Bound methods of one
        # object hash equal, so two engines over different meshes share
        # one jaxpr cache entry — the first mesh wins silently.
        if not self._file_has_mesh_context():
            return
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call)
                    and self._is_plain_jax_jit(node.func)
                    and node.args
                    and isinstance(node.args[0], ast.Attribute)):
                continue
            recv = node.args[0].value
            # `self._meth` is per-instance (the accepted pattern);
            # `self.model._meth` / `model._meth` binds a *shared* object
            if isinstance(recv, ast.Name) and recv.id in ("self", "cls"):
                continue
            segs = _segments(recv)
            if segs and segs[-1] in self.import_names:
                continue  # module function, not a bound method
            self.report(
                "jit-trace-capture", node,
                f"jax.jit({_dotted(node.args[0]) or 'bound method'}) "
                "jits a bound method of a shared object while this "
                "module builds meshes: bound methods hash equal across "
                "instances, so jaxprs traced under one mesh are "
                "silently reused under another — wrap in a fresh "
                "per-instance closure (see engine._jit_mesh_keyed)")

    def _check_host_effects(self) -> None:
        for fn in self._traced_fns():
            bound = _bound_names(fn)
            for node in _fn_own_walk(fn):
                if isinstance(node, ast.Call):
                    callee = node.func
                    if isinstance(callee, ast.Name) \
                            and callee.id == "print":
                        self.report(
                            "jit-host-effect", node,
                            f"print() inside traced {fn.name}() runs "
                            "once at trace time, then never again — "
                            "use jax.debug.print / pl.debug_print")
                    elif isinstance(callee, ast.Name) \
                            and callee.id == "open":
                        self.report(
                            "jit-host-effect", node,
                            f"open() inside traced {fn.name}() is a "
                            "host IO effect executed only at trace "
                            "time")
                    elif _dotted(callee) in ("sys.stdout.write",
                                             "sys.stderr.write"):
                        self.report(
                            "jit-host-effect", node,
                            f"stdout/stderr write inside traced "
                            f"{fn.name}() happens only at trace time")
                    elif isinstance(callee, ast.Attribute) \
                            and callee.attr in _MUTATOR_METHODS \
                            and isinstance(callee.value, ast.Name) \
                            and callee.value.id not in bound:
                        self.report(
                            "jit-host-effect", node,
                            f"traced {fn.name}() mutates closed-over "
                            f"{callee.value.id!r} "
                            f"(.{callee.attr}(...)): the mutation "
                            "happens once at trace time, not per call")
                elif isinstance(node, ast.Global):
                    stored = {n.id for n in ast.walk(fn)
                              if isinstance(n, ast.Name)
                              and isinstance(n.ctx, (ast.Store, ast.Del))}
                    for gname in node.names:
                        if gname in stored:
                            self.report(
                                "jit-host-effect", node,
                                f"traced {fn.name}() rebinds global "
                                f"{gname!r}: the rebind executes at "
                                "trace time only")
                elif isinstance(node, (ast.Assign, ast.AugAssign)):
                    targets = node.targets \
                        if isinstance(node, ast.Assign) else [node.target]
                    for tgt in targets:
                        if isinstance(tgt, ast.Subscript) \
                                and isinstance(tgt.value, ast.Name) \
                                and tgt.value.id not in bound:
                            self.report(
                                "jit-host-effect", node,
                                f"traced {fn.name}() stores into "
                                f"closed-over {tgt.value.id!r}[...]: a "
                                "host-side container mutation frozen "
                                "at trace time")

    # -- allocator rule ----------------------------------------------------
    @staticmethod
    def _is_alloc_receiver(func: ast.Attribute) -> bool:
        return any("alloc" in seg.lower()
                   for seg in _segments(func.value))

    def _has_release(self, nodes: Sequence[ast.AST]) -> bool:
        for root in nodes:
            for node in ast.walk(root):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _RELEASE:
                    return True
        return False

    @staticmethod
    def _own_expr_nodes(stmt: ast.AST):
        """Expression nodes belonging to this statement itself —
        excluding nested statement bodies and nested scopes."""
        roots: List[ast.AST] = []
        for field, value in ast.iter_fields(stmt):
            if field in ("body", "orelse", "finalbody", "handlers"):
                continue
            if isinstance(value, ast.AST):
                roots.append(value)
            elif isinstance(value, list):
                roots.extend(v for v in value
                             if isinstance(v, ast.AST))
        stack = roots
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
                continue
            yield node
            stack.extend(ast.iter_child_nodes(node))

    def _check_alloc_discipline(self) -> None:
        self._walk_alloc(self.tree.body, try_stack=[])

    def _walk_alloc(self, body: Sequence[ast.AST],
                    try_stack: List[ast.Try]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                # a nested scope's body doesn't run inside this try
                self._walk_alloc(stmt.body, try_stack=[])
                continue
            if isinstance(stmt, ast.Try):
                self._walk_alloc(stmt.body, try_stack + [stmt])
                for h in stmt.handlers:
                    self._walk_alloc(h.body, try_stack)
                self._walk_alloc(stmt.orelse, try_stack)
                self._walk_alloc(stmt.finalbody, try_stack)
                continue
            # this statement's own expressions (nested statement bodies
            # are handled by the recursion below; lambda bodies only
            # *define* an acquire, they don't run it here)
            for node in self._own_expr_nodes(stmt):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _ACQUIRE \
                        and self._is_alloc_receiver(node.func) \
                        and try_stack:
                    guard = try_stack[-1]
                    unwinders: List[ast.AST] = list(guard.finalbody)
                    for h in guard.handlers:
                        unwinders.extend(h.body)
                    if not self._has_release(unwinders):
                        self.report(
                            "alloc-try-no-release", node,
                            f"'.{node.func.attr}(...)' acquires pages "
                            "inside a try whose handlers/finally never "
                            "call release/release_all — a failure here "
                            "leaks the reservation")
            # recurse into compound statements
            for attr in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, attr, None)
                if sub:
                    self._walk_alloc(sub, try_stack)


# ---------------------------------------------------------------------------
# project-level passes (determinism-taint, cache-lock-discipline)
# ---------------------------------------------------------------------------
def _project_findings(paths: Sequence[str]) -> List[Tuple[str, str, int,
                                                          int, str]]:
    """Raw (rule, path, line, col, message) tuples from the
    interprocedural passes over one fileset.  Pragma filtering is the
    caller's job (it owns the per-file pragma maps)."""
    proj = _df.build_project(paths)
    res = _df.Resolver(proj)
    out: List[Tuple[str, str, int, int, str]] = []
    taint = _df.TaintAnalysis(proj, res, _classify_taint_source, _SINKS,
                              _TAINT_BOUNDARIES)
    for tf in taint.run():
        out.append(("determinism-taint", tf.path, tf.line, tf.col,
                    tf.message))
    out.extend(_lock_findings(proj, res))
    return out


def _expr_mentions_path(expr: ast.AST, derived: set) -> bool:
    """Does ``expr`` reference ``self.path`` or a name derived from it?"""
    for n in ast.walk(expr):
        if isinstance(n, ast.Attribute) and n.attr == "path" \
                and isinstance(n.value, ast.Name) and n.value.id == "self":
            return True
        if isinstance(n, ast.Name) and n.id in derived:
            return True
    return False


def _path_derived_names(fn: ast.AST) -> set:
    """Local names assigned from expressions involving ``self.path``
    (transitively, two rounds cover every real chain)."""
    derived: set = set()
    for _ in range(2):
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and _expr_mentions_path(node.value, derived):
                derived.add(node.targets[0].id)
    return derived


def _scan_lock_method(ci: "_df.ClassInfo", fi: "_df.FunctionInfo"):
    """(writes, calls) for one method of a lock-owning class.

    writes: (node, description, lexically_locked)
    calls:  (same-class callee name, lexically_locked, node)
    """
    derived = _path_derived_names(fi.node)
    writes: List[Tuple[ast.AST, str, bool]] = []
    calls: List[Tuple[str, bool, ast.AST]] = []

    def is_lock_with(stmt: ast.AST) -> bool:
        return isinstance(stmt, (ast.With, ast.AsyncWith)) and any(
            isinstance(it.context_expr, ast.Call)
            and isinstance(it.context_expr.func, ast.Attribute)
            and it.context_expr.func.attr == "_file_lock"
            for it in stmt.items)

    def classify_expr(node: ast.AST, locked: bool) -> None:
        if not isinstance(node, ast.Call):
            return
        fname = _last(node.func)
        dotted = _dotted(node.func)
        if isinstance(node.func, ast.Name) and fname == "open" \
                and node.args:
            mode = "r"
            if len(node.args) > 1 and isinstance(node.args[1], ast.Constant):
                mode = str(node.args[1].value)
            for kw in node.keywords:
                if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
                    mode = str(kw.value.value)
            if any(c in mode for c in "wax+") \
                    and _expr_mentions_path(node.args[0], derived):
                writes.append((node, "cache-file open for writing",
                               locked))
        elif dotted in ("os.replace", "os.rename") and any(
                _expr_mentions_path(a, derived) for a in node.args):
            writes.append((node, f"{dotted}() onto the cache file",
                           locked))
        elif fname == "write_text" \
                and isinstance(node.func, ast.Attribute) \
                and _expr_mentions_path(node.func.value, derived):
            writes.append((node, "cache-file write_text()", locked))
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in _CACHE_MUTATORS \
                and isinstance(node.func.value, ast.Attribute) \
                and isinstance(node.func.value.value, ast.Name) \
                and node.func.value.value.id == "self":
            writes.append((node, f"self.{node.func.value.attr}"
                           f".{node.func.attr}(...) state mutation",
                           locked))
        elif isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == "self" \
                and node.func.attr in ci.methods:
            calls.append((node.func.attr, locked, node))

    def visit(stmts: Sequence[ast.AST], locked: bool) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            if is_lock_with(stmt):
                for it in stmt.items:
                    classify_expr(it.context_expr, locked)
                visit(stmt.body, True)
                continue
            # statement-level mutation targets: self.<attr>[...] = / del
            targets: List[ast.AST] = []
            if isinstance(stmt, ast.Assign):
                targets = list(stmt.targets)
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                targets = [stmt.target]
            elif isinstance(stmt, ast.Delete):
                targets = list(stmt.targets)
            for tgt in targets:
                if isinstance(tgt, ast.Subscript) \
                        and isinstance(tgt.value, ast.Attribute) \
                        and isinstance(tgt.value.value, ast.Name) \
                        and tgt.value.value.id == "self":
                    writes.append((stmt, f"self.{tgt.value.attr}[...] "
                                   "store", locked))
            for node in _FileLinter._own_expr_nodes(stmt):
                classify_expr(node, locked)
            for attr in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, attr, None)
                if sub:
                    visit(sub, locked)
            for h in getattr(stmt, "handlers", []) or []:
                visit(h.body, locked)

    visit(fi.node.body, False)
    return writes, calls


def _lock_findings(proj: "_df.Project", res: "_df.Resolver"
                   ) -> List[Tuple[str, str, int, int, str]]:
    """cache-lock-discipline: every cache write must be dominated by
    the sidecar flock, directly or through exclusively-locked callers."""
    out: List[Tuple[str, str, int, int, str]] = []
    for mod in proj.sorted_modules():
        for cname in sorted(mod.classes):
            ci = mod.classes[cname]
            if "_file_lock" not in ci.methods:
                continue
            info = {m: _scan_lock_method(ci, ci.methods[m])
                    for m in sorted(ci.methods)}
            callers: Dict[str, List[Tuple[str, bool]]] = {
                m: [] for m in info}
            for m, (_w, calls) in info.items():
                for callee, locked, _site in calls:
                    if callee in callers:
                        callers[callee].append((m, locked))
            # greatest fixpoint: m is "externally locked" iff it has at
            # least one resolved call site and every one holds the lock
            # (lexically, or because the caller is externally locked)
            eff = {m: bool(callers[m]) for m in info}
            changed = True
            while changed:
                changed = False
                for m in info:
                    if eff[m] and not all(
                            locked or eff.get(c, False)
                            for c, locked in callers[m]):
                        eff[m] = False
                        changed = True
            for m in sorted(info):
                if m == "_file_lock":
                    continue  # the lock implementation itself
                writes, _calls = info[m]
                for node, desc, locked in writes:
                    if locked or eff[m]:
                        continue
                    bad = sorted({c for c, lk in callers[m]
                                  if not (lk or eff.get(c, False))})
                    via = (f"reachable unlocked via "
                           f"{', '.join(c + '()' for c in bad)}"
                           if bad else f"{m}() is an unlocked entry "
                           "point")
                    out.append((
                        "cache-lock-discipline", ci.module.path,
                        node.lineno, getattr(node, "col_offset", 0),
                        f"{desc} in {ci.name}.{m}() outside `with "
                        f"self._file_lock():` — {via}"))
    return out


# ---------------------------------------------------------------------------
# file discovery + CLI
# ---------------------------------------------------------------------------
def _lint_fileset(files: Sequence[Path]) -> List[Finding]:
    """Per-file rules + interprocedural passes over one fileset, with
    pragma filtering applied uniformly."""
    findings: List[Finding] = []
    pragma_maps: Dict[str, Dict[int, Optional[FrozenSet[str]]]] = {}
    parsed_paths: List[str] = []
    for f in files:
        path = str(f)
        source = Path(f).read_text(encoding="utf-8")
        try:
            tree = ast.parse(source, filename=path)
        except SyntaxError as exc:
            findings.append(Finding(
                rule="syntax-error", severity="error", path=path,
                line=exc.lineno or 1, col=exc.offset or 0,
                message=f"file does not parse: {exc.msg}"))
            continue
        pragma_maps[path] = _pragmas(source)
        parsed_paths.append(path)
        findings.extend(_FileLinter(path, tree, source).run())
    if parsed_paths:
        for rule, path, line, col, msg in _project_findings(parsed_paths):
            suppressed = pragma_maps.get(path, {}).get(line, frozenset())
            if suppressed is None or rule in suppressed:
                continue
            findings.append(Finding(rule=rule, severity=RULES[rule][0],
                                    path=path, line=line, col=col,
                                    message=msg))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule,
                                 f.message))
    return findings


def lint_file(path: Path) -> List[Finding]:
    """Lint one file: per-file rules plus the interprocedural passes
    run over the single-module project (intra-file chains resolve)."""
    return _lint_fileset([Path(path)])


def _discover(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(
                f for f in path.rglob("*.py")
                if "__pycache__" not in f.parts))
        elif path.suffix == ".py":
            out.append(path)
    return out


def lint_paths(paths: Sequence[str]) -> Tuple[List[Finding], int]:
    files = _discover(paths)
    return _lint_fileset(files), len(files)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="jit/Pallas/allocator static lint (JSON output)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories (default: src/repro, "
                         "falling back to '.')")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when any finding (error or warning) "
                         "survives pragmas")
    ap.add_argument("--compact", action="store_true",
                    help="single-line JSON (default pretty-prints)")
    ap.add_argument("--format", choices=("json", "github"),
                    default="json",
                    help="output format: machine-readable JSON "
                         "(default) or GitHub workflow-command "
                         "annotations (exit codes unchanged)")
    args = ap.parse_args(argv)

    paths = args.paths
    if not paths:
        default = Path("src/repro")
        paths = [str(default)] if default.is_dir() else ["."]

    findings, n_files = lint_paths(paths)
    n_err = sum(1 for f in findings if f.severity == "error")
    n_warn = len(findings) - n_err
    if args.format == "github":
        # workflow commands: one annotation per finding, a notice with
        # the totals; still deterministic, still exit 1 under --check
        for f in findings:
            kind = "error" if f.severity == "error" else "warning"
            msg = (f.message.replace("%", "%25")
                   .replace("\r", "%0D").replace("\n", "%0A"))
            sys.stdout.write(
                f"::{kind} file={f.path},line={f.line},col={f.col},"
                f"title={f.rule}::{msg}\n")
        sys.stdout.write(
            f"::notice title=lint::checked {n_files} files: "
            f"{n_err} errors, {n_warn} warnings\n")
    else:
        doc = {
            "version": 1,
            "files_checked": n_files,
            "n_errors": n_err,
            "n_warnings": n_warn,
            "findings": [f.to_dict() for f in findings],
        }
        json.dump(doc, sys.stdout,
                  indent=None if args.compact else 2, sort_keys=True)
        sys.stdout.write("\n")
    if args.check and findings:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
