"""Repo-wide static lint for jit/Pallas/allocator discipline.

Pure stdlib-``ast`` analysis — nothing here imports jax or executes repo
code, so the lint runs in CI before any accelerator is touched.  Three
rule families, each encoding a contract this codebase actually relies on:

jit retrace hazards (the engine holds 11 jit sites; a retrace per step
silently turns a served model into a compiler benchmark):

* ``jit-static-missing``    — a name listed in ``static_argnames`` that is
  not a parameter of the jitted function: jax raises only when the arg is
  passed, so the typo hides until a call site exercises it.
* ``jit-static-mutable-default`` — a static parameter whose default is a
  mutable literal (list/dict/set): unhashable the first time the default
  is used, and a shared-state bug besides.
* ``jit-traced-str-default`` — a parameter *not* marked static whose
  default is a ``str`` literal: strings cannot be traced, so the default
  aborts at trace time (or forces a retrace per distinct value when
  threaded through).

``pallas_call`` contract checks (Mosaic reports arity mismatches as deep
lowering errors, long after the mistake):

* ``pallas-operand-arity``  — the immediate call's operand count must be
  ``num_scalar_prefetch + len(in_specs)``.
* ``pallas-index-map-arity`` — every ``BlockSpec`` index_map lambda must
  take ``len(grid) + num_scalar_prefetch`` arguments.
* ``pallas-kernel-arity``   — the kernel's positional (ref) parameters
  must number ``num_scalar_prefetch + n_in + n_out + n_scratch``
  (``functools.partial`` keyword bindings and keyword-only config
  parameters are excluded; positional partial bindings consume leading
  slots).
* ``pallas-vmem-scratch``   — (warning) constant-shaped ``pltpu.VMEM``
  scratch totalling more than the per-core VMEM budget.

Allocator discipline (a page group leaked on an error path silently
shrinks every later run's pool):

* ``alloc-try-no-release``  — an acquire call (``reserve`` / ``extend`` /
  ``share`` / ``try_alloc`` / ``cow_split``) on an allocator-looking
  receiver, lexically inside a ``try`` body whose handlers/finally never
  call ``release``/``release_all``.

Mesh/sharding discipline (the serve engine jits against whatever mesh is
active; sharding mistakes surface as silent replication, not errors):

* ``jit-mesh-closure``      — a jitted function closing over a
  module-level name bound to a concrete ``Mesh`` / ``NamedSharding`` /
  ``make_mesh(...)``: the jit cache never keys on the closure, so a
  topology change silently reuses executables compiled for the old
  grid.  Pass the mesh (or shardings derived from it) as an argument.
* ``constrain-unknown-axis`` — a string logical-axis name passed to
  ``constrain(...)`` / ``spec_for_shape(...)`` that no entry of
  ``repro.dist.sharding.RULE_PRESETS`` (or the deliberate
  ``REPLICATED_AXES`` set) knows: every preset drops the axis, so the
  dimension silently replicates on every mesh — the typo class
  ``spec_for_shape``'s drop-unknown semantics can never raise on.

Every check is *resolve-or-skip*: when a piece (grid length, spec list,
kernel def, static names) is not statically resolvable, the site is
skipped rather than guessed at — findings are high-confidence by
construction.  False positives are suppressed per line with a same-line
pragma::

    alloc.reserve(rid, n)  # lint: ignore[alloc-try-no-release]
    risky_call()           # lint: ignore          (all rules)

Usage (machine-readable JSON on stdout)::

    python -m repro.analysis.lint src/repro            # report
    python -m repro.analysis.lint --check src/repro    # CI gate: exit 1
                                                       # on any finding
"""
from __future__ import annotations

import argparse
import ast
import json
import re
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, FrozenSet, List, Optional, Sequence, Tuple

__all__ = ["Finding", "RULES", "lint_file", "lint_paths", "main"]

# rule -> (severity, one-line description)
RULES: Dict[str, Tuple[str, str]] = {
    "jit-static-missing": (
        "error", "static_argnames entry is not a parameter of the "
                 "jitted function"),
    "jit-static-mutable-default": (
        "error", "static parameter has a mutable (unhashable) default"),
    "jit-traced-str-default": (
        "error", "traced parameter has a str default (untraceable; "
                 "retrace hazard)"),
    "pallas-operand-arity": (
        "error", "pallas_call operand count != num_scalar_prefetch + "
                 "len(in_specs)"),
    "pallas-index-map-arity": (
        "error", "index_map arity != len(grid) + num_scalar_prefetch"),
    "pallas-kernel-arity": (
        "error", "kernel positional params != prefetch + inputs + "
                 "outputs + scratch"),
    "pallas-vmem-scratch": (
        "warning", "constant VMEM scratch shapes exceed the per-core "
                   "VMEM budget"),
    "alloc-try-no-release": (
        "error", "allocator acquire inside try with no release on the "
                 "unwind path"),
    "jit-mesh-closure": (
        "error", "jitted function closes over a concrete "
                 "Mesh/NamedSharding instead of taking it as an "
                 "argument"),
    "constrain-unknown-axis": (
        "error", "logical axis name that no sharding rules preset maps "
                 "(the dimension would silently replicate)"),
}

try:  # single source of truth when the package is importable
    from repro.autotune.space import VMEM_BYTES
except Exception:  # pragma: no cover - standalone invocation
    VMEM_BYTES = 16 * 2 ** 20

try:  # the axis registry the constrain-unknown-axis rule checks against
    from repro.dist.sharding import KNOWN_LOGICAL_AXES
except Exception:  # pragma: no cover - standalone invocation
    KNOWN_LOGICAL_AXES = frozenset({
        "batch", "cap", "conv_dim", "embed", "embed_fsdp", "expert_ff",
        "experts", "ff", "head_dim", "heads", "kv_heads", "seq",
        "seq_res", "vocab"})

_ACQUIRE = frozenset({"reserve", "extend", "share", "try_alloc",
                      "cow_split"})
_RELEASE = frozenset({"release", "release_all"})

# constructors whose module-level result a jitted function must not
# close over (jit-mesh-closure)
_MESH_CTORS = frozenset({"Mesh", "NamedSharding", "make_mesh"})

_DTYPE_BYTES = {
    "float64": 8, "int64": 8, "uint64": 8,
    "float32": 4, "int32": 4, "uint32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2, "uint16": 2,
    "int8": 1, "uint8": 1, "bool_": 1, "bool": 1,
}

_PRAGMA_RE = re.compile(r"#\s*lint:\s*ignore(?:\[([^\]]*)\])?")


@dataclass(frozen=True)
class Finding:
    rule: str
    severity: str
    path: str
    line: int
    col: int
    message: str

    def to_dict(self) -> Dict[str, Any]:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line, "col": self.col,
                "message": self.message}


# ---------------------------------------------------------------------------
# small AST helpers (resolve-or-None everywhere)
# ---------------------------------------------------------------------------
def _dotted(node: ast.AST) -> Optional[str]:
    """'jax.jit' for Attribute chains / Names; None when unresolvable."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def _last(node: ast.AST) -> Optional[str]:
    d = _dotted(node)
    return d.rsplit(".", 1)[-1] if d else None


def _segments(node: ast.AST) -> List[str]:
    """All name segments along an attribute chain, skipping opaque parts
    (calls, subscripts) — 'self._alloc[i].reserve' -> [self, _alloc]."""
    out: List[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            out.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Name):
            out.append(node.id)
            return out
        elif isinstance(node, (ast.Subscript, ast.Call)):
            node = node.value if isinstance(node, ast.Subscript) \
                else node.func
        else:
            return out


def _str_elements(node: ast.AST) -> Optional[List[str]]:
    """A str literal or tuple/list of str literals -> the names."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant)
                    and isinstance(e.value, str)):
                return None
            out.append(e.value)
        return out
    return None


def _int_elements(node: ast.AST) -> Optional[List[int]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant)
                    and isinstance(e.value, int)):
                return None
            out.append(e.value)
        return out
    return None


def _positional_params(fn: ast.FunctionDef) -> List[str]:
    return [a.arg for a in fn.args.posonlyargs + fn.args.args]


def _all_params(fn: ast.FunctionDef) -> List[str]:
    return (_positional_params(fn)
            + [a.arg for a in fn.args.kwonlyargs])


def _defaults_by_name(fn: ast.FunctionDef) -> Dict[str, ast.AST]:
    out: Dict[str, ast.AST] = {}
    pos = fn.args.posonlyargs + fn.args.args
    for name, default in zip([a.arg for a in pos[-len(fn.args.defaults):]]
                             if fn.args.defaults else [],
                             fn.args.defaults):
        out[name] = default
    for a, d in zip(fn.args.kwonlyargs, fn.args.kw_defaults):
        if d is not None:
            out[a.arg] = d
    return out


def _bound_names(fn: ast.FunctionDef) -> set:
    """Every name the function binds locally (params, assignment and
    loop targets, nested defs, imports, lambda params): a reference to
    anything else reads the enclosing scope — a closure."""
    bound = set(_all_params(fn))
    for a in (fn.args.vararg, fn.args.kwarg):
        if a is not None:
            bound.add(a.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) \
                and isinstance(node.ctx, (ast.Store, ast.Del)):
            bound.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            bound.add(node.name)
            if not isinstance(node, ast.ClassDef):
                bound.update(_all_params(node))
        elif isinstance(node, ast.Lambda):
            bound.update(a.arg for a in node.args.posonlyargs
                         + node.args.args + node.args.kwonlyargs)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            bound.update((alias.asname or alias.name).split(".")[0]
                         for alias in node.names)
        elif isinstance(node, ast.ExceptHandler) and node.name:
            bound.add(node.name)
    return bound


def _axis_literals(node: ast.AST) -> List[Tuple[str, ast.AST]]:
    """(name, node) for every string literal in an axes argument,
    descending into tuple/list entries; non-literal elements are
    skipped (resolve-or-skip, per element)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [(node.value, node)]
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[Tuple[str, ast.AST]] = []
        for e in node.elts:
            out.extend(_axis_literals(e))
        return out
    return []


def _pragmas(source: str) -> Dict[int, Optional[FrozenSet[str]]]:
    """line (1-based) -> frozenset of suppressed rules, or None = all."""
    out: Dict[int, Optional[FrozenSet[str]]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        if "lint:" not in text:
            continue
        m = _PRAGMA_RE.search(text)
        if not m:
            continue
        rules = m.group(1)
        if rules is None:
            out[i] = None
        else:
            out[i] = frozenset(
                r.strip() for r in rules.split(",") if r.strip())
    return out


# ---------------------------------------------------------------------------
# per-file linter
# ---------------------------------------------------------------------------
class _FileLinter:
    def __init__(self, path: str, tree: ast.Module, source: str):
        self.path = path
        self.tree = tree
        self.pragmas = _pragmas(source)
        self.findings: List[Finding] = []
        # name -> def / simple-assignment value, for resolve-by-name.
        # File-global and last-wins: a heuristic, but resolution failure
        # only ever *skips* a check, and kernel names are file-unique.
        self.defs: Dict[str, ast.FunctionDef] = {}
        self.assigns: Dict[str, ast.AST] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.defs[node.name] = node
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                self.assigns[node.targets[0].id] = node.value

    # -- plumbing ----------------------------------------------------------
    def report(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 1)
        suppressed = self.pragmas.get(line, frozenset())
        if suppressed is None or rule in suppressed:
            return
        self.findings.append(Finding(
            rule=rule, severity=RULES[rule][0], path=self.path,
            line=line, col=getattr(node, "col_offset", 0),
            message=message))

    def run(self) -> List[Finding]:
        self._check_jit_sites()
        self._check_pallas_sites()
        self._check_alloc_discipline()
        self._check_mesh_closure()
        self._check_constrain_axes()
        self.findings.sort(key=lambda f: (f.line, f.col, f.rule))
        return self.findings

    # -- jit rules ---------------------------------------------------------
    def _jit_sites(self):
        """Yield (jitted FunctionDef, static-names set | None, site node).

        statics None means the site had no resolvable static spec and
        only the bare-jit checks apply; unresolvable *targets* are not
        yielded at all.
        """
        for fn in self.defs.values():
            for deco in fn.decorator_list:
                statics = self._statics_from_decorator(deco, fn)
                if statics is not None:
                    yield fn, statics, deco
        for node in ast.walk(self.tree):
            if not (isinstance(node, ast.Call)
                    and _last(node.func) == "jit"
                    and node.args):
                continue
            target = node.args[0]
            fn = None
            if isinstance(target, ast.Name):
                fn = self.defs.get(target.id)
            if fn is None:
                continue  # attribute/call targets: skip, don't guess
            statics = self._parse_statics(node.keywords, fn)
            if statics is not None:
                yield fn, statics, node

    def _statics_from_decorator(self, deco, fn):
        # @jax.jit
        if _last(deco) == "jit":
            return set()
        if not isinstance(deco, ast.Call):
            return None
        # @functools.partial(jax.jit, static_argnames=...)
        if _last(deco.func) == "partial" and deco.args \
                and _last(deco.args[0]) == "jit":
            return self._parse_statics(deco.keywords, fn)
        # @jax.jit(static_argnames=...)  (decorator-factory form)
        if _last(deco.func) == "jit":
            return self._parse_statics(deco.keywords, fn)
        return None

    def _parse_statics(self, keywords, fn):
        """static names from jit(...) keywords; None = unresolvable."""
        names: set = set()
        positional = _positional_params(fn)
        for kw in keywords:
            if kw.arg == "static_argnames":
                got = _str_elements(kw.value)
                if got is None:
                    return None
                names.update(got)
            elif kw.arg == "static_argnums":
                nums = _int_elements(kw.value)
                if nums is None:
                    return None
                for n in nums:
                    if 0 <= n < len(positional):
                        names.add(positional[n])
                    else:
                        return None  # out of range: let jax complain
        return names

    def _check_jit_sites(self) -> None:
        seen = set()
        for fn, statics, site in self._jit_sites():
            key = (fn.name, id(site))
            if key in seen:
                continue
            seen.add(key)
            params = set(_all_params(fn))
            has_var = fn.args.vararg is not None \
                or fn.args.kwarg is not None
            defaults = _defaults_by_name(fn)
            for s in sorted(statics):
                if s not in params and not has_var:
                    self.report(
                        "jit-static-missing", site,
                        f"static_argnames entry {s!r} is not a "
                        f"parameter of {fn.name}()")
            for name, default in defaults.items():
                if name in statics and isinstance(
                        default, (ast.List, ast.Dict, ast.Set)):
                    self.report(
                        "jit-static-mutable-default", default,
                        f"static parameter {name!r} of {fn.name}() has "
                        "a mutable default (unhashable under jit)")
                if name not in statics \
                        and isinstance(default, ast.Constant) \
                        and isinstance(default.value, str):
                    self.report(
                        "jit-traced-str-default", default,
                        f"parameter {name!r} of {fn.name}() defaults "
                        f"to str {default.value!r} but is not in "
                        "static_argnames")

    # -- pallas rules ------------------------------------------------------
    def _check_pallas_sites(self) -> None:
        immediate: Dict[int, ast.Call] = {}
        pallas_calls: List[ast.Call] = []
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            if _last(node.func) == "pallas_call":
                pallas_calls.append(node)
            elif isinstance(node.func, ast.Call) \
                    and _last(node.func.func) == "pallas_call":
                immediate[id(node.func)] = node
        for pc in pallas_calls:
            self._check_one_pallas(pc, immediate.get(id(pc)))

    def _grid_spec_fields(self, pc: ast.Call):
        """(k, grid_node, in_specs, out_specs, out_shape, scratch) with
        None for any field that is absent or unresolvable; k None means
        the whole spec is opaque."""
        fields = {kw.arg: kw.value for kw in pc.keywords if kw.arg}
        k: Optional[int] = 0
        spec = fields.get("grid_spec")
        if spec is not None:
            if not (isinstance(spec, ast.Call)
                    and _last(spec.func) == "PrefetchScalarGridSpec"):
                return None, None, None, None, None, None
            inner = {kw.arg: kw.value for kw in spec.keywords if kw.arg}
            n = inner.get("num_scalar_prefetch")
            if isinstance(n, ast.Constant) and isinstance(n.value, int):
                k = n.value
            elif n is not None:
                k = None
            fields = dict(fields)
            fields.update(inner)
        return (k, fields.get("grid"), fields.get("in_specs"),
                fields.get("out_specs"), fields.get("out_shape"),
                fields.get("scratch_shapes"))

    @staticmethod
    def _spec_count(node: Optional[ast.AST]) -> Optional[int]:
        if node is None:
            return None
        if isinstance(node, (ast.List, ast.Tuple)):
            return len(node.elts)
        if isinstance(node, ast.Call):  # single BlockSpec / SDS
            return 1
        return None

    @staticmethod
    def _index_maps(node: Optional[ast.AST]) -> List[ast.Lambda]:
        """index_map lambdas of the BlockSpec(s) in node."""
        specs: List[ast.AST] = []
        if isinstance(node, (ast.List, ast.Tuple)):
            specs = list(node.elts)
        elif isinstance(node, ast.Call):
            specs = [node]
        out: List[ast.Lambda] = []
        for s in specs:
            if not (isinstance(s, ast.Call)
                    and _last(s.func) == "BlockSpec"):
                continue
            cand: Optional[ast.AST] = None
            if len(s.args) > 1:
                cand = s.args[1]
            else:
                for kw in s.keywords:
                    if kw.arg == "index_map":
                        cand = kw.value
            if isinstance(cand, ast.Lambda):
                out.append(cand)
        return out

    def _resolve_kernel(self, node: ast.AST, depth: int = 0):
        """(FunctionDef, n_positional_bound, keyword-bound names) | None."""
        if depth > 4:
            return None
        if isinstance(node, ast.Name):
            if node.id in self.defs:
                return self.defs[node.id], 0, set()
            target = self.assigns.get(node.id)
            return None if target is None \
                else self._resolve_kernel(target, depth + 1)
        if isinstance(node, ast.Call) and _last(node.func) == "partial" \
                and node.args:
            inner = self._resolve_kernel(node.args[0], depth + 1)
            if inner is None:
                return None
            fn, n_pos, kw_names = inner
            return (fn, n_pos + len(node.args) - 1,
                    kw_names | {kw.arg for kw in node.keywords
                                if kw.arg})
        return None

    def _scratch_bytes(self, node: Optional[ast.AST]) -> Optional[int]:
        """Total bytes of VMEM scratch, when every shape is constant."""
        if not isinstance(node, (ast.List, ast.Tuple)) or not node.elts:
            return None
        total = 0
        for e in node.elts:
            if not (isinstance(e, ast.Call) and _last(e.func) == "VMEM"
                    and len(e.args) >= 2):
                return None
            dims = _int_elements(e.args[0])
            dtype = _last(e.args[1])
            if dims is None or dtype not in _DTYPE_BYTES:
                return None
            n = _DTYPE_BYTES[dtype]
            for d in dims:
                n *= d
            total += n
        return total

    def _check_one_pallas(self, pc: ast.Call,
                          operands: Optional[ast.Call]) -> None:
        k, grid, in_specs, out_specs, out_shape, scratch = \
            self._grid_spec_fields(pc)
        grid_len = len(grid.elts) \
            if isinstance(grid, (ast.Tuple, ast.List)) else None
        n_in = self._spec_count(in_specs)
        n_out = self._spec_count(out_specs)
        if n_out is None:
            n_out = self._spec_count(out_shape)
        n_scratch = self._spec_count(scratch)
        if n_scratch is None and scratch is None:
            n_scratch = 0

        # pallas-index-map-arity
        if k is not None and grid_len is not None:
            want = grid_len + k
            for lam in (self._index_maps(in_specs)
                        + self._index_maps(out_specs)):
                if lam.args.vararg is not None:
                    continue
                got = len(lam.args.posonlyargs) + len(lam.args.args)
                if got != want:
                    self.report(
                        "pallas-index-map-arity", lam,
                        f"index_map takes {got} args; grid has "
                        f"{grid_len} dims + {k} scalar-prefetch "
                        f"operands = {want} expected")

        # pallas-operand-arity
        if operands is not None and k is not None and n_in is not None \
                and not any(isinstance(a, ast.Starred)
                            for a in operands.args) \
                and not operands.keywords:
            want = k + n_in
            got = len(operands.args)
            if got != want:
                self.report(
                    "pallas-operand-arity", operands,
                    f"pallas_call invoked with {got} operands; "
                    f"{k} scalar-prefetch + {n_in} in_specs = "
                    f"{want} expected")

        # pallas-kernel-arity
        if pc.args and None not in (k, n_in, n_out, n_scratch):
            resolved = self._resolve_kernel(pc.args[0])
            if resolved is not None:
                fn, n_bound, kw_bound = resolved
                if fn.args.vararg is None:
                    slots = [p for p in _positional_params(fn)
                             if p not in kw_bound][n_bound:]
                    want = k + n_in + n_out + n_scratch
                    if len(slots) != want:
                        self.report(
                            "pallas-kernel-arity", pc,
                            f"kernel {fn.name}() exposes {len(slots)} "
                            f"positional ref params; {k} prefetch + "
                            f"{n_in} in + {n_out} out + {n_scratch} "
                            f"scratch = {want} expected")

        # pallas-vmem-scratch (warning)
        total = self._scratch_bytes(scratch)
        if total is not None and total > VMEM_BYTES:
            self.report(
                "pallas-vmem-scratch", scratch,
                f"VMEM scratch totals {total / 2**20:.1f} MiB, over "
                f"the {VMEM_BYTES / 2**20:.0f} MiB per-core budget")

    # -- mesh/sharding rules -----------------------------------------------
    def _mesh_value(self, name: str, depth: int = 0) -> Optional[ast.Call]:
        """The Mesh/NamedSharding/make_mesh constructor call a
        module-level name resolves to, through simple aliasing, or
        None."""
        if depth > 4:
            return None
        val = self.assigns.get(name)
        if isinstance(val, ast.Call) and _last(val.func) in _MESH_CTORS:
            return val
        if isinstance(val, ast.Name):
            return self._mesh_value(val.id, depth + 1)
        return None

    def _check_mesh_closure(self) -> None:
        seen = set()
        for fn, _statics, _site in self._jit_sites():
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            bound = _bound_names(fn)
            flagged: set = set()
            for node in ast.walk(fn):
                if not (isinstance(node, ast.Name)
                        and isinstance(node.ctx, ast.Load)
                        and node.id not in bound
                        and node.id not in flagged):
                    continue
                val = self._mesh_value(node.id)
                if val is not None:
                    flagged.add(node.id)
                    self.report(
                        "jit-mesh-closure", node,
                        f"jitted {fn.name}() closes over {node.id!r}, "
                        f"a concrete {_last(val.func)}(...) built at "
                        "module scope; the jit cache never keys on a "
                        "closure, so a topology change reuses stale "
                        "executables — pass it as an argument")

    def _check_constrain_axes(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            name = _last(node.func)
            if name == "constrain":
                axis_args = node.args[1:]
            elif name == "spec_for_shape" and len(node.args) >= 2:
                axis_args = [node.args[1]]
            else:
                continue
            for arg in axis_args:
                for axis, anode in _axis_literals(arg):
                    if axis not in KNOWN_LOGICAL_AXES:
                        self.report(
                            "constrain-unknown-axis", anode,
                            f"logical axis {axis!r} is in no "
                            "RULE_PRESETS entry (nor REPLICATED_AXES): "
                            "every preset would drop it and the "
                            "dimension silently replicates")

    # -- allocator rule ----------------------------------------------------
    @staticmethod
    def _is_alloc_receiver(func: ast.Attribute) -> bool:
        return any("alloc" in seg.lower()
                   for seg in _segments(func.value))

    def _has_release(self, nodes: Sequence[ast.AST]) -> bool:
        for root in nodes:
            for node in ast.walk(root):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _RELEASE:
                    return True
        return False

    @staticmethod
    def _own_expr_nodes(stmt: ast.AST):
        """Expression nodes belonging to this statement itself —
        excluding nested statement bodies and nested scopes."""
        roots: List[ast.AST] = []
        for field, value in ast.iter_fields(stmt):
            if field in ("body", "orelse", "finalbody", "handlers"):
                continue
            if isinstance(value, ast.AST):
                roots.append(value)
            elif isinstance(value, list):
                roots.extend(v for v in value
                             if isinstance(v, ast.AST))
        stack = roots
        while stack:
            node = stack.pop()
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
                continue
            yield node
            stack.extend(ast.iter_child_nodes(node))

    def _check_alloc_discipline(self) -> None:
        self._walk_alloc(self.tree.body, try_stack=[])

    def _walk_alloc(self, body: Sequence[ast.AST],
                    try_stack: List[ast.Try]) -> None:
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                # a nested scope's body doesn't run inside this try
                self._walk_alloc(stmt.body, try_stack=[])
                continue
            if isinstance(stmt, ast.Try):
                self._walk_alloc(stmt.body, try_stack + [stmt])
                for h in stmt.handlers:
                    self._walk_alloc(h.body, try_stack)
                self._walk_alloc(stmt.orelse, try_stack)
                self._walk_alloc(stmt.finalbody, try_stack)
                continue
            # this statement's own expressions (nested statement bodies
            # are handled by the recursion below; lambda bodies only
            # *define* an acquire, they don't run it here)
            for node in self._own_expr_nodes(stmt):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _ACQUIRE \
                        and self._is_alloc_receiver(node.func) \
                        and try_stack:
                    guard = try_stack[-1]
                    unwinders: List[ast.AST] = list(guard.finalbody)
                    for h in guard.handlers:
                        unwinders.extend(h.body)
                    if not self._has_release(unwinders):
                        self.report(
                            "alloc-try-no-release", node,
                            f"'.{node.func.attr}(...)' acquires pages "
                            "inside a try whose handlers/finally never "
                            "call release/release_all — a failure here "
                            "leaks the reservation")
            # recurse into compound statements
            for attr in ("body", "orelse", "finalbody"):
                sub = getattr(stmt, attr, None)
                if sub:
                    self._walk_alloc(sub, try_stack)


# ---------------------------------------------------------------------------
# file discovery + CLI
# ---------------------------------------------------------------------------
def lint_file(path: Path) -> List[Finding]:
    source = path.read_text(encoding="utf-8")
    try:
        tree = ast.parse(source, filename=str(path))
    except SyntaxError as exc:
        return [Finding(rule="syntax-error", severity="error",
                        path=str(path), line=exc.lineno or 1,
                        col=exc.offset or 0,
                        message=f"file does not parse: {exc.msg}")]
    return _FileLinter(str(path), tree, source).run()


def _discover(paths: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(
                f for f in path.rglob("*.py")
                if "__pycache__" not in f.parts))
        elif path.suffix == ".py":
            out.append(path)
    return out


def lint_paths(paths: Sequence[str]) -> Tuple[List[Finding], int]:
    files = _discover(paths)
    findings: List[Finding] = []
    for f in files:
        findings.extend(lint_file(f))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings, len(files)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="jit/Pallas/allocator static lint (JSON output)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories (default: src/repro, "
                         "falling back to '.')")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when any finding (error or warning) "
                         "survives pragmas")
    ap.add_argument("--compact", action="store_true",
                    help="single-line JSON (default pretty-prints)")
    args = ap.parse_args(argv)

    paths = args.paths
    if not paths:
        default = Path("src/repro")
        paths = [str(default)] if default.is_dir() else ["."]

    findings, n_files = lint_paths(paths)
    n_err = sum(1 for f in findings if f.severity == "error")
    n_warn = len(findings) - n_err
    doc = {
        "version": 1,
        "files_checked": n_files,
        "n_errors": n_err,
        "n_warnings": n_warn,
        "findings": [f.to_dict() for f in findings],
    }
    json.dump(doc, sys.stdout,
              indent=None if args.compact else 2)
    sys.stdout.write("\n")
    if args.check and findings:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
