"""ACTS applied to our own Pallas kernels: block-size autotuning.

See README.md in this package for the cache design and usage; the short
version:

    from repro import autotune
    autotune.autotune_kernel("flash_attention",
                             {"B": 1, "S": 2048, "SK": 2048, "H": 16,
                              "KV": 4, "D": 128},
                             dtype="bfloat16", budget=16)

tunes the kernel's tiling with the ordinary ACTS tuner and persists the
winner; afterwards every ``repro.kernels.ops`` call with that problem shape
picks the tuned blocks up automatically.
"""
from .api import (
    SERVE_SYSTEM,
    TRAIN_SYSTEM,
    autotune_kernel,
    backend_name,
    cached_blocks,
    cached_serve_config,
    cached_train_config,
    ensure_tuned,
    nearest_mesh_serve_config,
    put_serve_config,
    put_train_config,
    resolve_blocks,
    serve_config_candidates,
)
from .cache import (AutotuneCache, SCHEMA_VERSION, default_cache,
                    mesh_distance, mesh_sig, nearest_mesh, parse_mesh_sig,
                    reset_default_cache)
from .space import KERNELS, KernelSpace, shape_sig
from .sut import KernelSUT

__all__ = [
    "AutotuneCache",
    "KERNELS",
    "KernelSUT",
    "KernelSpace",
    "SCHEMA_VERSION",
    "SERVE_SYSTEM",
    "TRAIN_SYSTEM",
    "autotune_kernel",
    "backend_name",
    "cached_blocks",
    "cached_serve_config",
    "cached_train_config",
    "default_cache",
    "ensure_tuned",
    "mesh_distance",
    "mesh_sig",
    "nearest_mesh",
    "nearest_mesh_serve_config",
    "parse_mesh_sig",
    "put_serve_config",
    "put_train_config",
    "reset_default_cache",
    "resolve_blocks",
    "serve_config_candidates",
    "shape_sig",
]
