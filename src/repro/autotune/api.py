"""ACTS-driven kernel autotuning: tune, persist, resolve.

The flow mirrors the paper's architecture end to end:

    tune:     ``autotune_kernel`` runs the ordinary ACTS ``Tuner`` (LHS +
              RRS under a test budget) over a ``KernelSpace`` with a
              ``KernelSUT``, then persists the winner.
    persist:  ``AutotuneCache`` keys the result by (kernel, shape
              signature, dtype, backend) in one JSON file.
    resolve:  ``resolve_blocks`` is the cheap read path the kernel entry
              points (``repro.kernels.ops``) call when no explicit block
              override is given — cache hit wins, builtin default
              otherwise.  After the first disk read it is a dict lookup.
"""
from __future__ import annotations

import logging
from typing import Any, Dict, Optional

from .cache import AutotuneCache, default_cache, mesh_sig, nearest_mesh
from .space import KERNELS, KernelSpace, shape_sig
from .sut import KernelSUT

__all__ = ["autotune_kernel", "ensure_tuned", "resolve_blocks",
           "cached_blocks", "backend_name", "put_serve_config",
           "cached_serve_config", "serve_config_candidates",
           "nearest_mesh_serve_config", "SERVE_SYSTEM",
           "put_train_config", "cached_train_config", "TRAIN_SYSTEM"]

logger = logging.getLogger("repro.autotune")

# The serve engine's and train step's tuned knobs persist in the same
# AutotuneCache under these pseudo-kernel names (the joint co-tuning
# mode's winners) — one file keeps every tuned co-deployment artifact.
SERVE_SYSTEM = "serve_engine"
TRAIN_SYSTEM = "train_step"

# cache paths already warned about (resolve_blocks warns once per path)
_warned_cache_paths: set = set()


def backend_name() -> str:
    try:
        import jax

        return jax.default_backend()
    except Exception:  # pragma: no cover - jax always importable here
        return "unknown"


def cached_blocks(kernel: str, dims: Dict[str, int], dtype: str,
                  cache: Optional[AutotuneCache] = None,
                  backend: Optional[str] = None) -> Optional[Dict[str, Any]]:
    """The tuned block config for this problem, or None if never tuned."""
    sig = shape_sig(KernelSpace(kernel).validate_dims(dims))
    cache = default_cache() if cache is None else cache  # not `or`: an empty cache is falsy (__len__)
    return cache.get_config(kernel, sig, dtype,
                            backend or backend_name())


def resolve_blocks(kernel: str, dims: Dict[str, int], dtype: str,
                   defaults: Dict[str, Any],
                   cache: Optional[AutotuneCache] = None) -> Dict[str, Any]:
    """Tuned config if the cache has one, else the builtin defaults.

    A failed *lookup* (unreadable or structurally corrupt cache entry)
    falls back to the defaults — but loudly, once per cache path: a bare
    ``except`` here used to mask cache corruption and programming errors
    as silent default tilings.  Caller errors (unknown kernel, missing
    signature dims) are validated up front and propagate, as does anything
    outside the expected lookup-failure set.
    """
    # surface call-site programming errors before touching the cache
    KernelSpace(kernel).validate_dims(dims)
    cache = default_cache() if cache is None else cache  # not `or`: an empty cache is falsy (__len__)
    try:
        tuned = cached_blocks(kernel, dims, dtype, cache=cache)
    except (OSError, KeyError, ValueError, TypeError) as exc:
        if cache.path not in _warned_cache_paths:
            _warned_cache_paths.add(cache.path)
            logger.warning(
                "autotune cache lookup failed for kernel %r (%s: %s); "
                "falling back to builtin block defaults — check the cache "
                "file at %s", kernel, type(exc).__name__, exc, cache.path)
        return dict(defaults)
    if tuned:
        out = dict(defaults)
        out.update({k: tuned[k] for k in defaults if k in tuned})
        return out
    return dict(defaults)


def put_serve_config(sig_dims: Dict[str, int], dtype: str,
                     config: Dict[str, Any], value: float,
                     cache: Optional[AutotuneCache] = None,
                     backend: Optional[str] = None,
                     meta: Optional[Dict[str, Any]] = None,
                     workload: str = "", mesh: str = "") -> str:
    """Persist a tuned serve-engine knob config (the joint mode's winner).

    Keyed like a kernel entry — (``SERVE_SYSTEM``, model-shape signature,
    dtype, backend) — so serve knobs and kernel blocks live in one cache
    file.  ``workload`` is the fingerprint signature the knobs were
    tuned under (``repro.serve.workload.fingerprint_sig``); empty means
    workload-generic, the offline mode's entry.  ``mesh`` is the device
    topology the knobs were tuned for (a ``(data, model)`` shape or
    signature string; empty = single device) — since schema v4 a winner
    tuned at one device count never resolves at another.  Returns the
    shape signature used.
    """
    sig = shape_sig({k: int(v) for k, v in sig_dims.items()})
    cache = default_cache() if cache is None else cache  # not `or`: an empty cache is falsy (__len__)
    cache.put(SERVE_SYSTEM, sig, dtype, backend or backend_name(),
              dict(config), value, meta=meta, workload=workload,
              mesh=mesh_sig(mesh) if mesh else "")
    return sig


def cached_serve_config(sig_dims: Dict[str, int], dtype: str,
                        cache: Optional[AutotuneCache] = None,
                        backend: Optional[str] = None,
                        workload: str = "", mesh: str = ""
                        ) -> Optional[Dict[str, Any]]:
    """The tuned serve-engine knobs for this model shape (at this exact
    workload signature and mesh topology; generic single-device when
    omitted), or None."""
    sig = shape_sig({k: int(v) for k, v in sig_dims.items()})
    cache = default_cache() if cache is None else cache  # not `or`: an empty cache is falsy (__len__)
    return cache.get_config(SERVE_SYSTEM, sig, dtype,
                            backend or backend_name(), workload=workload,
                            mesh=mesh_sig(mesh) if mesh else "")


def serve_config_candidates(sig_dims: Dict[str, int], dtype: str,
                            cache: Optional[AutotuneCache] = None,
                            backend: Optional[str] = None,
                            mesh: str = ""
                            ) -> Dict[str, Dict[str, Any]]:
    """Every cached serve winner at this model shape and mesh topology,
    keyed by workload signature (``-`` = generic) — the nearest-
    signature transfer set."""
    sig = shape_sig({k: int(v) for k, v in sig_dims.items()})
    cache = default_cache() if cache is None else cache  # not `or`: an empty cache is falsy (__len__)
    return cache.scan_workloads(SERVE_SYSTEM, sig, dtype,
                                backend or backend_name(),
                                mesh=mesh_sig(mesh) if mesh else "")


def nearest_mesh_serve_config(sig_dims: Dict[str, int], dtype: str,
                              mesh: str,
                              cache: Optional[AutotuneCache] = None,
                              backend: Optional[str] = None,
                              workload: str = ""
                              ) -> Optional[Dict[str, Any]]:
    """Warm-start donor lookup across device topologies.

    Exact-mesh hit wins; on a miss the cached winner at the NEAREST mesh
    signature (``repro.autotune.cache.mesh_distance``) is returned as a
    donor — annotated with ``donor_mesh``/``mesh_distance`` so callers
    can tell a transferred seed from a native winner and must re-tune
    before persisting it at the new topology.  None when nothing is
    cached at any mesh for this shape/workload.
    """
    target = mesh_sig(mesh) if mesh else "1dev"
    sig = shape_sig({k: int(v) for k, v in sig_dims.items()})
    cache = default_cache() if cache is None else cache  # not `or`: an empty cache is falsy (__len__)
    backend = backend or backend_name()
    exact = cache.get(SERVE_SYSTEM, sig, dtype, backend,
                      workload=workload, mesh=target)
    if exact is not None:
        return dict(exact, donor_mesh=target, mesh_distance=0.0)
    donors = cache.scan_meshes(SERVE_SYSTEM, sig, dtype, backend,
                               workload=workload)
    near = nearest_mesh(donors, target)
    if near is None:
        return None
    donor, dist = near
    return dict(donors[donor], donor_mesh=donor, mesh_distance=dist)


def put_train_config(sig_dims: Dict[str, int], dtype: str,
                     config: Dict[str, Any], value: float,
                     cache: Optional[AutotuneCache] = None,
                     backend: Optional[str] = None,
                     meta: Optional[Dict[str, Any]] = None) -> str:
    """Persist tuned train-step knobs (the live joint mode's third winner).

    Keyed (``TRAIN_SYSTEM``, workload-shape signature, dtype, backend) —
    train knobs live in the same cache file as kernel blocks and the
    serve-config entry.  Returns the signature used.
    """
    sig = shape_sig({k: int(v) for k, v in sig_dims.items()})
    cache = default_cache() if cache is None else cache  # not `or`: an empty cache is falsy (__len__)
    cache.put(TRAIN_SYSTEM, sig, dtype, backend or backend_name(),
              dict(config), value, meta=meta)
    return sig


def cached_train_config(sig_dims: Dict[str, int], dtype: str,
                        cache: Optional[AutotuneCache] = None,
                        backend: Optional[str] = None
                        ) -> Optional[Dict[str, Any]]:
    """The tuned train-step knobs for this workload shape, or None."""
    sig = shape_sig({k: int(v) for k, v in sig_dims.items()})
    cache = default_cache() if cache is None else cache  # not `or`: an empty cache is falsy (__len__)
    return cache.get_config(TRAIN_SYSTEM, sig, dtype,
                            backend or backend_name())


def autotune_kernel(
    kernel: str,
    dims: Dict[str, int],
    dtype: str = "float32",
    budget: int = 16,
    mode: Optional[str] = None,
    interpret: Optional[bool] = None,
    seed: int = 0,
    cache: Optional[AutotuneCache] = None,
    optimizer: str = "rrs",
    verbose: bool = False,
) -> Dict[str, Any]:
    """Run ACTS over one kernel × problem signature and persist the winner.

    Returns a summary dict {kernel, sig, config, value, n_tests, mode}.
    """
    from repro.core.tuner import Tuner

    sut = KernelSUT(kernel, dims, dtype=dtype, mode=mode,
                    interpret=interpret, seed=seed)
    report = Tuner(sut.space(), sut, budget=budget, optimizer=optimizer,
                   seed=seed, verbose=verbose).run()
    cache = default_cache() if cache is None else cache  # not `or`: an empty cache is falsy (__len__)
    sig = shape_sig(sut.dims)
    summary = {
        "kernel": kernel,
        "sig": sig,
        "dtype": dtype,
        "backend": backend_name(),
        "config": dict(report.best_config),
        "value": report.best_metric.value,
        "default_value": report.default_metric.value,
        "n_tests": report.n_tests,
        "n_infeasible_pruned": report.n_infeasible_pruned,
        "mode": sut.mode,
    }
    cache.put(kernel, sig, dtype, summary["backend"], summary["config"],
              summary["value"],
              meta={"mode": sut.mode, "n_tests": report.n_tests,
                    "n_infeasible_pruned": report.n_infeasible_pruned,
                    "default_value": summary["default_value"]})
    return summary


def ensure_tuned(kernel: str, dims: Dict[str, int], dtype: str = "float32",
                 budget: int = 16, cache: Optional[AutotuneCache] = None,
                 **kw: Any) -> Dict[str, Any]:
    """Cache hit → return it; miss → tune now and persist."""
    cache = default_cache() if cache is None else cache  # not `or`: an empty cache is falsy (__len__)
    tuned = cached_blocks(kernel, dims, dtype, cache=cache)
    if tuned is not None:
        return tuned
    return autotune_kernel(kernel, dims, dtype=dtype, budget=budget,
                           cache=cache, **kw)["config"]
