"""Persistent kernel-autotune cache.

One JSON file maps ``kernel|shape-signature|dtype|backend`` to the tuned
block configuration (plus the measured/modelled cost and provenance).  The
file is the contract between the tuning side (``repro.autotune.autotune_
kernel``, ``python -m repro.launch.tune --tune-kernels``) and the consuming
side (``repro.kernels.ops`` resolves block defaults through it; the serve
engine and the dry-run's ``RunKnobs`` consult it for their shapes).

Location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune.json``.  Writes are atomic (tmp + ``os.replace``)
so concurrent tuning jobs cannot corrupt the file, and every write
merges-on-save: under an exclusive ``flock`` on a sidecar lock file, the
cache file is re-read and unioned with the in-memory view before the
replace — two processes tuning different systems into one cache file keep
each other's entries (the lock serializes the read-merge-replace; on
filesystems without working ``flock``, e.g. some NFS mounts, the merge
still narrows the lost-update window to the replace itself).  Per-key
conflicts stay last-writer-wins, acceptable because entries are
deterministic for a given machine.
"""
from __future__ import annotations

import contextlib
import json
import math
import os
import re
import threading
import time
from typing import Any, Dict, Optional, Tuple

try:  # POSIX cross-process file locking; absent on some platforms
    import fcntl
except ImportError:  # pragma: no cover - linux container always has it
    fcntl = None

__all__ = ["AutotuneCache", "SCHEMA_VERSION", "default_cache",
           "reset_default_cache", "mesh_sig", "parse_mesh_sig",
           "mesh_distance", "nearest_mesh"]

# Bump whenever the key schema changes meaning.  v2: flash_attention
# signatures gained the SK (KV sequence length) dim — v1 entries were keyed
# without it, so cross-attention / cache-prefill problems with different KV
# lengths collided on one entry.  v3: every key gained a trailing
# workload-signature component (``-`` = workload-generic) so serve winners
# tuned under different live request mixes coexist; v2 entries carry the
# same meaning at the generic signature, so ``_load``/``_save`` MIGRATE
# them (rewritten under ``v3|...|-``) instead of dropping them — only
# pre-v2 keys remain unresolvable and disappear on the next write.
# v4: keys gained a trailing device/mesh-signature component (``1dev`` =
# single device) so winners tuned at one device count / mesh orientation
# never silently deploy at another; every v3 entry was tuned on one
# device, so it migrates in place to ``v4|...|1dev``.
SCHEMA_VERSION = 4

# ---------------------------------------------------------------------------
# mesh signatures: the device-topology component of every v4 cache key
# ---------------------------------------------------------------------------
def mesh_sig(shape: Any = None) -> str:
    """Canonical device/mesh signature for a cache key.

    ``shape`` is a ``(data, model)`` mesh shape (the serve engine's
    orientation), an existing signature string, or ``None``/``(1, 1)``
    for the single-device case — all spellings of one device collapse to
    ``"1dev"`` so offline tuning and migrated v3 entries share one key.
    """
    if shape is None:
        return "1dev"
    if isinstance(shape, str):
        parsed = parse_mesh_sig(shape)
        if parsed is None:
            raise ValueError(f"not a mesh signature: {shape!r}")
        return mesh_sig(parsed)
    data, model = (int(shape[0]), int(shape[1]))
    if data < 1 or model < 1:
        raise ValueError(f"mesh shape must be positive, got {shape!r}")
    if data * model == 1:
        return "1dev"
    return f"d{data}m{model}"


def parse_mesh_sig(sig: str) -> Optional[Tuple[int, int]]:
    """``(data, model)`` for a mesh signature, or None for anything that
    is not one (other key components included)."""
    if sig == "1dev":
        return (1, 1)
    m = re.fullmatch(r"d(\d+)m(\d+)", str(sig))
    if m is None:
        return None
    data, model = int(m.group(1)), int(m.group(2))
    if data < 1 or model < 1:
        return None
    return (data, model)


def mesh_distance(a: str, b: str) -> float:
    """Topology distance between two mesh signatures: the sum of per-axis
    log2 size gaps.  Same mesh is 0; growing one axis 2x costs 1; the
    replicas-vs-TP orientation flip at equal device count (``d2m1`` vs
    ``d1m2``) costs 2 — a donor at the same orientation is always closer
    than the transposed mesh."""
    pa, pb = parse_mesh_sig(a), parse_mesh_sig(b)
    if pa is None or pb is None:
        return float("inf")
    return (abs(math.log2(pa[0]) - math.log2(pb[0]))
            + abs(math.log2(pa[1]) - math.log2(pb[1])))


def nearest_mesh(candidates: Any, target: str
                 ) -> Optional[Tuple[str, float]]:
    """The candidate mesh signature nearest ``target`` (and its
    distance), or None when no candidate parses.  Ties break on sorted
    signature order, so warm-start donor selection is deterministic."""
    best: Optional[Tuple[float, str]] = None
    for sig in sorted(set(candidates)):
        d = mesh_distance(sig, target)
        if math.isfinite(d) and (best is None or d < best[0]):
            best = (d, sig)
    if best is None:
        return None
    return best[1], best[0]


def _default_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


class AutotuneCache:
    """(kernel, shape, dtype, backend) -> tuned block config, on disk."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or _default_path()
        self._lock = threading.Lock()
        self._data: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------------
    @staticmethod
    def key(kernel: str, sig: str, dtype: str, backend: str,
            workload: str = "", mesh: str = "") -> str:
        """The canonical cache key.  Every component is coerced through
        ``str`` and the workload signature is ``|``-sanitized, so keys
        serialize identically from every producer — a formatting mismatch
        here is a silent cache miss (and, since v3, one the
        nearest-signature fallback would quietly paper over).
        ``workload`` defaults to ``-``: the workload-generic entry
        offline tuning writes and migrated v2 entries land on.
        ``mesh`` defaults to ``1dev``: the single-device signature
        offline tuning writes and migrated v3 entries land on."""
        w = str(workload or "-").replace("|", "/")
        m = mesh_sig(mesh) if mesh else "1dev"
        return (f"v{SCHEMA_VERSION}|{kernel}|{sig}|{str(dtype)}"
                f"|{str(backend)}|{w}|{m}")

    @staticmethod
    def _upgrade(key: str) -> Optional[str]:
        """The current-schema key a stored key maps to, or None.

        Identity for current and NEWER schemas (a shared cache file
        touched by binaries of different versions must not lose the
        newer entries — they are inert here, lookups only ever use the
        current prefix).  v3 keys migrate to v4 under the single-device
        ``1dev`` mesh signature (they were tuned on one device — same
        meaning, new shape); v2 keys additionally gain the generic ``-``
        workload signature.  Anything older (unversioned v1 included) is
        unresolvable: None.
        """
        head = key.split("|", 1)[0]
        if not head.startswith("v"):
            return None  # v1 keys carried no version
        try:
            version = int(head[1:])
        except ValueError:
            return None
        if version >= SCHEMA_VERSION:
            return key
        parts = key.split("|")
        if version == 3 and len(parts) == 6:
            # v3|kernel|sig|dtype|backend|workload
            return "|".join([f"v{SCHEMA_VERSION}"] + parts[1:] + ["1dev"])
        if version == 2 and len(parts) == 5:
            # v2|kernel|sig|dtype|backend
            return "|".join([f"v{SCHEMA_VERSION}"] + parts[1:]
                            + ["-", "1dev"])
        return None

    @classmethod
    def _stale(cls, key: str) -> bool:
        """True for keys that neither resolve nor migrate (pre-v2)."""
        return cls._upgrade(key) is None

    @classmethod
    def _migrate(cls, raw: Dict[str, Any]) -> Dict[str, Any]:
        """Raw file contents -> current-schema view: stale keys drop,
        v2 keys are rewritten in place (the migration), and a native
        current-schema key always wins over a migrated one (second pass
        overwrites), so re-tuned entries are never shadowed by their
        pre-migration ancestors."""
        out: Dict[str, Any] = {}
        for k, v in raw.items():
            nk = cls._upgrade(k)
            if nk is not None and nk != k:
                out[nk] = v
        for k, v in raw.items():
            if cls._upgrade(k) == k:
                out[k] = v
        return out

    def _load(self) -> Dict[str, Any]:
        if self._data is None:
            try:
                with open(self.path) as f:
                    raw = json.load(f)
            except (FileNotFoundError, json.JSONDecodeError):
                raw = {}
            # Migrate/invalidate entries from older key schemas: v2
            # entries re-key to the current schema here (and physically
            # on the next _save); pre-v2 entries drop.
            self._data = self._migrate(raw)
        return self._data

    def reload(self) -> None:
        """Drop the in-memory view and re-read the file on next access."""
        with self._lock:
            self._data = None

    # ------------------------------------------------------------------
    def get(self, kernel: str, sig: str, dtype: str, backend: str,
            workload: str = "", mesh: str = "") -> Optional[Dict[str, Any]]:
        """The cached entry ({config, value, ...}) or None."""
        with self._lock:
            entry = self._load().get(self.key(kernel, sig, dtype, backend,
                                              workload, mesh))
        return dict(entry) if entry else None

    def get_config(self, kernel: str, sig: str, dtype: str, backend: str,
                   workload: str = "", mesh: str = ""
                   ) -> Optional[Dict[str, Any]]:
        entry = self.get(kernel, sig, dtype, backend, workload, mesh)
        return dict(entry["config"]) if entry else None

    def scan_workloads(self, kernel: str, sig: str, dtype: str,
                       backend: str, mesh: str = ""
                       ) -> Dict[str, Dict[str, Any]]:
        """Every entry at this (kernel, shape, dtype, backend, mesh),
        keyed by its workload-signature component (``-`` = workload-
        generic) — the candidate set the online retuner's nearest-
        signature transfer searches.  Scoped to ONE mesh signature:
        workload transfer never crosses device topologies (that is
        ``scan_meshes``'s job, and an explicit warm-start decision)."""
        parts = self.key(kernel, sig, dtype, backend, "\0", mesh).split("|")
        head, tail = parts[:5], parts[6]
        with self._lock:
            data = self._load()
            out: Dict[str, Dict[str, Any]] = {}
            for k, v in data.items():
                kp = k.split("|")
                if len(kp) == 7 and kp[:5] == head and kp[6] == tail:
                    out[kp[5]] = dict(v)
            return out

    def scan_meshes(self, kernel: str, sig: str, dtype: str,
                    backend: str, workload: str = ""
                    ) -> Dict[str, Dict[str, Any]]:
        """Every entry at this (kernel, shape, dtype, backend, workload),
        keyed by its mesh-signature component — the donor set
        ``nearest_mesh`` warm-start transfer searches when no winner
        exists at the deployment's own topology."""
        parts = self.key(kernel, sig, dtype, backend, workload).split("|")
        head = parts[:6]
        with self._lock:
            data = self._load()
            out: Dict[str, Dict[str, Any]] = {}
            for k, v in data.items():
                kp = k.split("|")
                if len(kp) == 7 and kp[:6] == head:
                    out[kp[6]] = dict(v)
            return out

    def put(self, kernel: str, sig: str, dtype: str, backend: str,
            config: Dict[str, Any], value: float,
            meta: Optional[Dict[str, Any]] = None,
            workload: str = "", mesh: str = "") -> None:
        with self._lock:
            key = self.key(kernel, sig, dtype, backend, workload, mesh)
            entry = {
                "config": dict(config),
                "value": float(value),
                "meta": dict(meta or {}),
                "time": time.time(),
            }
            # save only the modified key: overlaying the whole in-memory
            # view would revert keys another process re-tuned since our
            # load (value-level lost update, not just key-level); _save
            # refreshes the in-memory view to the merged result.
            self._save({key: entry})

    @contextlib.contextmanager
    def _file_lock(self):
        """Exclusive cross-process lock over the read-merge-replace window
        (sidecar ``.lock`` file; the cache file itself is replaced, so it
        cannot carry the lock)."""
        if fcntl is None:  # pragma: no cover - non-POSIX fallback
            yield
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        fd = os.open(f"{self.path}.lock", os.O_CREAT | os.O_RDWR, 0o644)
        locked = False
        try:
            try:
                fcntl.flock(fd, fcntl.LOCK_EX)
                locked = True
            except OSError:
                # No working lock manager (e.g. some NFS mounts): proceed
                # unlocked — the merge still narrows the lost-update
                # window to the read-merge-replace itself.
                pass
            yield
        finally:
            if locked:
                fcntl.flock(fd, fcntl.LOCK_UN)
            os.close(fd)

    def _save(self, delta: Dict[str, Any]) -> None:
        """Write-temp-then-replace, merging concurrent writers' entries.

        ``delta`` holds ONLY the keys this writer modified.  Another
        process may have written the file since our in-memory view was
        loaded; dumping that whole view would silently erase its new keys
        (the classic lost update) or revert keys it re-tuned to our stale
        values.  Under the cross-process file lock the file is re-read and
        only the delta overlaid: our modified keys win, every other key
        keeps whatever the file now holds, older-schema keys migrate
        (v2) or stay dropped (pre-v2), and the in-memory view is
        refreshed to the merged state so subsequent gets observe the
        file's reality.
        """
        with self._file_lock():
            try:
                with open(self.path) as f:
                    disk = json.load(f)
            except (FileNotFoundError, json.JSONDecodeError):
                disk = {}
            merged = self._migrate(disk)
            merged.update(delta)
            self._data = merged
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            tmp = f"{self.path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(merged, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)

    def __len__(self) -> int:
        with self._lock:
            return len(self._load())


_default: Optional[AutotuneCache] = None
_default_lock = threading.Lock()


def default_cache() -> AutotuneCache:
    global _default
    with _default_lock:
        if _default is None or _default.path != _default_path():
            _default = AutotuneCache()
        return _default


def reset_default_cache() -> None:
    """Forget the process-wide cache object (tests repoint the env var)."""
    global _default
    with _default_lock:
        _default = None
