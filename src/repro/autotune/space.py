"""Kernel block-configuration spaces + analytic cost models.

``KernelSpace`` turns each Pallas kernel's tiling knobs into an ACTS
``ParameterSpace`` so the ordinary tuner stack (LHS + RRS, budget, cache,
report) drives kernel autotuning exactly like it drives MySQL knobs — the
paper's architecture pointed at our own hot path.

Per kernel: the knob space, an input builder for a problem signature, a
call adapter, and a roofline-style cost model.  The model is the CPU-side
stand-in for wall-clock timing (interpret-mode timings are meaningless for
TPU performance): it scores a block config by grid-step overhead + per-tile
MXU/VPU time + HBM streaming, with hard VMEM-capacity infeasibility and
sublane/lane alignment penalties (TPU tiles are (8/16/32, 128) — see the
Pallas guide).  On real TPU hardware the ``time`` mode measures instead.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.params import EnumParam, ParameterSpace

__all__ = ["KernelSpace", "KERNELS", "shape_sig"]

VMEM_BYTES = 16 * 2**20  # per-core VMEM (v5e-class)
MXU_FLOPS_PER_S = 394e12 * 0.5  # bf16 peak derated
HBM_BYTES_PER_S = 819e9
GRID_STEP_OVERHEAD_S = 1.5e-6  # per grid step (dispatch + DMA setup)


def shape_sig(dims: Dict[str, int]) -> str:
    """Canonical problem signature, e.g. ``B2_D64_H4_KV2_S256``."""
    return "_".join(f"{k}{int(v)}" for k, v in sorted(dims.items()))


def _dtype_bytes(dtype: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2}[dtype]


def _sublane(dtype: str) -> int:
    return {"float32": 8, "bfloat16": 16, "float16": 16}[dtype]


def _align_penalty(block: int, dtype: str) -> float:
    """Mosaic pads tiles to (sublane, 128); fractional-tile waste factor."""
    sub = _sublane(dtype)
    padded = math.ceil(block / sub) * sub
    return padded / max(block, 1)


def _dispatch_s(config: Dict[str, Any], n_steps: float,
                tile_elems: float) -> float:
    """Grid-scheduling time under the shared launch knobs.

    ``dim_semantics``: marking the non-reduction grid dims "parallel"
    lets Mosaic split them across the two TPU cores (megacore), halving
    the serialized step count.  ``num_warps`` is the GPU-lowering
    occupancy hint: more warps amortize per-step dispatch ~sqrt(n) but
    pay linear scheduling overhead, and a tile too small to feed them
    (``tile_elems``) caps the effective count — so the optimum co-moves
    with the block-size knobs instead of saturating at the rail.
    """
    steps = n_steps
    if config.get("dim_semantics", "arbitrary") == "parallel":
        steps = n_steps / 2.0
    nw = int(config.get("num_warps", 4))
    eff = min(nw, max(1.0, tile_elems / 2048.0))
    per_step = GRID_STEP_OVERHEAD_S * (1.0 + 0.08 * nw) / math.sqrt(eff)
    return steps * per_step


def _roofline_s(flops: float, hbm_bytes: float, n_steps: float,
                vmem_bytes: float, config: Optional[Dict[str, Any]] = None,
                tile_elems: float = 0.0) -> float:
    if vmem_bytes > VMEM_BYTES:
        return math.inf  # tile set does not fit on-chip
    compute = flops / MXU_FLOPS_PER_S
    stream = hbm_bytes / HBM_BYTES_PER_S
    if config is None:
        dispatch = n_steps * GRID_STEP_OVERHEAD_S
    else:
        dispatch = _dispatch_s(config, n_steps, tile_elems)
    return max(compute, stream) + dispatch


# ---------------------------------------------------------------------------
# per-kernel definitions
# ---------------------------------------------------------------------------
_POW2_BLOCKS = (16, 32, 64, 128, 256, 512)

# Shared launch knobs (ROADMAP PR-1 open item): every kernel space carries
# the Mosaic grid dimension-semantics choice, threaded to every kernel's
# ``pltpu.TPUCompilerParams`` and through the cost model's ``_dispatch_s``
# term, so ACTS tunes it jointly with the block sizes.  The GPU num_warps
# occupancy hint is *plumbed* (every kernel and ``_dispatch_s`` accept
# it) but joins a tune space only on backends whose lowering consumes it
# — none today: on TPU it is inert, and an inert axis in ``mode="time"``
# would spend wall-clock budget re-measuring identical kernels.
def _with_launch_knobs(params: list, warps: bool = False) -> ParameterSpace:
    params = params + [EnumParam("dim_semantics",
                                 ("arbitrary", "parallel"), "parallel")]
    if warps:
        params.append(EnumParam("num_warps", (2, 4, 8), 4))
    return ParameterSpace(params)


@dataclass(frozen=True)
class KernelDef:
    name: str
    dims: Tuple[str, ...]  # required signature dims
    knobs: Tuple[str, ...]
    make_space: Callable[[], ParameterSpace]
    make_inputs: Callable[[Dict[str, int], str, np.random.Generator], tuple]
    call: Callable[[tuple, Dict[str, Any], bool], Any]
    model_cost: Callable[[Dict[str, Any], Dict[str, int], str], float]
    # (config, dims, dtype) -> VMEM bytes of the kernel's resident tile
    # set.  The SINGLE source of the cost model's hard infeasibility
    # (``_roofline_s`` returns inf iff this exceeds ``VMEM_BYTES``) and of
    # the static feasibility predicate (``repro.analysis.feasibility``) —
    # sharing the function is what keeps ``feasible(cfg) ⇔ cost < inf``
    # exact instead of a re-derivation that drifts.
    vmem_footprint: Callable[[Dict[str, Any], Dict[str, int], str], float]


def _rand(rng, shape, dtype):
    import jax.numpy as jnp

    return jnp.asarray(rng.normal(size=shape), jnp.float32).astype(
        {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
         "float16": jnp.float16}[dtype])


# -- flash attention ---------------------------------------------------------
def _fa_space() -> ParameterSpace:
    return _with_launch_knobs([
        EnumParam("block_q", _POW2_BLOCKS, 128),
        EnumParam("block_kv", _POW2_BLOCKS, 128),
    ])


def _fa_inputs(d, dtype, rng):
    q = _rand(rng, (d["B"], d["S"], d["H"], d["D"]), dtype)
    k = _rand(rng, (d["B"], d["SK"], d["KV"], d["D"]), dtype)
    v = _rand(rng, (d["B"], d["SK"], d["KV"], d["D"]), dtype)
    return q, k, v


def _launch_kw(config) -> Dict[str, Any]:
    """The shared launch knobs, passed through to every kernel call so
    ``mode="time"`` wall-clocks what the knobs actually change on TPU."""
    return {"dimension_semantics": config.get("dim_semantics"),
            "num_warps": config.get("num_warps")}


def _fa_call(inputs, config, interpret):
    from repro.kernels.flash_attention import flash_attention_pallas

    q, k, v = inputs
    # SK >= S: queries sit at the end of the KV stream (cache-prefill
    # semantics).  SK < S is encoder-decoder cross-attention — no causal
    # structure exists there, so time it unmasked rather than handing the
    # kernel a negative offset.
    causal = k.shape[1] >= q.shape[1]
    q_offset = k.shape[1] - q.shape[1] if causal else 0
    return flash_attention_pallas(q, k, v, causal=causal,
                                  q_offset=q_offset,
                                  block_q=config["block_q"],
                                  block_kv=config["block_kv"],
                                  interpret=interpret,
                                  **_launch_kw(config))


def _fa_vmem(config, d, dtype):
    """Resident tiles: q + double-buffered k/v blocks + f32 m/l/acc rows."""
    bq = min(config["block_q"], d["S"])
    bk = min(config["block_kv"], d["SK"])
    ib = _dtype_bytes(dtype)
    return (bq * d["D"] + 2 * bk * d["D"]) * ib + bq * (2 + d["D"]) * 4


def _fa_cost(config, d, dtype):
    B, S, SK, H, D = d["B"], d["S"], d["SK"], d["H"], d["D"]
    bq = min(config["block_q"], S)
    bk = min(config["block_kv"], SK)
    nq, nk = math.ceil(S / bq), math.ceil(SK / bk)
    n_steps = B * H * nq * nk
    # causal reachability: with the queries at the end of the KV stream
    # (q_offset = SK - S), row i of S sees SK - S + i + 1 keys; averaging
    # gives the live tile-pair fraction below (0.55 at SK == S, -> 1 as the
    # cached prefix dominates).  SK < S is cross-attention: unmasked, so
    # every tile pair is live (matches _fa_call's causal choice).
    frac = max(0.15, 1.0 - 0.45 * S / SK) if SK >= S else 1.0
    live = frac * n_steps
    pad = _align_penalty(bq, dtype) * _align_penalty(bk, dtype)
    flops = live * (4.0 * bq * bk * D) * pad
    ib = _dtype_bytes(dtype)
    hbm = (B * H * nq * bq * D * ib          # q tiles
           + 2.0 * live * bk * D * ib        # streamed k/v tiles
           + B * H * S * D * ib)             # output (S query rows)
    vmem = _fa_vmem(config, d, dtype)
    return _roofline_s(flops, hbm, n_steps, vmem, config, bq * bk)


# -- decode attention --------------------------------------------------------
def _fd_space() -> ParameterSpace:
    return _with_launch_knobs([
        EnumParam("block_kv", (32, 64, 128, 256, 512, 1024), 256),
    ])


def _fd_inputs(d, dtype, rng):
    q = _rand(rng, (d["B"], d["H"], d["D"]), dtype)
    k = _rand(rng, (d["B"], d["S"], d["KV"], d["D"]), dtype)
    v = _rand(rng, (d["B"], d["S"], d["KV"], d["D"]), dtype)
    return q, k, v, d["S"]


def _fd_call(inputs, config, interpret):
    from repro.kernels.decode_attention import flash_decode_pallas

    q, k, v, kv_len = inputs
    return flash_decode_pallas(q, k, v, kv_len,
                               block_kv=config["block_kv"],
                               interpret=interpret,
                               **_launch_kw(config))


def _fd_vmem(config, d, dtype):
    """Resident tiles: k/v blocks + per-group f32 m/l/acc + query group."""
    G = max(d["H"] // d["KV"], 1)
    bk = min(config["block_kv"], d["S"])
    ib = _dtype_bytes(dtype)
    return 2 * bk * d["D"] * ib + G * (2 + d["D"]) * 4 + G * d["D"] * ib


def _fd_cost(config, d, dtype):
    B, S, H, KV, D = d["B"], d["S"], d["H"], d["KV"], d["D"]
    G = max(H // KV, 1)
    bk = min(config["block_kv"], S)
    nk = math.ceil(S / bk)
    n_steps = B * KV * nk
    ib = _dtype_bytes(dtype)
    flops = n_steps * 4.0 * G * bk * D * _align_penalty(bk, dtype)
    hbm = 2.0 * B * KV * nk * bk * D * ib  # stream the cache once
    vmem = _fd_vmem(config, d, dtype)
    return _roofline_s(flops, hbm, n_steps, vmem, config, bk * D)


# -- gated linear attention --------------------------------------------------
def _gla_space() -> ParameterSpace:
    return _with_launch_knobs([
        EnumParam("chunk", (16, 32, 64, 128, 256), 128),
    ])


def _gla_inputs(d, dtype, rng):
    q = _rand(rng, (d["B"], d["S"], d["H"], d["DK"]), dtype)
    k = _rand(rng, (d["B"], d["S"], d["H"], d["DK"]), dtype)
    v = _rand(rng, (d["B"], d["S"], d["H"], d["DV"]), dtype)
    import jax.numpy as jnp

    g = jnp.asarray(-np.abs(rng.normal(size=(d["B"], d["S"], d["H"])) * 0.3),
                    jnp.float32)
    return q, k, v, g


def _gla_call(inputs, config, interpret):
    from repro.kernels.gla import gla_pallas

    q, k, v, g = inputs
    return gla_pallas(q, k, v, g, chunk=config["chunk"],
                      interpret=interpret, **_launch_kw(config))[0]


def _gla_vmem(config, d, dtype):
    """Resident tiles: q/k/v/g chunk + f32 recurrent state + (L,L) scores."""
    L = min(config["chunk"], d["S"])
    DK, DV = d["DK"], d["DV"]
    ib = _dtype_bytes(dtype)
    return (L * (2 * DK + 2 * DV) + L) * ib + DK * DV * 4 + L * L * 4


def _gla_cost(config, d, dtype):
    B, S, H, DK, DV = d["B"], d["S"], d["H"], d["DK"], d["DV"]
    L = min(config["chunk"], S)
    nc = math.ceil(S / L)
    n_steps = B * H * nc
    ib = _dtype_bytes(dtype)
    pad = _align_penalty(L, dtype)
    # intra-chunk (L,L)x(L,dv) + qk^T + state update, all MXU work
    flops = n_steps * (2.0 * L * L * DK + 2.0 * L * L * DV
                       + 4.0 * L * DK * DV) * pad
    hbm = n_steps * L * (2 * DK + 2 * DV + 1) * ib
    vmem = _gla_vmem(config, d, dtype)
    return _roofline_s(flops, hbm, n_steps, vmem, config, L * L)


# -- rmsnorm -----------------------------------------------------------------
def _rn_space() -> ParameterSpace:
    return _with_launch_knobs([
        EnumParam("block_rows", (8, 16, 32, 64, 128, 256, 512, 1024), 256),
    ])


def _rn_inputs(d, dtype, rng):
    x = _rand(rng, (d["ROWS"], d["D"]), dtype)
    import jax.numpy as jnp

    s = jnp.asarray(rng.normal(size=(d["D"],)), jnp.float32)
    return x, s


def _rn_call(inputs, config, interpret):
    from repro.kernels.rmsnorm import rmsnorm_pallas

    x, s = inputs
    return rmsnorm_pallas(x, s, block_rows=config["block_rows"],
                          interpret=interpret, **_launch_kw(config))


def _rn_vmem(config, d, dtype):
    """Resident tiles: input + output row blocks (f32 accumulate) + scale."""
    br = min(config["block_rows"], d["ROWS"])
    return 2 * br * d["D"] * max(_dtype_bytes(dtype), 4) + d["D"] * 4


def _rn_cost(config, d, dtype):
    rows, D = d["ROWS"], d["D"]
    br = min(config["block_rows"], rows)
    n = math.ceil(rows / br)
    ib = _dtype_bytes(dtype)
    pad = _align_penalty(br, dtype)
    flops = n * 4.0 * br * D * pad  # VPU work; counted at MXU scale below
    hbm = 2.0 * rows * D * ib + n * D * 4
    vmem = _rn_vmem(config, d, dtype)
    # rmsnorm is pure VPU: scale compute down to VPU throughput (~1/8 MXU)
    return _roofline_s(flops * 8.0, hbm, n, vmem, config, br * D)


# -- paged decode attention --------------------------------------------------
# the authoritative page granularity (serve/paging.py is numpy-only, so
# this import stays cheap and the two can never drift)
from repro.serve.paging import PAGE_TOKENS  # noqa: E402


def _pa_space() -> ParameterSpace:
    # pages_per_block is the pool-layout granularity: tokens streamed per
    # grid step = pages_per_block * PAGE_TOKENS.  The serve engine's paged
    # allocator adopts the tuned value as its group size, so the knob
    # couples kernel tiling with allocator fragmentation.
    return _with_launch_knobs([
        EnumParam("pages_per_block", (1, 2, 4, 8, 16, 32), 4),
    ])


def _pa_inputs(d, dtype, rng):
    # Dense K/V + lengths; the call adapter lays the pool out at the
    # candidate pages_per_block (layout is part of the config under test).
    q = _rand(rng, (d["B"], d["H"], d["D"]), dtype)
    k = _rand(rng, (d["B"], d["S"], d["KV"], d["D"]), dtype)
    v = _rand(rng, (d["B"], d["S"], d["KV"], d["D"]), dtype)
    return q, k, v, d["S"]


def _pa_call(inputs, config, interpret):
    import jax.numpy as jnp

    from repro.kernels.paged_attention import paged_flash_decode_pallas

    q, k, v, kv_len = inputs
    B, S, KV, D = k.shape
    T = int(config["pages_per_block"]) * PAGE_TOKENS
    pad = (-S) % T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    maxg = k.shape[1] // T
    k_pages = k.reshape(B * maxg, T, KV, D)
    v_pages = v.reshape(B * maxg, T, KV, D)
    pt = jnp.arange(B * maxg, dtype=jnp.int32).reshape(B, maxg)
    lengths = jnp.full((B,), kv_len, jnp.int32)
    return paged_flash_decode_pallas(
        q, k_pages, v_pages, pt, lengths,
        dimension_semantics=config.get("dim_semantics"),
        num_warps=config.get("num_warps"), interpret=interpret)


def _pa_vmem(config, d, dtype):
    """Resident tiles: k/v page blocks + per-group f32 m/l/acc + queries."""
    G = max(d["H"] // d["KV"], 1)
    T = min(int(config["pages_per_block"]) * PAGE_TOKENS, d["S"])
    ib = _dtype_bytes(dtype)
    return 2 * T * d["D"] * ib + G * (2 + d["D"]) * 4 + G * d["D"] * ib


def _pa_cost(config, d, dtype):
    B, S, H, KV, D = d["B"], d["S"], d["H"], d["KV"], d["D"]
    G = max(H // KV, 1)
    T = min(int(config["pages_per_block"]) * PAGE_TOKENS, S)
    ng = math.ceil(S / T)
    n_steps = B * KV * ng
    ib = _dtype_bytes(dtype)
    flops = n_steps * 4.0 * G * T * D * _align_penalty(T, dtype)
    # stream the pool once + the page-table walk (one SMEM-indexed DMA
    # program per group — small but real, and it shrinks as T grows)
    hbm = 2.0 * B * KV * ng * T * D * ib + n_steps * 64.0
    vmem = _pa_vmem(config, d, dtype)
    return _roofline_s(flops, hbm, n_steps, vmem, config, T * D)


KERNELS: Dict[str, KernelDef] = {
    # SK = KV sequence length; distinct from S so cross-attention and
    # cache-prefill problems (different KV lengths, same query length) key
    # separate autotune entries.
    "flash_attention": KernelDef(
        "flash_attention", ("B", "S", "SK", "H", "KV", "D"),
        ("block_q", "block_kv", "dim_semantics"),
        _fa_space, _fa_inputs, _fa_call, _fa_cost, _fa_vmem),
    "decode_attention": KernelDef(
        "decode_attention", ("B", "S", "H", "KV", "D"),
        ("block_kv", "dim_semantics"),
        _fd_space, _fd_inputs, _fd_call, _fd_cost, _fd_vmem),
    "paged_attention": KernelDef(
        "paged_attention", ("B", "S", "H", "KV", "D"),
        ("pages_per_block", "dim_semantics"),
        _pa_space, _pa_inputs, _pa_call, _pa_cost, _pa_vmem),
    "gla": KernelDef(
        "gla", ("B", "S", "H", "DK", "DV"),
        ("chunk", "dim_semantics"),
        _gla_space, _gla_inputs, _gla_call, _gla_cost, _gla_vmem),
    "rmsnorm": KernelDef(
        "rmsnorm", ("ROWS", "D"),
        ("block_rows", "dim_semantics"),
        _rn_space, _rn_inputs, _rn_call, _rn_cost, _rn_vmem),
}


class KernelSpace:
    """The ACTS parameter space of one kernel's tiling knobs."""

    def __init__(self, kernel: str):
        if kernel not in KERNELS:
            raise ValueError(f"unknown kernel {kernel!r}; "
                             f"have {sorted(KERNELS)}")
        self.kernel = kernel
        self.definition = KERNELS[kernel]

    def space(self) -> ParameterSpace:
        return self.definition.make_space()

    @property
    def knobs(self) -> Tuple[str, ...]:
        return self.definition.knobs

    def validate_dims(self, dims: Dict[str, int]) -> Dict[str, int]:
        missing = [k for k in self.definition.dims if k not in dims]
        if missing:
            raise ValueError(
                f"kernel {self.kernel}: missing dims {missing}")
        return {k: int(dims[k]) for k in self.definition.dims}
