"""The kernel-under-tune: an ACTS ``TunableSystem`` over Pallas tilings.

A ``KernelSUT`` scores one block configuration for one problem signature.
Two measurement modes:

* ``"time"``  — compile + wall-clock the kernel (the real thing; only
  meaningful on actual accelerator backends),
* ``"model"`` — the deterministic roofline cost model from
  ``repro.autotune.space`` (the CPU/interpret default: interpret-mode wall
  time measures the Python emulator, not the TPU).

Either way the metric is seconds (lower is better), so the unmodified ACTS
``Tuner`` — budget, duplicate-config cache, report — drives the search.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional

import numpy as np

from repro.core.params import Config, ParameterSpace
from repro.core.tuner import PerfMetric

from .space import KERNELS, KernelSpace, shape_sig

__all__ = ["KernelSUT"]


class KernelSUT:
    def __init__(
        self,
        kernel: str,
        dims: Dict[str, int],
        dtype: str = "float32",
        mode: Optional[str] = None,  # None = time on TPU, model elsewhere
        interpret: Optional[bool] = None,
        timing_iters: int = 3,
        seed: int = 0,
    ):
        self.kspace = KernelSpace(kernel)
        self.kernel = kernel
        self.dims = self.kspace.validate_dims(dims)
        self.dtype = dtype
        self.timing_iters = timing_iters
        self.seed = seed
        self._interpret = interpret
        self._mode = mode
        self._inputs: Optional[tuple] = None
        self.name = f"kernel[{kernel}×{shape_sig(self.dims)}]"

    # lazy jax-touching properties so building a SUT never initializes jax
    @property
    def interpret(self) -> bool:
        if self._interpret is None:
            from repro.kernels.ops import default_interpret

            self._interpret = default_interpret()
        return self._interpret

    @property
    def mode(self) -> str:
        if self._mode is None:
            self._mode = "model" if self.interpret else "time"
        return self._mode

    def space(self) -> ParameterSpace:
        return self.kspace.space()

    @property
    def feasibility_model(self):
        """Static feasibility of a block config on this problem signature.

        Auto-detected by the ``Tuner``: statically-VMEM-infeasible tilings
        are pruned before they burn a test (in ``mode="time"`` they would
        compile-and-crash on real hardware; in ``mode="model"`` they would
        spend a budget unit to learn ``inf``).  Built on the same
        ``vmem_footprint`` the cost model evaluates, so pruning never
        disagrees with cost-model finiteness.
        """
        from repro.analysis.feasibility import kernel_feasibility

        return kernel_feasibility(self.kernel, self.dims, self.dtype)

    # ------------------------------------------------------------------
    def _get_inputs(self) -> tuple:
        if self._inputs is None:
            rng = np.random.default_rng(self.seed)
            self._inputs = self.kspace.definition.make_inputs(
                self.dims, self.dtype, rng)
        return self._inputs

    def test(self, config: Config) -> PerfMetric:
        d = self.kspace.definition
        if self.mode == "model":
            cost = float(d.model_cost(config, self.dims, self.dtype))
            return PerfMetric(value=cost, higher_is_better=False,
                              metrics={"mode": "model",
                                       "config": dict(config)})
        import jax

        inputs = self._get_inputs()
        out = d.call(inputs, config, self.interpret)  # compile + first run
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(self.timing_iters):
            t0 = time.perf_counter()
            jax.block_until_ready(d.call(inputs, config, self.interpret))
            best = min(best, time.perf_counter() - t0)
        return PerfMetric(value=best, higher_is_better=False,
                          metrics={"mode": "time", "config": dict(config)})

    def test_batch(self, configs) -> list:
        """One evaluator call per candidate round (BatchEvaluator protocol).

        The cost model is scalar math, so the batch is a plain loop —
        value-identical to per-config ``test`` — but a composite/batched
        tuner still dispatches the whole round in a single call.
        """
        return [self.test(c) for c in configs]
