"""Checkpoint substrate: atomic saves, retention, elastic restore."""
from .manager import CheckpointInfo, CheckpointManager

__all__ = ["CheckpointInfo", "CheckpointManager"]
