"""Atomic, retention-managed checkpointing with elastic restore.

Design points for 1000+-node deployments (scaled to this container):

* **Atomicity** — a checkpoint directory is staged under a temp name and
  ``os.replace``d into place; readers can never observe a partial write.
  Interrupted writes leave ``*.tmp`` junk that is skipped and GC'd.
* **Validation** — a manifest (step, leaf count, per-leaf shapes/dtypes,
  fingerprint) is written last and verified on restore; corrupt or truncated
  checkpoints are skipped and the previous one is used.
* **Retention** — keep the newest ``keep`` checkpoints (plus optional every-N
  keepers for post-hoc analysis).
* **Async** — saves can run on a background thread (the train loop keeps
  stepping); ``wait()`` joins before the next save or at exit.
* **Elastic restore** — arrays are stored logically (host numpy); the caller
  re-shards onto whatever mesh is alive via ``jax.device_put`` with new
  shardings, so a job may restart with a different data-parallel extent.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["CheckpointManager", "CheckpointInfo"]


@dataclass
class CheckpointInfo:
    step: int
    path: str
    manifest: Dict[str, Any]


_UINT_FOR_SIZE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _to_savable(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    """np.savez can't store ml_dtypes (bfloat16, fp8): view as raw uints and
    record the true dtype in the manifest."""
    dtype_str = str(arr.dtype)
    try:
        np.dtype(dtype_str)
        native = arr.dtype.kind != "V"
    except TypeError:
        native = False
    if native and dtype_str not in ("bfloat16",):
        return arr, dtype_str
    return arr.view(_UINT_FOR_SIZE[arr.dtype.itemsize]), dtype_str


def _from_saved(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(arr.dtype) == dtype_str:
        return arr
    import ml_dtypes

    try:
        dt = np.dtype(dtype_str)
    except TypeError:
        dt = np.dtype(getattr(ml_dtypes, dtype_str))
    return arr.view(dt)


def _flatten_with_names(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 keep_every: Optional[int] = None, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.keep_every = keep_every
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        self._gc_tmp()

    # ------------------------------------------------------------------
    def _gc_tmp(self):
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    def _ckpt_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_checkpoints(self) -> List[CheckpointInfo]:
        out = []
        for name in sorted(os.listdir(self.directory)):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            path = os.path.join(self.directory, name)
            mpath = os.path.join(path, "manifest.json")
            try:
                with open(mpath) as f:
                    manifest = json.load(f)
                out.append(CheckpointInfo(manifest["step"], path, manifest))
            except (OSError, json.JSONDecodeError, KeyError):
                continue  # incomplete/corrupt: skip
        return sorted(out, key=lambda c: c.step)

    def latest(self) -> Optional[CheckpointInfo]:
        cks = self.all_checkpoints()
        return cks[-1] if cks else None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict[str, Any]] = None):
        self.wait()
        if self.async_save:
            host_tree = jax.tree_util.tree_map(np.asarray, tree)
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_tree, extra or {}))
            self._thread.start()
        else:
            self._save_sync(step, tree, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _save_sync(self, step: int, tree, extra: Dict[str, Any]):
        final = self._ckpt_dir(step)
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        leaves = _flatten_with_names(tree)
        arrays = {}
        manifest_leaves = {}
        fp = 0
        for name, leaf in leaves:
            arr = np.asarray(leaf)
            savable, dtype_str = _to_savable(arr)
            arrays[name] = savable
            manifest_leaves[name] = {"shape": list(arr.shape),
                                     "dtype": dtype_str}
            fp = zlib.crc32(savable.tobytes()[:4096], fp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k.replace("/", "__"): v for k, v in arrays.items()})
        manifest = {
            "step": step,
            "time": time.time(),
            "n_leaves": len(leaves),
            "fingerprint": fp,
            "leaves": manifest_leaves,
            "extra": extra,
        }
        # manifest written last: its presence marks the checkpoint complete
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        self._retain()

    def _retain(self):
        cks = self.all_checkpoints()
        if len(cks) <= self.keep:
            return
        drop = cks[:-self.keep]
        for c in drop:
            if self.keep_every and c.step % self.keep_every == 0:
                continue
            shutil.rmtree(c.path, ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Tuple[int, Any]:
        """Restore into the structure of ``template``.

        ``shardings``: optional matching tree of ``jax.sharding.Sharding`` —
        arrays are placed directly onto the (possibly different) mesh, which
        is the elastic-rescale path.
        """
        self.wait()
        infos = self.all_checkpoints()
        if step is not None:
            infos = [c for c in infos if c.step == step]
        if not infos:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        info = infos[-1]
        with np.load(os.path.join(info.path, "arrays.npz")) as data:
            arrays = {}
            for k in data.files:
                name = k.replace("__", "/")
                dtype_str = info.manifest["leaves"][name]["dtype"]
                arrays[name] = _from_saved(data[k], dtype_str)
        if len(arrays) != info.manifest["n_leaves"]:
            raise ValueError(f"checkpoint {info.path} is corrupt "
                             f"(leaf count mismatch)")
        names = [n for n, _ in _flatten_with_names(template)]
        missing = [n for n in names if n not in arrays]
        if missing:
            raise ValueError(f"checkpoint missing leaves: {missing[:5]}...")
        ordered = [arrays[n] for n in names]
        treedef = jax.tree_util.tree_structure(template)
        restored = jax.tree_util.tree_unflatten(treedef, ordered)
        if shardings is not None:
            restored = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), restored, shardings)
        return info.step, restored
