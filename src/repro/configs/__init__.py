"""Architecture configs and input shapes.

One module per assigned architecture defines its exact published
configuration; this package holds the shared ``ModelConfig`` schema, the
four per-arch input shapes, the registry (``--arch <id>``), and the
``reduced()`` transform used by CPU smoke tests.

Block kinds usable in ``superblock`` (the repeating layer pattern):

  attn      global self-attention + dense MLP
  swa       sliding-window self-attention + dense MLP
  cross     cross-attention to frontend/encoder memory + dense MLP
  moe       global self-attention + MoE FFN (top-k routed)
  moe_swa   sliding-window self-attention + MoE FFN
  dec       self-attention + cross-attention + MLP (enc-dec decoder layer)
  mamba2    Mamba2 (SSD) mixer block
  mlstm     xLSTM matrix-memory block
  slstm     xLSTM scalar-memory (recurrent) block
  shared    invocation of the weight-shared attention+MLP block (Zamba2)

``n_layers`` must equal ``len(superblock) × n_superblocks``; the stack is
executed as ``lax.scan`` over stacked superblock parameters.
"""
from __future__ import annotations

import importlib
import math
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Tuple

__all__ = [
    "MoESpec",
    "EncoderSpec",
    "ModelConfig",
    "ShapeSpec",
    "SHAPES",
    "ARCH_IDS",
    "get_config",
    "list_configs",
    "reduced",
    "shape_applicable",
    "register",
]


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    experts_per_token: int
    d_ff: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class EncoderSpec:
    n_layers: int
    superblock: Tuple[str, ...] = ("attn",)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    superblock: Tuple[str, ...] = ("attn",)
    activation: str = "swiglu"  # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int = 0  # sliding-window size for swa blocks
    moe: Optional[MoESpec] = None
    # SSM (mamba2) / xLSTM
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    gla_impl: str = "jnp"  # jnp | pallas (TPU kernel; interpret on CPU)
    # enc-dec
    encoder: Optional[EncoderSpec] = None
    # modality frontend stub (precomputed embeddings supplied as inputs)
    frontend: Optional[str] = None  # "vision" | "audio"
    frontend_tokens: int = 0
    frontend_dim: int = 0
    # numerics
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 256
    # attention implementation: dense | blocked | local | auto
    attn_impl: str = "auto"
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    # pad query-head count up to a multiple (0 = off): padded heads are
    # zero-initialized so they contribute exactly nothing, in exchange for
    # a shardable head count (e.g. qwen's 40 -> 48 on a 16-way model axis)
    pad_heads_to_multiple: int = 0
    # long_500k applicability override (None = derive from block kinds)
    long_context: Optional[bool] = None
    notes: str = ""

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def padded_heads(self) -> int:
        m = self.pad_heads_to_multiple
        if not m:
            return self.n_heads
        h = ((self.n_heads + m - 1) // m) * m
        # GQA grouping must stay integral
        while h % self.n_kv_heads:
            h += m
        return h

    @property
    def n_superblocks(self) -> int:
        if self.n_layers % len(self.superblock):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"superblock of {len(self.superblock)}"
            )
        return self.n_layers // len(self.superblock)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def sub_quadratic(self) -> bool:
        """True when the arch can serve 500k-token contexts (linear/windowed
        recurrence dominates; ``long_context`` overrides the heuristic)."""
        if self.long_context is not None:
            return self.long_context
        quad = {"attn", "moe", "cross", "dec", "shared"}
        kinds = set(self.superblock)
        if self.encoder:
            kinds |= set(self.encoder.superblock)
        return not (kinds & quad)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for sanity checks."""
        from repro.models.transformer import count_params  # lazy, avoids cycle

        return count_params(self)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS: List[str] = [
    "xlstm-350m",
    "gemma-7b",
    "qwen2.5-32b",
    "starcoder2-15b",
    "gemma3-12b",
    "llama-3.2-vision-90b",
    "seamless-m4t-medium",
    "mixtral-8x22b",
    "grok-1-314b",
    "zamba2-1.2b",
]

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all() -> None:
    if len(_REGISTRY) >= len(ARCH_IDS):
        return
    mods = [
        "xlstm_350m",
        "gemma_7b",
        "qwen2_5_32b",
        "starcoder2_15b",
        "gemma3_12b",
        "llama32_vision_90b",
        "seamless_m4t_medium",
        "mixtral_8x22b",
        "grok1_314b",
        "zamba2_1_2b",
    ]
    for m in mods:
        importlib.import_module(f"repro.configs.{m}")


def get_config(name: str) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> List[str]:
    _load_all()
    return [a for a in ARCH_IDS if a in _REGISTRY]


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell runs, per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k needs sub-quadratic attention; "
            f"{cfg.name} has full-attention blocks (see DESIGN.md)"
        )
    return True, ""


def reduced(cfg: ModelConfig, seed_width: int = 64) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests: same superblock pattern
    and block kinds, 2 superblocks, small widths, tiny vocab."""
    n_sb = min(2, cfg.n_superblocks)
    d_model = seed_width
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    while n_heads % n_kv:
        n_kv -= 1
    updates = dict(
        name=cfg.name + "-smoke",
        n_layers=len(cfg.superblock) * n_sb,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16,
        d_ff=seed_width * 2 if cfg.d_ff else 0,
        vocab_size=512,
        window=min(cfg.window, 32) if cfg.window else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_heads=min(cfg.ssm_heads, 4) if cfg.ssm_heads else 0,
        ssm_chunk=16,
        frontend_tokens=min(cfg.frontend_tokens, 8) if cfg.frontend_tokens else 0,
        frontend_dim=32 if cfg.frontend_dim else 0,
        param_dtype="float32",
        compute_dtype="float32",
        vocab_pad_multiple=64,
        attn_block_q=16,
        attn_block_kv=32,
    )
    if cfg.moe:
        n_exp = min(cfg.moe.n_experts, 4)
        k = min(cfg.moe.experts_per_token, 2)
        updates["moe"] = MoESpec(
            n_experts=n_exp,
            experts_per_token=k,
            d_ff=seed_width * 2,
            # drop-free capacity so prefill/decode consistency is exact
            # (token dropping is batch-dependent by design; tested separately)
            capacity_factor=float(n_exp) / k,
        )
    if cfg.encoder:
        updates["encoder"] = EncoderSpec(
            n_layers=len(cfg.encoder.superblock) * min(2, cfg.encoder.n_layers),
            superblock=cfg.encoder.superblock,
        )
    return replace(cfg, **updates)
