"""Gemma3-12B [hf:google/gemma-3-1b-pt scaling; unverified].

48 layers, d_model=3840, 16 heads / 8 KV heads, GeGLU d_ff=15360, vocab
262144.  5:1 local:global attention pattern (superblock = 5×swa + 1×attn,
window 1024), 128k context target.
"""
from repro.configs import ModelConfig, register

register(
    ModelConfig(
        name="gemma3-12b",
        family="dense",
        n_layers=48,
        d_model=3840,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262144,
        superblock=("swa", "swa", "swa", "swa", "swa", "attn"),
        window=1024,
        activation="geglu",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        notes="long_500k skipped: the 1-in-6 global layers are full "
              "attention (unbounded KV), so the arch is not sub-quadratic.",
    )
)
