"""Gemma-7B [arXiv:2403.08295; hf].

28 layers, d_model=3072, 16 heads with head_dim=256 (q-dim 4096 > d_model,
faithful to the paper), MHA (kv=16; MQA is the 2b variant), GeGLU d_ff=24576,
vocab 256000, tied embeddings.
"""
from repro.configs import ModelConfig, register

register(
    ModelConfig(
        name="gemma-7b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=16,
        n_kv_heads=16,
        head_dim=256,
        d_ff=24576,
        vocab_size=256000,
        superblock=("attn",),
        activation="geglu",
        rope_theta=10_000.0,
        tie_embeddings=True,
        notes="pure full attention -> long_500k skipped",
    )
)
