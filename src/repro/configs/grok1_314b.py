"""Grok-1 (314B) [hf:xai-org/grok-1; unverified].

64 layers, d_model=6144, 48 heads / 8 KV heads, MoE: 8 experts top-2 with
expert d_ff=32768, vocab 131072, full attention.
"""
from repro.configs import ModelConfig, MoESpec, register

register(
    ModelConfig(
        name="grok-1-314b",
        family="moe",
        n_layers=64,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=32768,
        vocab_size=131072,
        superblock=("moe",),
        activation="geglu",  # gated MoE FFN (w_in, w_gate, w_out) => 314B total
        rope_theta=10_000.0,
        moe=MoESpec(n_experts=8, experts_per_token=2, d_ff=32768,
                    capacity_factor=1.25),
        tie_embeddings=False,
        notes="long_500k skipped (full attention).",
    )
)
