"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision scaling; unverified].

100 decoder layers, d_model=8192, 64 heads / 8 KV heads, SwiGLU d_ff=28672,
vocab 128256.  Cross-attention image layers every 5th layer (20 total);
the vision tower is a STUB — ``input_specs()`` supplies precomputed patch
embeddings (1601 patches × 1280, ViT-H/14-scale), per the modality rule.
"""
from repro.configs import ModelConfig, register

register(
    ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        n_layers=100,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=28672,
        vocab_size=128256,
        superblock=("attn", "attn", "attn", "attn", "cross"),
        activation="swiglu",
        rope_theta=500_000.0,
        tie_embeddings=False,
        frontend="vision",
        frontend_tokens=1601,
        frontend_dim=1280,
        notes="cross layers use tanh-gated residuals (zero-init). "
              "long_500k skipped (full attention).",
    )
)
