"""Mixtral-8x22B [arXiv:2401.04088; hf].

56 layers, d_model=6144, 48 heads / 8 KV heads, MoE: 8 experts top-2 with
expert d_ff=16384, vocab 32768, sliding-window attention (window 4096, per
the assignment spec).  SWA bounds the KV cache => long_500k RUNS.
"""
from repro.configs import ModelConfig, MoESpec, register

register(
    ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=32768,
        superblock=("moe_swa",),
        window=4096,
        activation="swiglu",
        rope_theta=1_000_000.0,
        moe=MoESpec(n_experts=8, experts_per_token=2, d_ff=16384,
                    capacity_factor=1.25),
        tie_embeddings=False,
        notes="SWA is sub-quadratic (ring-buffer KV cache of 4096) so "
              "long_500k runs.",
    )
)
