"""Qwen2.5-32B [hf:Qwen/Qwen2.5-0.5B family scaling; hf].

64 layers, d_model=5120, 40 heads / 8 KV heads (GQA), SwiGLU d_ff=27648,
vocab 152064, QKV bias (the Qwen signature), untied embeddings.
"""
from repro.configs import ModelConfig, register

register(
    ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=27648,
        vocab_size=152064,
        superblock=("attn",),
        activation="swiglu",
        qkv_bias=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        notes="40 heads don't divide the 16-way model axis: attention "
              "falls back to replicated head sharding under default rules "
              "(hillclimb target).  long_500k skipped (full attention).",
    )
)
