"""SeamlessM4T-medium [arXiv:2308.11596; hf].

Encoder-decoder, d_model=1024, 16 heads (MHA), d_ff=4096, vocab 256206
(padded to 256256 for sharding).  12 encoder + 12 decoder layers; the
speech frontend is a STUB — ``input_specs()`` supplies precomputed frame
embeddings (960 frames × 1024).  Decode shapes lower the *decoder* step
with self-attention KV cache + cross-attention to the encoder memory.
"""
from repro.configs import EncoderSpec, ModelConfig, register

register(
    ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        n_layers=12,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        superblock=("dec",),
        activation="gelu",
        encoder=EncoderSpec(n_layers=12, superblock=("attn",)),
        frontend="audio",
        frontend_tokens=960,
        frontend_dim=1024,
        tie_embeddings=True,
        notes="long_500k skipped (full attention). decoder layers = "
              "self-attn + cross-attn + MLP.",
    )
)
