"""StarCoder2-15B [arXiv:2402.19173; hf].

40 layers, d_model=6144, 48 heads / 4 KV heads (GQA), d_ff=24576, vocab
49152, RoPE, GELU MLP (starcoder2 uses non-gated GELU-style FFN).
"""
from repro.configs import ModelConfig, register

register(
    ModelConfig(
        name="starcoder2-15b",
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24576,
        vocab_size=49152,
        superblock=("attn",),
        activation="gelu",
        rope_theta=100_000.0,
        tie_embeddings=False,
        notes="long_500k skipped (full attention)",
    )
)
