"""xLSTM-350M [arXiv:2405.04517; unverified].

24 layers, d_model=1024, 4 heads, d_ff=0 (the xLSTM blocks carry their own
2x up/down projections), vocab 50304 (GPT-NeoX tokenizer).  Alternating
mLSTM/sLSTM superblock; linear-time recurrence => long_500k RUNS.
"""
from repro.configs import ModelConfig, register

register(
    ModelConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        superblock=("mlstm", "slstm"),
        ssm_expand=2,
        ssm_conv=4,
        ssm_chunk=256,
        tie_embeddings=True,
        notes="sLSTM is sequential by construction (hidden-state feedback); "
              "mLSTM runs on the chunked-GLA core.",
    )
)
