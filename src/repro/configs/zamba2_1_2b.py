"""Zamba2-1.2B [arXiv:2411.15242; hf].

38 layers, d_model=2048, Mamba2 backbone (ssm_state=64) with a **weight-
shared** attention block (32 heads MHA + MLP d_ff=8192) invoked twice per
superblock of 19.  Linear-time recurrence + O(1) shared-attn usage at the
38-layer scale => long_500k RUNS (the shared block's KV cache is bounded by
2 invocation points per superblock... it is still full attention over the
sequence, see DESIGN.md note below).
"""
from repro.configs import ModelConfig, register

register(
    ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=32000,
        superblock=("mamba2",) * 9 + ("shared",) + ("mamba2",) * 9,
        activation="gelu",
        ssm_state=64,
        ssm_heads=64,
        ssm_expand=2,
        ssm_conv=4,
        ssm_chunk=256,
        tie_embeddings=True,
        long_context=True,  # hybrid: mamba2 backbone dominates at 500k

        notes="shared attention block: one weight set, 2 invocations "
              "(distinct KV caches). Decode cost is O(1) per token for the "
              "36 mamba2 layers; the 2 shared-attn calls keep a KV cache "
              "(full attention), dominated by the mamba backbone at 500k.",
    )
)
