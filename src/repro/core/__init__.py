"""ACTS — Automatic Configuration Tuning with Scalability guarantees.

The paper's primary contribution (Zhu et al., APSys'17) as a composable
library: typed parameter spaces over a unit hypercube, scalable sampling
(LHS), scalable search (RRS + baselines), and the flexible tuner ⇄ system
manipulator ⇄ workload generator architecture.  The JAX distributed runtime
in this repo is itself a first-class SUT (``repro.core.sut_jax``).
"""
from .base import BatchObjective, BudgetedRun, BudgetExhausted, Trial, \
    TuningResult
from .bottleneck import BottleneckReport, identify_bottleneck
from .composite import (
    CompositeSpace,
    CompositeSUT,
    SubspaceRoundRobinOptimizer,
    throughput_under_sla,
    weighted_objective,
)
from .optimizers import (
    OPTIMIZERS,
    CoordinateSearchOptimizer,
    LHSOnlyOptimizer,
    RandomSearchOptimizer,
    SmartHillClimbingOptimizer,
    get_optimizer,
)
from .params import (
    BoolParam,
    EnumParam,
    FloatParam,
    IntParam,
    Parameter,
    ParameterSpace,
)
from .rrs import RRSOptimizer
from .sampling import (
    centered_l2_discrepancy,
    get_sampler,
    lhs,
    lhs_unit,
    maximin_lhs,
    min_pairwise_distance,
    random_sampling,
    random_unit,
    stratification_counts,
)
from .surrogates import (
    ComposedSUT,
    FrontendSurrogate,
    MySQLSurrogate,
    SparkSurrogate,
    Surrogate,
    TomcatSurrogate,
)
from .tuner import (
    BatchEvaluator,
    CallableSUT,
    PerfMetric,
    SystemManipulator,
    TunableSystem,
    Tuner,
    TuningReport,
    WorkloadGenerator,
)

__all__ = [n for n in dir() if not n.startswith("_")]
