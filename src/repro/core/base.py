"""Shared types for ACTS optimizers and the tuner."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from .params import Config

__all__ = ["Trial", "TuningResult", "Objective", "BudgetExhausted"]


class BudgetExhausted(Exception):
    """Raised by a budgeted objective when the resource limit is used up."""


@dataclass
class Trial:
    config: Config
    value: float  # minimized objective value
    test_index: int  # which test (1-based) produced this sample
    phase: str = ""  # e.g. "default", "explore", "exploit"
    metrics: Dict[str, Any] = field(default_factory=dict)


@dataclass
class TuningResult:
    best_config: Config
    best_value: float
    history: List[Trial]
    n_tests: int

    @property
    def best_trial(self) -> Optional[Trial]:
        best = None
        for t in self.history:
            if best is None or t.value < best.value:
                best = t
        return best

    def best_so_far(self) -> List[float]:
        """Monotone best-value trace, one entry per test (for convergence plots)."""
        out: List[float] = []
        cur = float("inf")
        for t in sorted(self.history, key=lambda t: t.test_index):
            cur = min(cur, t.value)
            out.append(cur)
        return out


Objective = Callable[[Config], float]
