"""Shared types for ACTS optimizers and the tuner."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from .params import Config

__all__ = ["Trial", "TuningResult", "Objective", "BatchObjective",
           "Feasible", "BudgetedRun", "BudgetExhausted"]


class BudgetExhausted(Exception):
    """Raised by a budgeted objective when the resource limit is used up."""


@dataclass
class Trial:
    config: Config
    value: float  # minimized objective value
    test_index: int  # which test (1-based) produced this sample
    phase: str = ""  # e.g. "default", "explore", "exploit"
    metrics: Dict[str, Any] = field(default_factory=dict)


@dataclass
class TuningResult:
    best_config: Config
    best_value: float
    history: List[Trial]
    n_tests: int
    # candidates rejected by the static feasibility model before reaching
    # the SUT — never charged against the budget, never in the history
    n_infeasible_pruned: int = 0

    @property
    def best_trial(self) -> Optional[Trial]:
        best = None
        for t in self.history:
            if best is None or t.value < best.value:
                best = t
        return best

    def best_so_far(self) -> List[float]:
        """Monotone best-value trace, one entry per test (for convergence plots)."""
        out: List[float] = []
        cur = float("inf")
        for t in sorted(self.history, key=lambda t: t.test_index):
            cur = min(cur, t.value)
            out.append(cur)
        return out


Objective = Callable[[Config], float]

# A static feasibility test: True = worth spending a test on.  Infeasible
# candidates are pruned before the objective runs and charge NO budget
# (``repro.analysis.feasibility`` builds these from declarative models).
Feasible = Callable[[Config], bool]

# A batch objective scores a whole candidate round in one call.  It may
# return values for a strict *prefix* of the requested configs: a short
# return means the resource limit was exhausted after that prefix, and the
# caller must record the prefix and stop.
BatchObjective = Callable[[Sequence[Config]], Sequence[float]]


class BudgetedRun:
    """Shared optimizer bookkeeping: budget enforcement + history + best.

    ``evaluate_batch`` scores one candidate round.  The round is truncated
    to the remaining budget; if the objective itself runs out of resource
    (a short prefix return from a ``BatchObjective``), the prefix is
    recorded before ``BudgetExhausted`` propagates — exactly what a
    point-by-point loop would have left behind.  Candidate rounds are
    scored through ``batch_objective`` when one is provided (the tuner's
    vectorized ``BatchEvaluator`` path) and per-config otherwise; the two
    modes evaluate the identical trial sequence.

    When a ``feasible`` model is given, statically-infeasible candidates
    are pruned BEFORE the objective runs: they charge no budget, record no
    trial, and return ``math.inf`` in their round slot (positionally — the
    value the cost model would have reported, so round argmins and
    incumbent updates are unchanged).  Candidate *generation* is untouched
    and the mask is a deterministic function of the candidates, so the
    same seed still yields the same trial stream; the budget a pruned
    candidate would have burned flows to the round's (and later rounds')
    feasible candidates instead.
    """

    # A space whose feasible region the model rejects entirely would let a
    # round-based optimizer generate forever without ever consuming
    # budget.  After this many consecutive pruned candidates with no
    # intervening test, the run is declared exhausted (deterministic — a
    # pure count, no wall clock).
    MAX_CONSECUTIVE_PRUNED = 100_000

    def __init__(self, space, objective: Optional[Objective], budget: int,
                 batch_objective: Optional[BatchObjective] = None,
                 feasible: Optional[Feasible] = None):
        self.space = space
        self.objective = objective
        self.batch_objective = batch_objective
        self.feasible = feasible
        self.budget = budget
        self.history: List[Trial] = []
        self.n_tests = 0
        self.n_infeasible_pruned = 0
        self._pruned_since_test = 0
        self.best_u = None
        self.best_val = math.inf

    @property
    def remaining(self) -> int:
        return self.budget - self.n_tests

    def evaluate_batch(self, units, phase: str):
        units = np.atleast_2d(np.asarray(units, dtype=float))
        if self.remaining <= 0:
            raise BudgetExhausted
        cfgs = self.space.from_unit_matrix(units)
        # Walk the round in candidate order, exactly like a sequential
        # loop would: infeasible candidates are pruned for free, feasible
        # ones are charged until the resource limit cuts the round.
        eval_idx: List[int] = []
        n_pruned = 0
        truncated = False
        for i, cfg in enumerate(cfgs):
            if self.feasible is not None and not self.feasible(cfg):
                n_pruned += 1
                continue
            if len(eval_idx) >= self.remaining:
                truncated = True  # rows past this point never run
                break
            eval_idx.append(i)
        self.n_infeasible_pruned += n_pruned
        if eval_idx:
            self._pruned_since_test = 0
        else:
            self._pruned_since_test += n_pruned
            if self._pruned_since_test > self.MAX_CONSECUTIVE_PRUNED:
                raise BudgetExhausted  # feasible region is (near-)empty
        sub = [cfgs[i] for i in eval_idx]
        if self.batch_objective is not None:
            vals = [float(v) for v in self.batch_objective(sub)]
        else:
            vals = []
            try:
                for cfg in sub:
                    vals.append(float(self.objective(cfg)))
            except BudgetExhausted:
                pass  # record the prefix below, then re-raise
        # Pruned slots report inf — the value the roofline cost model
        # assigns an infeasible config — so optimizers that argmin a round
        # behave as if it had been scored, minus the budget charge.
        out = np.full(len(cfgs), math.inf)
        for i, val in zip(eval_idx, vals):
            self.n_tests += 1
            self.history.append(Trial(cfgs[i], val, self.n_tests, phase))
            if val < self.best_val:
                self.best_val, self.best_u = val, units[i].copy()
            out[i] = val
        if truncated or len(vals) < len(eval_idx):
            raise BudgetExhausted
        return out

    def evaluate(self, u, phase: str) -> float:
        return float(
            self.evaluate_batch(np.asarray(u, float)[None], phase)[0])

    def result(self) -> TuningResult:
        if self.best_u is None:
            return TuningResult(
                self.space.default_config(), math.inf, self.history,
                self.n_tests, self.n_infeasible_pruned)
        return TuningResult(
            self.space.from_unit_vector(self.best_u),
            self.best_val,
            self.history,
            self.n_tests,
            self.n_infeasible_pruned,
        )
