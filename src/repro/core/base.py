"""Shared types for ACTS optimizers and the tuner."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from .params import Config

__all__ = ["Trial", "TuningResult", "Objective", "BatchObjective",
           "BudgetedRun", "BudgetExhausted"]


class BudgetExhausted(Exception):
    """Raised by a budgeted objective when the resource limit is used up."""


@dataclass
class Trial:
    config: Config
    value: float  # minimized objective value
    test_index: int  # which test (1-based) produced this sample
    phase: str = ""  # e.g. "default", "explore", "exploit"
    metrics: Dict[str, Any] = field(default_factory=dict)


@dataclass
class TuningResult:
    best_config: Config
    best_value: float
    history: List[Trial]
    n_tests: int

    @property
    def best_trial(self) -> Optional[Trial]:
        best = None
        for t in self.history:
            if best is None or t.value < best.value:
                best = t
        return best

    def best_so_far(self) -> List[float]:
        """Monotone best-value trace, one entry per test (for convergence plots)."""
        out: List[float] = []
        cur = float("inf")
        for t in sorted(self.history, key=lambda t: t.test_index):
            cur = min(cur, t.value)
            out.append(cur)
        return out


Objective = Callable[[Config], float]

# A batch objective scores a whole candidate round in one call.  It may
# return values for a strict *prefix* of the requested configs: a short
# return means the resource limit was exhausted after that prefix, and the
# caller must record the prefix and stop.
BatchObjective = Callable[[Sequence[Config]], Sequence[float]]


class BudgetedRun:
    """Shared optimizer bookkeeping: budget enforcement + history + best.

    ``evaluate_batch`` scores one candidate round.  The round is truncated
    to the remaining budget; if the objective itself runs out of resource
    (a short prefix return from a ``BatchObjective``), the prefix is
    recorded before ``BudgetExhausted`` propagates — exactly what a
    point-by-point loop would have left behind.  Candidate rounds are
    scored through ``batch_objective`` when one is provided (the tuner's
    vectorized ``BatchEvaluator`` path) and per-config otherwise; the two
    modes evaluate the identical trial sequence.
    """

    def __init__(self, space, objective: Optional[Objective], budget: int,
                 batch_objective: Optional[BatchObjective] = None):
        self.space = space
        self.objective = objective
        self.batch_objective = batch_objective
        self.budget = budget
        self.history: List[Trial] = []
        self.n_tests = 0
        self.best_u = None
        self.best_val = math.inf

    @property
    def remaining(self) -> int:
        return self.budget - self.n_tests

    def evaluate_batch(self, units, phase: str):
        units = np.atleast_2d(np.asarray(units, dtype=float))
        if self.remaining <= 0:
            raise BudgetExhausted
        truncated = len(units) > self.remaining
        units = units[: self.remaining]
        cfgs = self.space.from_unit_matrix(units)
        if self.batch_objective is not None:
            vals = [float(v) for v in self.batch_objective(cfgs)]
        else:
            vals = []
            try:
                for cfg in cfgs:
                    vals.append(float(self.objective(cfg)))
            except BudgetExhausted:
                pass  # record the prefix below, then re-raise
        for u, cfg, val in zip(units, cfgs, vals):
            self.n_tests += 1
            self.history.append(Trial(cfg, val, self.n_tests, phase))
            if val < self.best_val:
                self.best_val, self.best_u = val, u.copy()
        if truncated or len(vals) < len(units):
            raise BudgetExhausted
        return np.asarray(vals)

    def evaluate(self, u, phase: str) -> float:
        return float(
            self.evaluate_batch(np.asarray(u, float)[None], phase)[0])

    def result(self) -> TuningResult:
        if self.best_u is None:
            return TuningResult(
                self.space.default_config(), math.inf, self.history,
                self.n_tests)
        return TuningResult(
            self.space.from_unit_vector(self.best_u),
            self.best_val,
            self.history,
            self.n_tests,
        )
