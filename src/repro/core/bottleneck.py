"""Bottleneck identification via tuning (paper §5.5).

Procedure:
  1. Tune every member system to its best performance in isolation.
  2. Tune the composed deployment (joint knob space) to its best.
  3. If the composed best stays near some member's *untuned* level while that
     member tunes well in isolation, the ceiling lives elsewhere — the member
     whose tuned-alone throughput is the lowest is the bottleneck; if the
     composition underperforms every tuned member, the *interaction* is.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from .surrogates import ComposedSUT, Surrogate
from .tuner import Tuner, TuningReport

__all__ = ["BottleneckReport", "identify_bottleneck"]


@dataclass
class BottleneckReport:
    member_reports: Dict[str, TuningReport]
    composed_report: TuningReport
    bottleneck: str  # member name, or "<interaction>"
    rationale: str

    def summary(self) -> str:
        lines = ["bottleneck identification (§5.5):"]
        for name, rep in self.member_reports.items():
            lines.append(
                f"  {name:<10} alone: default={rep.default_metric.value:10.1f} "
                f"tuned={rep.best_metric.value:10.1f} "
                f"(+{(rep.improvement - 1) * 100:5.1f}%)"
            )
        rep = self.composed_report
        lines.append(
            f"  {'composed':<10}      : default={rep.default_metric.value:10.1f} "
            f"tuned={rep.best_metric.value:10.1f} "
            f"(+{(rep.improvement - 1) * 100:5.1f}%)"
        )
        lines.append(f"  => bottleneck: {self.bottleneck} ({self.rationale})")
        return "\n".join(lines)


def identify_bottleneck(
    members: Dict[str, Surrogate],
    budget_per_system: int = 60,
    seed: int = 0,
    interaction_margin: float = 0.10,
) -> BottleneckReport:
    member_reports: Dict[str, TuningReport] = {}
    for name, sut in members.items():
        tuner = Tuner(sut.space(), sut, budget=budget_per_system, seed=seed)
        member_reports[name] = tuner.run()

    composed = ComposedSUT(members)
    tuner = Tuner(composed.space(), composed, budget=budget_per_system, seed=seed)
    composed_report = tuner.run()

    tuned_alone = {n: r.best_metric.value for n, r in member_reports.items()}
    weakest = min(tuned_alone, key=tuned_alone.get)
    composed_best = composed_report.best_metric.value

    if composed_best < (1.0 - interaction_margin) * tuned_alone[weakest]:
        bottleneck = "<interaction>"
        rationale = (
            f"composed best {composed_best:.0f} is >{interaction_margin:.0%} below "
            f"every member's tuned-alone best (min {tuned_alone[weakest]:.0f}) — "
            "member systems are interacting (§5.5, last case)"
        )
    else:
        bottleneck = weakest
        rationale = (
            f"{weakest} has the lowest tuned-alone throughput "
            f"({tuned_alone[weakest]:.0f}); the composed deployment tracks it "
            f"({composed_best:.0f}) no matter how the others are tuned"
        )
    return BottleneckReport(member_reports, composed_report, bottleneck, rationale)
