"""Cross-system co-tuning: several SUTs as ONE system under tune.

The ACTS paper's §2.1 observation is that co-deployed systems "can interact
to affect the overall performance, they must be tuned together".  This
module makes that first-class:

* ``CompositeSpace`` joins named per-system ``ParameterSpace``s under
  prefixed keys (``"serve.max_batch"``) while keeping each subspace's own
  unit-matrix conversion — the vectorized batch path delegates each
  member's column block to that member's ``from_unit_matrix``, so frozen
  views, custom ``Parameter`` subclasses and subclassed spaces convert
  exactly as they would standalone.
* ``CompositeSUT`` aggregates member SUTs under ONE shared resource limit:
  a joint test applies one subconfig per member, collects one
  ``PerfMetric`` per member, and scalarizes them into the composite's
  single objective (throughput-under-latency-SLA, a weighted objective, or
  any callable).  It implements the tuner's ``BatchEvaluator`` protocol, so
  a batched optimizer round stays one ``test_batch`` call end to end.
* ``SubspaceRoundRobinOptimizer`` is BestConfig-style divide-and-diverge
  (Zhu et al., 2017) over the composite's subspaces: tune one subspace at a
  time in a shrinking window around the incumbent (divide), restart from a
  fresh joint LHS round when the whole cycle stalls (diverge).  The joint
  space's dimensionality therefore never inflates a single sampling round —
  each round is a low-dimensional LHS — which is what keeps the sample
  budget meaningful as subspaces are added.

Registered as optimizer ``"subspace_rr"``; on a non-composite space it
degrades to per-parameter round-robin (cyclic low-dimensional search).
"""
from __future__ import annotations

import copy
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence

import numpy as np

from .base import BatchObjective, BudgetedRun, BudgetExhausted, \
    Feasible, Objective, TuningResult
from .optimizers import OPTIMIZERS
from .params import Config, Parameter, ParameterSpace
from .sampling import lhs_unit
from .tuner import PerfMetric

__all__ = [
    "CompositeSpace",
    "CompositeSUT",
    "SubspaceRoundRobinOptimizer",
    "weighted_objective",
    "throughput_under_sla",
]


class CompositeSpace(ParameterSpace):
    """Named per-system subspaces joined into one joint knob space.

    Every member knob appears as ``f"{member}{sep}{knob}"``; the unit
    hypercube is the concatenation of the members' hypercubes (member order
    = column order).  Conversion, validation and defaults all delegate to
    the member spaces, so a ``FrozenSpaceView`` member keeps emitting its
    fixed values and a subclassed space keeps its own conversion kernels.
    """

    def __init__(self, subspaces: Mapping[str, ParameterSpace],
                 sep: str = "."):
        if not subspaces:
            raise ValueError("CompositeSpace needs at least one subspace")
        self.sep = sep
        self._subspaces: Dict[str, ParameterSpace] = {}
        self._slices: Dict[str, slice] = {}
        params: List[Parameter] = []
        col = 0
        for name, sub in subspaces.items():
            if not name or sep in name:
                raise ValueError(
                    f"bad subspace name {name!r}: must be non-empty and "
                    f"must not contain the separator {sep!r}")
            self._subspaces[name] = sub
            self._slices[name] = slice(col, col + sub.dim)
            col += sub.dim
            for p in sub:
                q = copy.copy(p)
                object.__setattr__(q, "name", f"{name}{sep}{p.name}")
                params.append(q)
        super().__init__(params)

    # --- structure ---------------------------------------------------------
    @property
    def subspace_names(self) -> List[str]:
        return list(self._subspaces)

    def subspace(self, name: str) -> ParameterSpace:
        return self._subspaces[name]

    def column_groups(self) -> Dict[str, List[int]]:
        """Unit-cube column indices per subspace (member order)."""
        return {name: list(range(s.start, s.stop))
                for name, s in self._slices.items()}

    def split(self, config: Mapping[str, Any]) -> Dict[str, Config]:
        """Joint config -> per-member subconfigs (prefixes stripped)."""
        out: Dict[str, Config] = {name: {} for name in self._subspaces}
        for key, v in config.items():
            name, _, knob = key.partition(self.sep)
            if not knob or name not in self._subspaces:
                raise ValueError(
                    f"config key {key!r} does not belong to any subspace "
                    f"of {self.subspace_names}")
            out[name][knob] = v
        return out

    def join(self, subconfigs: Mapping[str, Mapping[str, Any]]) -> Config:
        """Per-member subconfigs -> one prefixed joint config."""
        cfg: Config = {}
        for name, sub in subconfigs.items():
            if name not in self._subspaces:
                raise ValueError(f"unknown subspace {name!r}")
            for k, v in sub.items():
                cfg[f"{name}{self.sep}{k}"] = v
        return cfg

    # --- conversion (delegated per subspace) -------------------------------
    def default_config(self) -> Config:
        return self.join({name: sub.default_config()
                          for name, sub in self._subspaces.items()})

    def from_unit_matrix(self, units: np.ndarray) -> List[Config]:
        units = np.atleast_2d(np.asarray(units, dtype=float))
        if units.shape[1] != self.dim:
            raise ValueError(
                f"expected shape (m, {self.dim}), got {units.shape}")
        merged: List[Config] = [{} for _ in range(len(units))]
        for name, sub in self._subspaces.items():
            sep = f"{name}{self.sep}"
            for row, sub_cfg in zip(
                    merged, sub.from_unit_matrix(units[:, self._slices[name]])):
                for k, v in sub_cfg.items():
                    row[sep + k] = v
        return merged

    def from_unit_vector(self, u: np.ndarray) -> Config:
        u = np.asarray(u, dtype=float)
        if u.shape != (self.dim,):
            raise ValueError(f"expected shape ({self.dim},), got {u.shape}")
        return self.join({name: sub.from_unit_vector(u[self._slices[name]])
                          for name, sub in self._subspaces.items()})

    def to_unit_vector(self, config: Mapping[str, Any]) -> np.ndarray:
        parts = self.split(config)
        return np.concatenate([
            np.asarray(sub.to_unit_vector(parts[name]), dtype=float)
            for name, sub in self._subspaces.items()
        ]) if self.dim else np.zeros(0)

    def validate(self, config: Mapping[str, Any]) -> None:
        parts = self.split(config)
        for name, sub in self._subspaces.items():
            sub.validate(parts[name])


# ---------------------------------------------------------------------------
# scalarizers: Dict[member, PerfMetric] x Dict[member, Config] -> PerfMetric
# ---------------------------------------------------------------------------
Scalarizer = Callable[[Dict[str, PerfMetric], Dict[str, Config]], PerfMetric]


def weighted_objective(weights: Mapping[str, float]) -> Scalarizer:
    """Weighted sum of member objectives (each in its minimization view).

    Members measure in their own units; the weights are the exchange rate.
    Missing members default to weight 0 (measured but not scored).
    """

    def scalarize(metrics: Dict[str, PerfMetric],
                  configs: Dict[str, Config]) -> PerfMetric:
        parts = {name: float(weights.get(name, 0.0)) * m.objective()
                 for name, m in metrics.items()}
        return PerfMetric(value=float(sum(parts.values())),
                          higher_is_better=False,
                          metrics={"weighted_parts": parts})

    return scalarize


def throughput_under_sla(throughput_member: str, sla_s: float,
                         latency_member: Optional[str] = None,
                         latency_key: str = "latency_s",
                         penalty: float = 2.0) -> Scalarizer:
    """Maximize one member's throughput subject to a latency SLA.

    The SLA is enforced as a smooth penalty — ``tput * (sla/lat)**penalty``
    past the bound — so the optimizer keeps gradient information instead of
    falling off a feasibility cliff.
    """

    def scalarize(metrics: Dict[str, PerfMetric],
                  configs: Dict[str, Config]) -> PerfMetric:
        tput = float(metrics[throughput_member].value)
        src = latency_member or throughput_member
        raw_lat = metrics[src].metrics.get(latency_key)
        if raw_lat is None:
            # A missing measurement must not read as a met SLA — that
            # would silently drop the constraint from the whole search.
            raise ValueError(
                f"member {src!r} recorded no {latency_key!r} metric; "
                f"throughput_under_sla needs the latency measurement")
        lat = float(raw_lat)
        ok = lat <= sla_s
        value = tput if ok or lat <= 0 else tput * (sla_s / lat) ** penalty
        return PerfMetric(value=float(value), higher_is_better=True,
                          metrics={"raw_throughput": tput,
                                   "latency_s": lat, "sla_s": sla_s,
                                   "sla_met": bool(ok)})

    return scalarize


# ---------------------------------------------------------------------------
class CompositeSUT:
    """Member SUTs co-tuned as one system under one resource limit.

    One joint test = one test on every member (their subconfig applied),
    scalarized into a single ``PerfMetric`` — so the tuner's budget counts
    *co-deployment tests*, the machine-time unit of a staging environment
    that restarts every member per trial.  Implements ``BatchEvaluator``:
    a candidate round is split once and dispatched to each member's
    ``test_batch`` in a single call (per-config fallback for test-only
    members), keeping batched rounds O(members) Python calls.

    The scalarizer receives all member metrics AND all member subconfigs —
    cross-system interaction (e.g. a kernel block choice shifting the serve
    engine's optimal batching point) lives there, in the composition model,
    not in the members.

    A member given as a bare ``ParameterSpace`` is a **config-only
    subsystem**: its knobs join the space and reach the scalarizer, but no
    standalone evaluator runs for it — for subsystems whose contribution
    only exists in composition (no meaningful isolated measurement, or one
    the scalarizer would recompute anyway).

    Member feasibility models compose: every member exposing a
    ``feasibility_model`` contributes its predicates under the member's
    prefixed keys (``feasibility`` adds/overrides models per member name —
    the only way to constrain a config-only member, which has no SUT
    object to hang a model on).  The composed model is what the ``Tuner``
    auto-detects, so a joint candidate whose ANY subconfig is statically
    infeasible is pruned before a single member evaluates.
    """

    def __init__(self, members: Mapping[str, Any], scalarize: Scalarizer,
                 name: Optional[str] = None, sep: str = ".",
                 feasibility: Optional[Mapping[str, Any]] = None):
        if not members:
            raise ValueError("CompositeSUT needs at least one member")
        self.members = dict(members)
        self.scalarize = scalarize
        spaces: Dict[str, ParameterSpace] = {}
        self._evaluated: List[str] = []
        for n, m in self.members.items():
            if isinstance(m, ParameterSpace):
                spaces[n] = m  # config-only subsystem
            else:
                spaces[n] = m.space()
                self._evaluated.append(n)
        self._space = CompositeSpace(spaces, sep=sep)
        self.name = name or "+".join(self.members)
        models: Dict[str, Any] = {}
        for n, m in self.members.items():
            model = getattr(m, "feasibility_model", None)
            if model is not None:
                models[n] = model
        for n, model in dict(feasibility or {}).items():
            if n not in self.members:
                raise ValueError(f"feasibility for unknown member {n!r}")
            models[n] = model
        self.feasibility_model = None
        if models:
            from repro.analysis.feasibility import CompositeFeasibility

            self.feasibility_model = CompositeFeasibility(models, sep=sep)
        # dispatch accounting (the quantity the batched engine minimizes)
        self.member_batch_calls = {n: 0 for n in self._evaluated}
        self.member_test_calls = {n: 0 for n in self._evaluated}

    def space(self) -> CompositeSpace:
        return self._space

    def test(self, config: Config) -> PerfMetric:
        return self.test_batch([config])[0]

    def test_batch(self, configs: Sequence[Config]) -> List[PerfMetric]:
        parts = [self._space.split(c) for c in configs]
        per_member: Dict[str, List[PerfMetric]] = {}
        for name in self._evaluated:
            member = self.members[name]
            subs = [p[name] for p in parts]
            batch = getattr(member, "test_batch", None)
            if callable(batch):
                self.member_batch_calls[name] += 1
                metrics = list(batch(subs))
                if len(metrics) != len(subs):
                    raise ValueError(
                        f"member {name!r} returned {len(metrics)} metrics "
                        f"for {len(subs)} configs")
            else:
                self.member_test_calls[name] += len(subs)
                metrics = [member.test(c) for c in subs]
            per_member[name] = metrics
        out: List[PerfMetric] = []
        for i, part in enumerate(parts):
            row = {name: per_member[name][i] for name in self._evaluated}
            metric = self.scalarize(row, part)
            metric.metrics.setdefault(
                "member_values",
                {name: float(row[name].value) for name in self._evaluated})
            out.append(metric)
        return out


# ---------------------------------------------------------------------------
class SubspaceRoundRobinOptimizer:
    """BestConfig-style divide-and-diverge over a composite space.

    DIVIDE: visit subspaces round-robin; each visit is ONE candidate round
    of ``round_size`` LHS samples that vary only that subspace's columns
    inside a window of width ``span`` around the incumbent (every other
    column pinned).  The incumbent moves to the round's best improver.
    DIVERGE: a full cycle with no improvement shrinks the window; when it
    bottoms out below ``min_span``, restart from a fresh joint LHS round
    and re-center on its best sample even if worse — BestConfig's escape
    from local optima.

    Round-synchronous like every optimizer here: candidate generation never
    depends on the dispatch mode, so batched and sequential runs score the
    identical trial sequence.
    """

    def __init__(self, round_size: int = 7, span: float = 1.0,
                 shrink: float = 0.5, min_span: float = 0.05,
                 diverge_size: Optional[int] = None):
        if round_size < 1:
            raise ValueError("round_size must be >= 1")
        if not (0 < shrink < 1):
            raise ValueError("shrink must be in (0, 1)")
        self.round_size = round_size
        self.span0 = span
        self.shrink = shrink
        self.min_span = min_span
        self.diverge_size = diverge_size

    def optimize(
        self,
        space: ParameterSpace,
        objective: Objective,
        budget: int,
        rng: np.random.Generator,
        init_unit_points: Optional[np.ndarray] = None,
        batch_objective: Optional[BatchObjective] = None,
        feasible: Optional[Feasible] = None,
    ) -> TuningResult:
        run = BudgetedRun(space, objective, budget, batch_objective,
                          feasible=feasible)
        dim = space.dim
        if isinstance(space, CompositeSpace):
            groups = [np.asarray(g) for g in space.column_groups().values()]
        else:  # degrade gracefully: one group per parameter
            groups = [np.asarray([j]) for j in range(dim)]
        diverge_n = self.diverge_size or max(2 * dim, 8)
        try:
            if init_unit_points is not None:
                run.evaluate_batch(np.atleast_2d(init_unit_points), "explore")
            if run.best_u is None:
                run.evaluate_batch(lhs_unit(diverge_n, dim, rng), "explore")
            incumbent = np.asarray(run.best_u, dtype=float).copy()
            inc_val = run.best_val
            span = self.span0
            while True:
                improved_cycle = False
                for g in groups:
                    local = lhs_unit(self.round_size, len(g), rng)
                    lo = np.clip(incumbent[g] - span / 2, 0.0,
                                 max(0.0, 1.0 - span))
                    hi = np.minimum(lo + span, 1.0)
                    cands = np.tile(incumbent, (self.round_size, 1))
                    cands[:, g] = lo + local * (hi - lo)
                    vals = run.evaluate_batch(cands, "exploit")
                    j = int(np.argmin(vals))
                    if float(vals[j]) < inc_val:
                        incumbent = cands[j].copy()
                        inc_val = float(vals[j])
                        improved_cycle = True
                if not improved_cycle:
                    span *= self.shrink
                    if span < self.min_span:
                        batch = lhs_unit(diverge_n, dim, rng)
                        vals = run.evaluate_batch(batch, "explore")
                        j = int(np.argmin(vals))
                        incumbent = np.asarray(batch[j], dtype=float).copy()
                        inc_val = float(vals[j])
                        span = self.span0
        except BudgetExhausted:
            pass
        return run.result()


# Self-registration keeps the optimizer registry import-cycle-free
# (tuner -> optimizers; composite -> tuner): importing repro.core (or any
# of its submodules) loads this module and makes "subspace_rr" available.
OPTIMIZERS["subspace_rr"] = SubspaceRoundRobinOptimizer
