"""Search-based optimizers for ACTS, plus the registry.

RRS (``repro.core.rrs``) is the algorithm the paper adopts.  The baselines
here are the methods the paper positions against:

* ``random``      — pure random sampling (the no-structure floor),
* ``lhs_only``    — a single LHS design, take the best (sampling w/o search),
* ``shc``         — Smart Hill-Climbing (Xi et al., WWW'04 [44]): LHS init,
                    then weighted-Gaussian sampling around the incumbent with
                    shrinking variance; restarts on stagnation,
* ``coordinate``  — cyclic one-knob-at-a-time line search (the "tuning guide"
                    strategy humans follow, §5.3).

All optimizers minimize, operate on the unit hypercube, and respect a strict
test budget — the resource limit of the ACTS problem definition (§3).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Type

import numpy as np

from .base import BudgetExhausted, Objective, Trial, TuningResult
from .params import Config, ParameterSpace
from .rrs import RRSOptimizer
from .sampling import lhs_unit

__all__ = [
    "RandomSearchOptimizer",
    "LHSOnlyOptimizer",
    "SmartHillClimbingOptimizer",
    "CoordinateSearchOptimizer",
    "get_optimizer",
    "OPTIMIZERS",
]


class _BudgetedRun:
    """Shared bookkeeping: budget enforcement + history + best tracking."""

    def __init__(self, space: ParameterSpace, objective: Objective, budget: int):
        self.space = space
        self.objective = objective
        self.budget = budget
        self.history: List[Trial] = []
        self.n_tests = 0
        self.best_u: Optional[np.ndarray] = None
        self.best_val = math.inf

    def evaluate(self, u: np.ndarray, phase: str) -> float:
        if self.n_tests >= self.budget:
            raise BudgetExhausted
        cfg = self.space.from_unit_vector(u)
        val = float(self.objective(cfg))
        self.n_tests += 1
        self.history.append(Trial(cfg, val, self.n_tests, phase))
        if val < self.best_val:
            self.best_val, self.best_u = val, u.copy()
        return val

    def result(self) -> TuningResult:
        if self.best_u is None:
            return TuningResult(
                self.space.default_config(), math.inf, self.history, self.n_tests
            )
        return TuningResult(
            self.space.from_unit_vector(self.best_u),
            self.best_val,
            self.history,
            self.n_tests,
        )


class RandomSearchOptimizer:
    def optimize(
        self,
        space: ParameterSpace,
        objective: Objective,
        budget: int,
        rng: np.random.Generator,
        init_unit_points: Optional[np.ndarray] = None,
    ) -> TuningResult:
        run = _BudgetedRun(space, objective, budget)
        try:
            if init_unit_points is not None:
                for u in np.atleast_2d(init_unit_points):
                    run.evaluate(np.asarray(u, float), "explore")
            while True:
                run.evaluate(rng.random(space.dim), "explore")
        except BudgetExhausted:
            pass
        return run.result()


class LHSOnlyOptimizer:
    """One Latin hypercube of size == budget; best sample wins."""

    def optimize(
        self,
        space: ParameterSpace,
        objective: Objective,
        budget: int,
        rng: np.random.Generator,
        init_unit_points: Optional[np.ndarray] = None,
    ) -> TuningResult:
        run = _BudgetedRun(space, objective, budget)
        try:
            if init_unit_points is not None:
                for u in np.atleast_2d(init_unit_points):
                    run.evaluate(np.asarray(u, float), "explore")
            remaining = budget - run.n_tests
            for u in lhs_unit(remaining, space.dim, rng):
                run.evaluate(u, "explore")
        except BudgetExhausted:
            pass
        return run.result()


class SmartHillClimbingOptimizer:
    """Smart Hill-Climbing (Xi et al. 2004), simplified:

    LHS initial design → Gaussian proposals around the incumbent with
    per-round variance shrink; random restart after ``patience`` stale rounds.
    """

    def __init__(self, init_frac: float = 0.25, shrink: float = 0.7,
                 patience: int = 5, sigma0: float = 0.25):
        self.init_frac = init_frac
        self.shrink = shrink
        self.patience = patience
        self.sigma0 = sigma0

    def optimize(
        self,
        space: ParameterSpace,
        objective: Objective,
        budget: int,
        rng: np.random.Generator,
        init_unit_points: Optional[np.ndarray] = None,
    ) -> TuningResult:
        run = _BudgetedRun(space, objective, budget)
        dim = space.dim
        try:
            if init_unit_points is not None:
                for u in np.atleast_2d(init_unit_points):
                    run.evaluate(np.asarray(u, float), "explore")
            n_init = max(2, int(budget * self.init_frac) - run.n_tests)
            for u in lhs_unit(n_init, dim, rng):
                run.evaluate(u, "explore")
            sigma, stale = self.sigma0, 0
            incumbent = run.best_u if run.best_u is not None else rng.random(dim)
            incumbent_val = run.best_val
            while True:
                cand = np.clip(incumbent + rng.normal(0, sigma, dim), 0, 1 - 1e-12)
                val = run.evaluate(cand, "exploit")
                if val < incumbent_val:
                    incumbent, incumbent_val = cand, val
                    stale = 0
                else:
                    stale += 1
                    if stale % 2 == 0:
                        sigma = max(sigma * self.shrink, 1e-3)
                    if stale >= self.patience:
                        incumbent = rng.random(dim)  # restart
                        incumbent_val = run.evaluate(incumbent, "explore")
                        sigma, stale = self.sigma0, 0
        except BudgetExhausted:
            pass
        return run.result()


class CoordinateSearchOptimizer:
    """Cyclic coordinate line search — the manual-tuning-guide strategy."""

    def __init__(self, points_per_axis: int = 5, shrink: float = 0.5):
        self.points_per_axis = points_per_axis
        self.shrink = shrink

    def optimize(
        self,
        space: ParameterSpace,
        objective: Objective,
        budget: int,
        rng: np.random.Generator,
        init_unit_points: Optional[np.ndarray] = None,
    ) -> TuningResult:
        run = _BudgetedRun(space, objective, budget)
        dim = space.dim
        try:
            if init_unit_points is not None:
                for u in np.atleast_2d(init_unit_points):
                    run.evaluate(np.asarray(u, float), "explore")
            x = space.to_unit_vector(space.default_config())
            fx = run.evaluate(x, "explore")
            span = 1.0
            while True:
                improved_any = False
                for j in range(dim):
                    lo = max(0.0, x[j] - span / 2)
                    hi = min(1.0, x[j] + span / 2)
                    for t in np.linspace(lo, hi, self.points_per_axis):
                        cand = x.copy()
                        cand[j] = min(t, 1 - 1e-12)
                        if abs(cand[j] - x[j]) < 1e-12:
                            continue
                        val = run.evaluate(cand, "exploit")
                        if val < fx:
                            x, fx = cand, val
                            improved_any = True
                if not improved_any:
                    span *= self.shrink
                    if span < 1e-3:
                        x = rng.random(dim)
                        fx = run.evaluate(x, "explore")
                        span = 1.0
        except BudgetExhausted:
            pass
        return run.result()


OPTIMIZERS: Dict[str, type] = {
    "rrs": RRSOptimizer,
    "random": RandomSearchOptimizer,
    "lhs_only": LHSOnlyOptimizer,
    "shc": SmartHillClimbingOptimizer,
    "coordinate": CoordinateSearchOptimizer,
}


def get_optimizer(name: str, **kwargs):
    try:
        cls = OPTIMIZERS[name]
    except KeyError:
        raise ValueError(f"unknown optimizer {name!r}; have {sorted(OPTIMIZERS)}")
    return cls(**kwargs)
