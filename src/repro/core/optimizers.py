"""Search-based optimizers for ACTS, plus the registry.

RRS (``repro.core.rrs``) is the algorithm the paper adopts.  The baselines
here are the methods the paper positions against:

* ``random``      — pure random sampling (the no-structure floor),
* ``lhs_only``    — a single LHS design, take the best (sampling w/o search),
* ``shc``         — Smart Hill-Climbing (Xi et al., WWW'04 [44]): LHS init,
                    then weighted-Gaussian sampling around the incumbent with
                    shrinking variance; restarts on stagnation,
* ``coordinate``  — cyclic one-knob-at-a-time line search (the "tuning guide"
                    strategy humans follow, §5.3).

``subspace_rr`` (BestConfig-style divide-and-diverge over a composite
space's subspaces) lives in ``repro.core.composite`` and registers itself
into ``OPTIMIZERS`` on import — keeping the registry here import-cycle-free.

All optimizers minimize, operate on the unit hypercube, and respect a strict
test budget — the resource limit of the ACTS problem definition (§3).

Every optimizer is *round-based*: candidates are generated a whole round at
a time and scored through ``_BudgetedRun.evaluate_batch``, which dispatches
to a vectorized ``batch_objective`` when one is provided (the tuner's
``BatchEvaluator`` path) and falls back to a per-config loop otherwise.
Candidate generation never depends on the dispatch mode, so batched and
sequential runs of the same seed evaluate the *identical* trial sequence —
the parity guarantee the batched-tuning tests pin down.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Type

import numpy as np

from .base import BatchObjective, BudgetedRun, BudgetExhausted, \
    Feasible, Objective, Trial, TuningResult
from .params import Config, ParameterSpace
from .rrs import RRSOptimizer
from .sampling import lhs_unit

_BudgetedRun = BudgetedRun  # shared bookkeeping lives in base.py

__all__ = [
    "RandomSearchOptimizer",
    "LHSOnlyOptimizer",
    "SmartHillClimbingOptimizer",
    "CoordinateSearchOptimizer",
    "get_optimizer",
    "OPTIMIZERS",
]


class RandomSearchOptimizer:
    """Uniform random sampling in rounds of ``round_size``."""

    def __init__(self, round_size: int = 64):
        self.round_size = max(1, round_size)

    def optimize(
        self,
        space: ParameterSpace,
        objective: Objective,
        budget: int,
        rng: np.random.Generator,
        init_unit_points: Optional[np.ndarray] = None,
        batch_objective: Optional[BatchObjective] = None,
        feasible: Optional[Feasible] = None,
    ) -> TuningResult:
        run = _BudgetedRun(space, objective, budget, batch_objective,
                           feasible=feasible)
        try:
            if init_unit_points is not None:
                run.evaluate_batch(np.atleast_2d(init_unit_points), "explore")
            while True:
                n = min(self.round_size, max(run.remaining, 1))
                run.evaluate_batch(rng.random((n, space.dim)), "explore")
        except BudgetExhausted:
            pass
        return run.result()


class LHSOnlyOptimizer:
    """One Latin hypercube of size == budget; best sample wins."""

    def optimize(
        self,
        space: ParameterSpace,
        objective: Objective,
        budget: int,
        rng: np.random.Generator,
        init_unit_points: Optional[np.ndarray] = None,
        batch_objective: Optional[BatchObjective] = None,
        feasible: Optional[Feasible] = None,
    ) -> TuningResult:
        run = _BudgetedRun(space, objective, budget, batch_objective,
                           feasible=feasible)
        try:
            if init_unit_points is not None:
                run.evaluate_batch(np.atleast_2d(init_unit_points), "explore")
            remaining = run.remaining
            if remaining > 0:
                run.evaluate_batch(lhs_unit(remaining, space.dim, rng),
                                   "explore")
        except BudgetExhausted:
            pass
        return run.result()


class SmartHillClimbingOptimizer:
    """Smart Hill-Climbing (Xi et al. 2004), simplified:

    LHS initial design (one batched round) → Gaussian proposals around the
    incumbent with per-round variance shrink; random restart after
    ``patience`` stale rounds.  The climb itself is inherently sequential
    (every proposal conditions on the previous outcome), so proposals run
    as rounds of one.
    """

    def __init__(self, init_frac: float = 0.25, shrink: float = 0.7,
                 patience: int = 5, sigma0: float = 0.25):
        self.init_frac = init_frac
        self.shrink = shrink
        self.patience = patience
        self.sigma0 = sigma0

    def optimize(
        self,
        space: ParameterSpace,
        objective: Objective,
        budget: int,
        rng: np.random.Generator,
        init_unit_points: Optional[np.ndarray] = None,
        batch_objective: Optional[BatchObjective] = None,
        feasible: Optional[Feasible] = None,
    ) -> TuningResult:
        run = _BudgetedRun(space, objective, budget, batch_objective,
                           feasible=feasible)
        dim = space.dim
        try:
            if init_unit_points is not None:
                run.evaluate_batch(np.atleast_2d(init_unit_points), "explore")
            n_init = max(2, int(budget * self.init_frac) - run.n_tests)
            run.evaluate_batch(lhs_unit(n_init, dim, rng), "explore")
            sigma, stale = self.sigma0, 0
            incumbent = run.best_u if run.best_u is not None else rng.random(dim)
            incumbent_val = run.best_val
            while True:
                cand = np.clip(incumbent + rng.normal(0, sigma, dim), 0, 1 - 1e-12)
                val = run.evaluate(cand, "exploit")
                if val < incumbent_val:
                    incumbent, incumbent_val = cand, val
                    stale = 0
                else:
                    stale += 1
                    if stale % 2 == 0:
                        sigma = max(sigma * self.shrink, 1e-3)
                    if stale >= self.patience:
                        incumbent = rng.random(dim)  # restart
                        incumbent_val = run.evaluate(incumbent, "explore")
                        sigma, stale = self.sigma0, 0
        except BudgetExhausted:
            pass
        return run.result()


class CoordinateSearchOptimizer:
    """Cyclic coordinate line search — the manual-tuning-guide strategy.

    Each axis sweep is one candidate round: all probe points along the axis
    are generated from the current incumbent and scored together, then the
    incumbent moves to the best improving probe.
    """

    def __init__(self, points_per_axis: int = 5, shrink: float = 0.5):
        self.points_per_axis = points_per_axis
        self.shrink = shrink

    def optimize(
        self,
        space: ParameterSpace,
        objective: Objective,
        budget: int,
        rng: np.random.Generator,
        init_unit_points: Optional[np.ndarray] = None,
        batch_objective: Optional[BatchObjective] = None,
        feasible: Optional[Feasible] = None,
    ) -> TuningResult:
        run = _BudgetedRun(space, objective, budget, batch_objective,
                           feasible=feasible)
        dim = space.dim
        try:
            if init_unit_points is not None:
                run.evaluate_batch(np.atleast_2d(init_unit_points), "explore")
            x = space.to_unit_vector(space.default_config())
            fx = run.evaluate(x, "explore")
            span = 1.0
            while True:
                improved_any = False
                for j in range(dim):
                    lo = max(0.0, x[j] - span / 2)
                    hi = min(1.0, x[j] + span / 2)
                    cands = []
                    for t in np.linspace(lo, hi, self.points_per_axis):
                        cand = x.copy()
                        cand[j] = min(t, 1 - 1e-12)
                        if abs(cand[j] - x[j]) < 1e-12:
                            continue
                        cands.append(cand)
                    if not cands:
                        continue
                    vals = run.evaluate_batch(np.stack(cands), "exploit")
                    best_i = int(np.argmin(vals))
                    if vals[best_i] < fx:
                        x, fx = cands[best_i], float(vals[best_i])
                        improved_any = True
                if not improved_any:
                    span *= self.shrink
                    if span < 1e-3:
                        x = rng.random(dim)
                        fx = run.evaluate(x, "explore")
                        span = 1.0
        except BudgetExhausted:
            pass
        return run.result()


OPTIMIZERS: Dict[str, type] = {
    "rrs": RRSOptimizer,
    "random": RandomSearchOptimizer,
    "lhs_only": LHSOnlyOptimizer,
    "shc": SmartHillClimbingOptimizer,
    "coordinate": CoordinateSearchOptimizer,
}


def get_optimizer(name: str, **kwargs):
    try:
        cls = OPTIMIZERS[name]
    except KeyError:
        raise ValueError(f"unknown optimizer {name!r}; have {sorted(OPTIMIZERS)}")
    return cls(**kwargs)
