"""Configuration-parameter spaces for ACTS.

The paper (§3, §4.1) requires the tuner to handle *all* knob types — boolean,
enumeration and numerics — over wide ranges, and to scale with the size of the
parameter set.  Every parameter therefore knows how to map itself to and from
the unit interval, so the whole space is a unit hypercube on which LHS and RRS
operate uniformly regardless of knob type.

Parameters are deliberately framework-agnostic (pure numpy): the same space
implementation tunes a surrogate MySQL, a Tomcat model, or the JAX distributed
runtime (``repro.core.sut_jax``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Parameter",
    "BoolParam",
    "EnumParam",
    "IntParam",
    "FloatParam",
    "ParameterSpace",
]


class Parameter:
    """Base class: a named, bounded, unit-mappable configuration knob."""

    name: str
    default: Any

    # --- unit-cube mapping ------------------------------------------------
    def from_unit(self, u: float) -> Any:
        """Map ``u ∈ [0, 1)`` to a concrete knob value."""
        raise NotImplementedError

    def to_unit(self, value: Any) -> float:
        """Map a concrete knob value to a representative ``u ∈ [0, 1)``."""
        raise NotImplementedError

    def validate(self, value: Any) -> bool:
        raise NotImplementedError

    def from_unit_array(self, us: np.ndarray) -> List[Any]:
        """Vectorized ``from_unit`` over a 1-D array of unit samples.

        The input must already be clipped to [0, 1) —
        ``ParameterSpace.from_unit_matrix`` clips the whole sample matrix
        once so the per-parameter kernels stay allocation-light.  Returns
        plain Python values (the scalar path's types), so configs built
        from a batch are indistinguishable from per-point ones.
        """
        return [self.from_unit(float(u)) for u in us]

    # Number of distinct values (None for continuous).
    @property
    def cardinality(self) -> Optional[int]:
        return None

    def grid(self, n: int) -> List[Any]:
        """n representative values spanning the range (for surface plots)."""
        us = (np.arange(n) + 0.5) / n
        out: List[Any] = []
        for u in us:
            v = self.from_unit(float(u))
            if not out or out[-1] != v:
                out.append(v)
        return out


def _clip_unit(u: float) -> float:
    # Keep strictly inside [0, 1) so index arithmetic never overflows.
    return min(max(float(u), 0.0), np.nextafter(1.0, 0.0))


def _clip_unit_arr(us: np.ndarray) -> np.ndarray:
    return np.clip(np.asarray(us, dtype=float), 0.0, np.nextafter(1.0, 0.0))


@dataclass(frozen=True)
class BoolParam(Parameter):
    name: str
    default: bool = False

    def from_unit(self, u: float) -> bool:
        return _clip_unit(u) >= 0.5

    def from_unit_array(self, us: np.ndarray) -> List[Any]:
        return (us >= 0.5).tolist()

    def to_unit(self, value: Any) -> float:
        return 0.75 if value else 0.25

    def validate(self, value: Any) -> bool:
        return isinstance(value, (bool, np.bool_))

    @property
    def cardinality(self) -> Optional[int]:
        return 2


@dataclass(frozen=True)
class EnumParam(Parameter):
    name: str
    choices: Tuple[Any, ...]
    default: Any = None

    def __post_init__(self):
        if not self.choices:
            raise ValueError(f"EnumParam {self.name!r} needs at least one choice")
        if self.default is None:
            object.__setattr__(self, "default", self.choices[0])
        if self.default not in self.choices:
            raise ValueError(
                f"EnumParam {self.name!r}: default {self.default!r} not in choices"
            )

    def from_unit(self, u: float) -> Any:
        idx = int(_clip_unit(u) * len(self.choices))
        return self.choices[idx]

    def from_unit_array(self, us: np.ndarray) -> List[Any]:
        idx = (us * len(self.choices)).astype(np.int64)
        return [self.choices[i] for i in idx]

    def to_unit(self, value: Any) -> float:
        idx = self.choices.index(value)
        return (idx + 0.5) / len(self.choices)

    def validate(self, value: Any) -> bool:
        return value in self.choices

    @property
    def cardinality(self) -> Optional[int]:
        return len(self.choices)


@dataclass(frozen=True)
class IntParam(Parameter):
    name: str
    lo: int
    hi: int  # inclusive
    default: Optional[int] = None
    log: bool = False  # sample on a log scale (wide ranges, e.g. buffer sizes)

    def __post_init__(self):
        if self.hi < self.lo:
            raise ValueError(f"IntParam {self.name!r}: hi < lo")
        if self.log and self.lo <= 0:
            raise ValueError(f"IntParam {self.name!r}: log scale needs lo > 0")
        if self.default is None:
            object.__setattr__(self, "default", self.lo)
        if not (self.lo <= self.default <= self.hi):
            raise ValueError(f"IntParam {self.name!r}: default out of range")

    def from_unit(self, u: float) -> int:
        u = _clip_unit(u)
        if self.log:
            lo, hi = math.log(self.lo), math.log(self.hi + 1)
            return min(self.hi, int(math.exp(lo + u * (hi - lo))))
        return self.lo + int(u * (self.hi - self.lo + 1))

    def from_unit_array(self, us: np.ndarray) -> List[Any]:
        if self.log:
            lo, hi = math.log(self.lo), math.log(self.hi + 1)
            vals = np.minimum(
                self.hi, np.exp(lo + us * (hi - lo)).astype(np.int64))
        else:
            vals = self.lo + (us * (self.hi - self.lo + 1)).astype(np.int64)
        return vals.tolist()

    def to_unit(self, value: Any) -> float:
        v = int(value)
        if self.log:
            lo, hi = math.log(self.lo), math.log(self.hi + 1)
            return _clip_unit((math.log(v + 0.5) - lo) / (hi - lo))
        return _clip_unit((v - self.lo + 0.5) / (self.hi - self.lo + 1))

    def validate(self, value: Any) -> bool:
        return isinstance(value, (int, np.integer)) and self.lo <= value <= self.hi

    @property
    def cardinality(self) -> Optional[int]:
        return self.hi - self.lo + 1


@dataclass(frozen=True)
class FloatParam(Parameter):
    name: str
    lo: float
    hi: float
    default: Optional[float] = None
    log: bool = False

    def __post_init__(self):
        if self.hi <= self.lo:
            raise ValueError(f"FloatParam {self.name!r}: hi <= lo")
        if self.log and self.lo <= 0:
            raise ValueError(f"FloatParam {self.name!r}: log scale needs lo > 0")
        if self.default is None:
            object.__setattr__(self, "default", self.lo)
        if not (self.lo <= self.default <= self.hi):
            raise ValueError(f"FloatParam {self.name!r}: default out of range")

    def from_unit(self, u: float) -> float:
        u = _clip_unit(u)
        if self.log:
            lo, hi = math.log(self.lo), math.log(self.hi)
            return float(math.exp(lo + u * (hi - lo)))
        return float(self.lo + u * (self.hi - self.lo))

    def from_unit_array(self, us: np.ndarray) -> List[Any]:
        if self.log:
            lo, hi = math.log(self.lo), math.log(self.hi)
            return np.exp(lo + us * (hi - lo)).tolist()
        return (self.lo + us * (self.hi - self.lo)).tolist()

    def to_unit(self, value: Any) -> float:
        v = float(value)
        if self.log:
            lo, hi = math.log(self.lo), math.log(self.hi)
            return _clip_unit((math.log(v) - lo) / (hi - lo))
        return _clip_unit((v - self.lo) / (self.hi - self.lo))

    def validate(self, value: Any) -> bool:
        return isinstance(value, (int, float, np.floating)) and (
            self.lo <= float(value) <= self.hi
        )


Config = Dict[str, Any]


class ParameterSpace:
    """An ordered set of configuration parameters ≡ a unit hypercube.

    Supports the paper's parameter-set scalability requirement: spaces compose
    (``merge``) so co-deployed systems (e.g. Hadoop + JVM, §2.1; DB + frontend,
    §5.5) are tuned *together* in one joint space, and restrict (``subset``)
    so a tuner can be pointed at any knob subset without touching the SUT.
    """

    def __init__(self, params: Sequence[Parameter]):
        names = [p.name for p in params]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate parameter names: {dupes}")
        self._params: Dict[str, Parameter] = {p.name: p for p in params}

    # --- basic introspection ---------------------------------------------
    def __len__(self) -> int:
        return len(self._params)

    def __iter__(self):
        return iter(self._params.values())

    def __contains__(self, name: str) -> bool:
        return name in self._params

    def __getitem__(self, name: str) -> Parameter:
        return self._params[name]

    @property
    def names(self) -> List[str]:
        return list(self._params.keys())

    @property
    def dim(self) -> int:
        return len(self._params)

    def log_cardinality(self) -> float:
        """log10 of the number of distinct settings (inf if any continuous)."""
        total = 0.0
        for p in self:
            c = p.cardinality
            if c is None:
                return math.inf
            total += math.log10(c)
        return total

    # --- configs <-> unit vectors ------------------------------------------
    def default_config(self) -> Config:
        return {p.name: p.default for p in self}

    def from_unit_vector(self, u: np.ndarray) -> Config:
        u = np.asarray(u, dtype=float)
        if u.shape != (self.dim,):
            raise ValueError(f"expected shape ({self.dim},), got {u.shape}")
        return {p.name: p.from_unit(float(ui)) for p, ui in zip(self, u)}

    def _conversion_plan(self):
        """Group parameters by conversion kind for matrix-wide transforms.

        Computed once per space: sampling-heavy optimizer loops convert
        hundreds of small rounds, so the per-round fixed cost must be a
        handful of vector ops, not ~3 per parameter.
        """
        plan = {
            "bool": [], "enum": [], "int_lin": [], "int_log": [],
            "float_lin": [], "float_log": [], "custom": [],
        }
        for j, p in enumerate(self):
            t = type(p)
            if t is BoolParam:
                plan["bool"].append(j)
            elif t is EnumParam:
                plan["enum"].append((j, p.choices))
            elif t is IntParam:
                plan["int_log" if p.log else "int_lin"].append((j, p))
            elif t is FloatParam:
                plan["float_log" if p.log else "float_lin"].append((j, p))
            else:  # subclassed parameter: fall back to its own kernel
                plan["custom"].append((j, p))
        for kind in ("int_lin", "int_log", "float_lin", "float_log"):
            entries = plan[kind]
            if not entries:
                continue
            idx = [j for j, _ in entries]
            if kind == "int_lin":
                lo = np.array([p.lo for _, p in entries], float)
                span = np.array([p.hi - p.lo + 1 for _, p in entries], float)
            elif kind == "float_lin":
                lo = np.array([p.lo for _, p in entries], float)
                span = np.array([p.hi - p.lo for _, p in entries], float)
            elif kind == "int_log":
                lo = np.array([math.log(p.lo) for _, p in entries], float)
                span = np.array([math.log(p.hi + 1) - math.log(p.lo)
                                 for _, p in entries], float)
            else:  # float_log
                lo = np.array([math.log(p.lo) for _, p in entries], float)
                span = np.array([math.log(p.hi) - math.log(p.lo)
                                 for _, p in entries], float)
            plan[kind] = (idx, lo, span,
                          [p.hi for _, p in entries] if kind == "int_log"
                          else None)
        self.__dict__["_plan"] = plan
        return plan

    def from_unit_matrix(self, units: np.ndarray) -> List[Config]:
        """Vectorized ``from_unit_vector`` over an (m, dim) sample matrix.

        Parameters are converted in matrix-wide groups (one transform per
        parameter *kind*) — the conversion half of the batched evaluation
        engine's speedup.
        """
        units = np.atleast_2d(np.asarray(units, dtype=float))
        if units.shape[1] != self.dim:
            raise ValueError(
                f"expected shape (m, {self.dim}), got {units.shape}")
        units = _clip_unit_arr(units)  # one clip for the whole matrix
        plan = self.__dict__.get("_plan") or self._conversion_plan()
        cols: List[Any] = [None] * self.dim
        if plan["bool"]:
            idx = plan["bool"]
            vals = units[:, idx] >= 0.5
            for k, j in enumerate(idx):
                cols[j] = vals[:, k].tolist()
        for j, choices in plan["enum"]:
            ci = (units[:, j] * len(choices)).astype(np.int64)
            cols[j] = [choices[i] for i in ci]
        for kind in ("int_lin", "int_log", "float_lin", "float_log"):
            entry = plan[kind]
            if not entry or isinstance(entry, list):
                continue
            idx, lo, span, hi = entry
            if kind == "int_lin":
                # match the scalar formula exactly: lo + int(u * span)
                vals = lo.astype(np.int64) + (
                    units[:, idx] * span).astype(np.int64)
            elif kind == "int_log":
                vals = np.minimum(np.exp(lo + units[:, idx] * span)
                                  .astype(np.int64),
                                  np.array(hi, dtype=np.int64))
            elif kind == "float_log":
                vals = np.exp(lo + units[:, idx] * span)
            else:
                vals = lo + units[:, idx] * span
            for k, j in enumerate(idx):
                cols[j] = vals[:, k].tolist()
        for j, p in plan["custom"]:
            # Trust a subclass's own vectorized kernel only if it defines
            # one; otherwise loop its (possibly overridden) from_unit so
            # batched conversion never diverges from the scalar path.
            if "from_unit_array" in type(p).__dict__:
                cols[j] = p.from_unit_array(units[:, j])
            else:
                cols[j] = [p.from_unit(float(u)) for u in units[:, j]]
        names = self.names
        return [dict(zip(names, row)) for row in zip(*cols)]

    def to_unit_vector(self, config: Mapping[str, Any]) -> np.ndarray:
        self.validate(config)
        return np.array([p.to_unit(config[p.name]) for p in self], dtype=float)

    def validate(self, config: Mapping[str, Any]) -> None:
        missing = [n for n in self.names if n not in config]
        if missing:
            raise ValueError(f"config missing parameters: {missing}")
        for p in self:
            if not p.validate(config[p.name]):
                raise ValueError(
                    f"invalid value {config[p.name]!r} for parameter {p.name!r}"
                )

    def random_config(self, rng: np.random.Generator) -> Config:
        return self.from_unit_vector(rng.random(self.dim))

    # --- composition --------------------------------------------------------
    def merge(self, other: "ParameterSpace", prefix: str = "") -> "ParameterSpace":
        """Join two spaces (co-deployed systems tuned together, §5.5)."""
        import copy

        params: List[Parameter] = list(self)
        for p in other:
            q = copy.copy(p)
            if prefix:
                object.__setattr__(q, "name", f"{prefix}{p.name}")
            params.append(q)
        return ParameterSpace(params)

    def subset(self, names: Iterable[str]) -> "ParameterSpace":
        return ParameterSpace([self._params[n] for n in names])

    def freeze(self, fixed: Mapping[str, Any]) -> "FrozenSpaceView":
        """A view with some knobs pinned (tune the rest)."""
        return FrozenSpaceView(self, dict(fixed))

    def config_key(self, config: Mapping[str, Any]) -> Tuple:
        """Hashable identity of a config (for duplicate-test caching)."""
        return tuple((n, config[n]) for n in self._params)


class FrozenSpaceView(ParameterSpace):
    """A ParameterSpace with some parameters fixed to constants.

    Sampling/optimization sees only the free parameters; emitted configs
    always carry the fixed values too.
    """

    def __init__(self, base: ParameterSpace, fixed: Dict[str, Any]):
        for n, v in fixed.items():
            if n not in base:
                raise ValueError(f"unknown fixed parameter {n!r}")
            if not base[n].validate(v):
                raise ValueError(f"invalid fixed value {v!r} for {n!r}")
        free = [p for p in base if p.name not in fixed]
        super().__init__(free)
        self._fixed = dict(fixed)
        self._base = base

    @property
    def fixed(self) -> Dict[str, Any]:
        return dict(self._fixed)

    def from_unit_vector(self, u: np.ndarray) -> Config:
        cfg = super().from_unit_vector(u)
        cfg.update(self._fixed)
        return cfg

    def from_unit_matrix(self, units: np.ndarray) -> List[Config]:
        cfgs = super().from_unit_matrix(units)
        for cfg in cfgs:
            cfg.update(self._fixed)
        return cfgs

    def to_unit_vector(self, config: Mapping[str, Any]) -> np.ndarray:
        return np.array([p.to_unit(config[p.name]) for p in self], dtype=float)

    def default_config(self) -> Config:
        cfg = {p.name: p.default for p in self}
        cfg.update(self._fixed)
        return cfg

    def validate(self, config: Mapping[str, Any]) -> None:
        # Free params must be valid; fixed params, if present, must match.
        for p in self:
            if p.name not in config:
                raise ValueError(f"config missing parameter {p.name!r}")
            if not p.validate(config[p.name]):
                raise ValueError(
                    f"invalid value {config[p.name]!r} for parameter {p.name!r}"
                )

    def config_key(self, config: Mapping[str, Any]) -> Tuple:
        return tuple((n, config[n]) for n in self._params)
