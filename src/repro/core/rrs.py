"""Recursive Random Search (Ye & Kalyanaraman, SIGMETRICS 2003 [46]).

The optimization algorithm adopted by the ACTS paper (§4.3) because it meets
the three scalability conditions:

  (1) it returns an answer at *any* sample budget (pure sampling, no gradient
      or model fit),
  (2) a larger budget strictly widens/deepens the search (more exploration
      batches, finer exploitation), and
  (3) the re-exploration stage prevents permanent capture by local optima.

Structure (faithful to the original):

  EXPLORE   Draw ``n = ln(1-p)/ln(1-r)`` samples; with confidence ``p`` the
            best of them lies in the top ``r``-fraction of the space.  The
            running ``r``-quantile of all exploration values is the promise
            threshold ``y_r``.  Any sample beating ``y_r`` seeds exploitation.
  EXPLOIT   Recursive local search in an axis-aligned box of measure ``rho``
            (initially ``r``) centred on the promising point: ``l =
            ln(1-q)/ln(1-v)`` samples per round; improvement ⇒ re-centre;
            no improvement in a round ⇒ shrink the box by ``c``; stop when
            the box measure falls below ``st`` and resume exploration.

ACTS couples RRS with LHS (§4.3 "LHS + RRS"): the exploration batches here are
drawn with LHS rather than i.i.d. uniform, inheriting LHS's stratified
coverage; set ``explore_sampler="random"`` for the original formulation.

Everything operates on the unit hypercube via ``ParameterSpace``; boolean and
enum knobs quantize on the way out, so mixed spaces (§4.1) work unchanged.
"""
from __future__ import annotations

import math
from typing import List, Optional

import numpy as np

from .base import BudgetExhausted, Objective, Trial, TuningResult
from .params import Config, ParameterSpace
from .sampling import get_sampler

__all__ = ["RRSOptimizer"]


class RRSOptimizer:
    def __init__(
        self,
        p: float = 0.99,
        r: float = 0.1,
        q: float = 0.99,
        v: float = 0.8,
        c: float = 0.5,
        st: float = 1e-3,
        explore_sampler: str = "lhs",
    ):
        if not (0 < r < 1 and 0 < p < 1 and 0 < q < 1 and 0 < v < 1):
            raise ValueError("p, r, q, v must be in (0, 1)")
        if not (0 < c < 1):
            raise ValueError("shrink factor c must be in (0, 1)")
        self.p, self.r, self.q, self.v, self.c, self.st = p, r, q, v, c, st
        self.explore_sampler = explore_sampler
        # Sample counts per the confidence arguments in the original paper.
        self.n_explore = max(1, math.ceil(math.log(1 - p) / math.log(1 - r)))
        self.n_exploit = max(1, math.ceil(math.log(1 - q) / math.log(1 - v)))

    # ------------------------------------------------------------------
    def optimize(
        self,
        space: ParameterSpace,
        objective: Objective,
        budget: int,
        rng: np.random.Generator,
        init_unit_points: Optional[np.ndarray] = None,
    ) -> TuningResult:
        """Minimize ``objective`` over ``space`` within ``budget`` tests."""
        dim = space.dim
        sampler = get_sampler(self.explore_sampler)

        history: List[Trial] = []
        explore_values: List[float] = []
        n_tests = 0
        best_u: Optional[np.ndarray] = None
        best_val = math.inf

        def evaluate(u: np.ndarray, phase: str) -> float:
            nonlocal n_tests, best_u, best_val
            if n_tests >= budget:
                raise BudgetExhausted
            cfg = space.from_unit_vector(u)
            val = float(objective(cfg))
            n_tests += 1
            history.append(Trial(cfg, val, n_tests, phase))
            if val < best_val:
                best_val, best_u = val, u.copy()
            return val

        def threshold() -> float:
            """Running r-quantile of exploration values (promise threshold)."""
            if not explore_values:
                return math.inf
            return float(np.quantile(np.array(explore_values), self.r))

        try:
            # Optional warm start (e.g. the tuner's initial LHS round).
            if init_unit_points is not None:
                for u in np.atleast_2d(init_unit_points):
                    val = evaluate(np.asarray(u, dtype=float), "explore")
                    explore_values.append(val)

            while True:
                # ---------------- exploration ----------------
                batch = sampler(self.n_explore, dim, rng)
                promising: Optional[np.ndarray] = None
                promising_val = math.inf
                for u in batch:
                    val = evaluate(u, "explore")
                    explore_values.append(val)
                    if val < promising_val:
                        promising, promising_val = u.copy(), val
                # Only exploit points that beat the running r-quantile
                # threshold (the "promising" test of the original paper).
                if promising is None or promising_val > threshold():
                    continue

                # ---------------- exploitation ----------------
                center, center_val = promising, promising_val
                rho = self.r  # box measure as a fraction of the space
                while rho >= self.st:
                    improved = False
                    for _ in range(self.n_exploit):
                        cand = self._sample_box(center, rho, dim, rng)
                        val = evaluate(cand, "exploit")
                        if val < center_val:
                            center, center_val = cand, val
                            improved = True
                            break  # re-align immediately on improvement
                    if not improved:
                        rho *= self.c  # shrink and keep drilling
        except BudgetExhausted:
            pass

        if best_u is None:
            # Budget was zero; fall back to the space default.
            cfg = space.default_config()
            return TuningResult(cfg, math.inf, history, n_tests)
        return TuningResult(
            space.from_unit_vector(best_u), best_val, history, n_tests
        )

    # ------------------------------------------------------------------
    @staticmethod
    def _sample_box(
        center: np.ndarray, rho: float, dim: int, rng: np.random.Generator
    ) -> np.ndarray:
        """Uniform sample from a box of measure ``rho`` centred at ``center``,
        clipped to the unit cube (the box slides inward at the boundary so its
        measure is preserved)."""
        side = rho ** (1.0 / dim)
        lo = np.clip(center - side / 2, 0.0, 1.0 - side)
        lo = np.maximum(lo, 0.0)
        hi = np.minimum(lo + side, 1.0)
        return lo + rng.random(dim) * (hi - lo)
