"""Recursive Random Search (Ye & Kalyanaraman, SIGMETRICS 2003 [46]).

The optimization algorithm adopted by the ACTS paper (§4.3) because it meets
the three scalability conditions:

  (1) it returns an answer at *any* sample budget (pure sampling, no gradient
      or model fit),
  (2) a larger budget strictly widens/deepens the search (more exploration
      batches, finer exploitation), and
  (3) the re-exploration stage prevents permanent capture by local optima.

Structure (round-synchronous variant):

  EXPLORE   Draw ``n = ln(1-p)/ln(1-r)`` samples; with confidence ``p`` the
            best of them lies in the top ``r``-fraction of the space.  The
            running ``r``-quantile of all exploration values is the promise
            threshold ``y_r``.  Any sample beating ``y_r`` seeds exploitation.
  EXPLOIT   Recursive local search in an axis-aligned box of measure ``rho``
            (initially ``r``) centred on the promising point: ``l =
            ln(1-q)/ln(1-v)`` samples per round; the whole round is scored
            at once and the box re-centres on the round's best improver;
            no improvement in a round ⇒ shrink the box by ``c``; stop when
            the box measure falls below ``st`` and resume exploration.

Every round — exploration batch, warm-start batch and exploitation round —
is evaluated as ONE call through ``_BudgetedRun.evaluate_batch``, so a SUT
exposing the tuner's ``BatchEvaluator`` protocol scores each round in a
single vectorized call instead of ``n`` Python round-trips.  (The original
formulation evaluates exploitation candidates one at a time and re-centres
on the *first* improver; scoring the full round and taking its best is the
standard batch-parallel adaptation, and is what makes the evaluation
pipeline vectorizable end to end.)  Candidate generation is independent of
the dispatch mode, so batched and sequential runs are trial-for-trial
identical.

ACTS couples RRS with LHS (§4.3 "LHS + RRS"): the exploration batches here
are drawn with LHS rather than i.i.d. uniform, inheriting LHS's stratified
coverage; set ``explore_sampler="random"`` for the original formulation.

Everything operates on the unit hypercube via ``ParameterSpace``; boolean and
enum knobs quantize on the way out, so mixed spaces (§4.1) work unchanged.
"""
from __future__ import annotations

import math
from typing import List, Optional

import numpy as np

from .base import BatchObjective, BudgetExhausted, Feasible, \
    Objective, Trial, TuningResult
from .base import BudgetedRun as _BudgetedRun
from .params import Config, ParameterSpace
from .sampling import get_sampler

__all__ = ["RRSOptimizer"]


class RRSOptimizer:
    def __init__(
        self,
        p: float = 0.99,
        r: float = 0.1,
        q: float = 0.99,
        v: float = 0.5,
        c: float = 0.5,
        st: float = 1e-3,
        explore_sampler: str = "lhs",
    ):
        # v=0.5 (l = ln(1-q)/ln(1-v) = 7 samples per exploitation round) is
        # the round-synchronous default: wider rounds both amortize the
        # per-round dispatch of the batched evaluation engine and drill the
        # promising box with confidence q per round.  The original paper's
        # sequential formulation used v=0.8 (l = 3); pass it explicitly to
        # reproduce that behaviour.
        if not (0 < r < 1 and 0 < p < 1 and 0 < q < 1 and 0 < v < 1):
            raise ValueError("p, r, q, v must be in (0, 1)")
        if not (0 < c < 1):
            raise ValueError("shrink factor c must be in (0, 1)")
        self.p, self.r, self.q, self.v, self.c, self.st = p, r, q, v, c, st
        self.explore_sampler = explore_sampler
        # Sample counts per the confidence arguments in the original paper.
        self.n_explore = max(1, math.ceil(math.log(1 - p) / math.log(1 - r)))
        self.n_exploit = max(1, math.ceil(math.log(1 - q) / math.log(1 - v)))

    # ------------------------------------------------------------------
    def optimize(
        self,
        space: ParameterSpace,
        objective: Objective,
        budget: int,
        rng: np.random.Generator,
        init_unit_points: Optional[np.ndarray] = None,
        batch_objective: Optional[BatchObjective] = None,
        feasible: Optional[Feasible] = None,
    ) -> TuningResult:
        """Minimize ``objective`` over ``space`` within ``budget`` tests."""
        dim = space.dim
        sampler = get_sampler(self.explore_sampler)
        run = _BudgetedRun(space, objective, budget, batch_objective,
                           feasible=feasible)
        explore_values: List[float] = []

        def threshold() -> float:
            """Running r-quantile of exploration values (promise threshold)."""
            if not explore_values:
                return math.inf
            return float(np.quantile(np.array(explore_values), self.r))

        try:
            # Optional warm start (e.g. the tuner's initial LHS round).
            if init_unit_points is not None:
                vals = run.evaluate_batch(np.atleast_2d(init_unit_points),
                                          "explore")
                explore_values.extend(float(v) for v in vals)

            while True:
                # ---------------- exploration ----------------
                # Snapshot the promise threshold BEFORE this batch extends
                # the exploration evidence (§4.3 running-quantile): a batch
                # minimum may only seed exploitation if it beats the
                # r-quantile of *prior* exploration values.  Testing against
                # a batch-inclusive quantile lets a batch min self-qualify
                # even when it beats no earlier evidence.
                y_r = threshold()
                batch = sampler(self.n_explore, dim, rng)
                vals = run.evaluate_batch(batch, "explore")
                explore_values.extend(float(v) for v in vals)
                i_best = int(np.argmin(vals))
                promising = np.asarray(batch[i_best], dtype=float)
                promising_val = float(vals[i_best])
                # Only exploit points that beat the running r-quantile
                # threshold (the "promising" test of the original paper).
                if promising_val > y_r:
                    continue

                # ---------------- exploitation ----------------
                center, center_val = promising, promising_val
                rho = self.r  # box measure as a fraction of the space
                while rho >= self.st:
                    cands = self._sample_box_round(center, rho, dim, rng,
                                                   self.n_exploit)
                    cvals = run.evaluate_batch(cands, "exploit")
                    j = int(np.argmin(cvals))
                    if float(cvals[j]) < center_val:
                        center, center_val = cands[j], float(cvals[j])
                    else:
                        rho *= self.c  # shrink and keep drilling
        except BudgetExhausted:
            pass

        return run.result()

    # ------------------------------------------------------------------
    @staticmethod
    def _sample_box(
        center: np.ndarray, rho: float, dim: int, rng: np.random.Generator
    ) -> np.ndarray:
        """Uniform sample from a box of measure ``rho`` centred at ``center``,
        clipped to the unit cube (the box slides inward at the boundary so its
        measure is preserved)."""
        side = rho ** (1.0 / dim)
        lo = np.clip(center - side / 2, 0.0, 1.0 - side)
        lo = np.maximum(lo, 0.0)
        hi = np.minimum(lo + side, 1.0)
        return lo + rng.random(dim) * (hi - lo)

    @staticmethod
    def _sample_box_round(
        center: np.ndarray, rho: float, dim: int,
        rng: np.random.Generator, n: int
    ) -> np.ndarray:
        """One exploitation round of ``n`` box samples in a single draw.

        ``rng.random((n, dim))`` consumes the bit stream exactly like ``n``
        sequential ``_sample_box`` calls, so round-based runs reproduce the
        point-by-point candidate sequence."""
        side = rho ** (1.0 / dim)
        lo = np.clip(center - side / 2, 0.0, 1.0 - side)
        lo = np.maximum(lo, 0.0)
        hi = np.minimum(lo + side, 1.0)
        return lo + rng.random((n, dim)) * (hi - lo)
