"""Scalable sampling for ACTS (§4.1 condition set, §4.3).

The paper requires a sampling method whose sample sets
  (1) widely cover the high-dimensional knob space,
  (2) fit the resource limit (|set| == m exactly), and
  (3) widen their coverage monotonically as m grows.

LHS (McKay, Beckman & Conover 2000 [36]) satisfies all three: each of the m
strata of every dimension is used exactly once, so marginal coverage is
uniform at any m and refines as m grows.  We implement plain LHS plus a
maximin variant (best-of-k candidate sets by minimum pairwise distance), and
uniform random sampling as the baseline architecture the paper compares
against.  Coverage metrics used by ``benchmarks/lhs_coverage.py`` live here
too.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from .params import Config, ParameterSpace

__all__ = [
    "lhs_unit",
    "lhs",
    "maximin_lhs",
    "random_unit",
    "random_sampling",
    "min_pairwise_distance",
    "centered_l2_discrepancy",
    "stratification_counts",
    "get_sampler",
]


# --------------------------------------------------------------------------
# samplers (unit hypercube)
# --------------------------------------------------------------------------
def lhs_unit(m: int, dim: int, rng: np.random.Generator) -> np.ndarray:
    """Latin hypercube: (m, dim) points, one per stratum per dimension."""
    if m <= 0:
        return np.zeros((0, dim))
    # For each dim: a random permutation of the m strata, jittered in-stratum.
    strata = np.argsort(rng.random((dim, m)), axis=1).T  # (m, dim), each col a perm
    jitter = rng.random((m, dim))
    return (strata + jitter) / m


def random_unit(m: int, dim: int, rng: np.random.Generator) -> np.ndarray:
    return rng.random((max(m, 0), dim))


def maximin_lhs(
    m: int, dim: int, rng: np.random.Generator, candidates: int = 16
) -> np.ndarray:
    """Best-of-k LHS by maximin distance — still a valid Latin hypercube."""
    best, best_d = None, -1.0
    for _ in range(max(candidates, 1)):
        pts = lhs_unit(m, dim, rng)
        d = min_pairwise_distance(pts)
        if d > best_d:
            best, best_d = pts, d
    return best


def lhs(space: ParameterSpace, m: int, rng: np.random.Generator) -> List[Config]:
    return [space.from_unit_vector(u) for u in lhs_unit(m, space.dim, rng)]


def random_sampling(
    space: ParameterSpace, m: int, rng: np.random.Generator
) -> List[Config]:
    return [space.from_unit_vector(u) for u in random_unit(m, space.dim, rng)]


_SAMPLERS = {
    "lhs": lhs_unit,
    "maximin_lhs": maximin_lhs,
    "random": random_unit,
}


def get_sampler(name: str):
    try:
        return _SAMPLERS[name]
    except KeyError:
        raise ValueError(f"unknown sampler {name!r}; have {sorted(_SAMPLERS)}")


# --------------------------------------------------------------------------
# coverage metrics
# --------------------------------------------------------------------------
def min_pairwise_distance(pts: np.ndarray) -> float:
    """Maximin coverage criterion (larger = better spread)."""
    n = len(pts)
    if n < 2:
        return float("inf")
    d2 = np.sum((pts[:, None, :] - pts[None, :, :]) ** 2, axis=-1)
    d2[np.diag_indices(n)] = np.inf
    return float(np.sqrt(d2.min()))

def centered_l2_discrepancy(pts: np.ndarray) -> float:
    """Centered L2 discrepancy (Hickernell); smaller = more uniform."""
    n, d = pts.shape
    if n == 0:
        return float("nan")
    x = pts - 0.5
    ax = np.abs(x)
    term1 = np.prod(1 + 0.5 * ax - 0.5 * ax**2, axis=1).sum() * (2.0 / n)
    cross = (
        1
        + 0.5 * (ax[:, None, :] + ax[None, :, :])
        - 0.5 * np.abs(x[:, None, :] - x[None, :, :])
    ).prod(axis=-1)
    term2 = cross.sum() / (n * n)
    return float(np.sqrt(max((13.0 / 12.0) ** d - term1 + term2, 0.0)))


def stratification_counts(pts: np.ndarray) -> np.ndarray:
    """Per-dimension histogram over m strata.  All-ones ⟺ valid LHS."""
    m, dim = pts.shape
    counts = np.zeros((dim, m), dtype=int)
    strata = np.clip((pts * m).astype(int), 0, m - 1)
    for j in range(dim):
        counts[j] = np.bincount(strata[:, j], minlength=m)
    return counts
