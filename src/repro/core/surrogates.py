"""Deterministic surrogate SUTs replicating the paper's empirical settings.

The paper's evidence (§2.2 Fig. 1, §5.1-§5.5) comes from live MySQL, Tomcat
and Spark deployments.  A CPU-only container cannot host those servers, so we
rebuild each as a *surrogate performance model*: a deterministic analytic
response surface over the real systems' knobs, shaped to match the published
observations —

* MySQL (Fig. 1a/1d):  ``query_cache_type`` dominates under a uniform-read
  workload (the "two lines" projection) and stops dominating under
  zipfian read-write; default ≈ 9,815 ops/s, tuned optimum ≈ 118,184 ops/s
  (the 12×/"11 times better" result of §5.1).
* Tomcat (Fig. 1b/1e):  an irregular bumpy surface whose optimum location
  shifts when the co-deployed JVM's ``TargetSurvivorRatio`` changes; the
  fully-utilized deployment of §5.2 caps gains at a few percent (Table 1).
* Spark (Fig. 1c/1f):  smooth surface in standalone mode; a sharp ridge
  appears at ``executor.cores == 4`` in cluster mode.
* §5.5:  a front-end cache/load-balancer surrogate whose capacity ceiling
  sits near the *untuned* DB throughput, so tuning the composed deployment
  exposes the front end as the bottleneck.

Surrogates carry a tiny deterministic "measurement jitter" (hash-seeded,
±0.5%) so optimizers face realistic non-smoothness, while every test remains
exactly reproducible — a requirement for the test suite.

Every surrogate implements the tuner's ``BatchEvaluator`` protocol: the
response surface is evaluated as vectorized NumPy over a whole candidate
round (``test_batch``), and the scalar ``test`` delegates to a batch of one
so both evaluation engines share bit-identical arithmetic.  The per-call
Python overhead this amortizes (knob-space construction, validation,
scalar math) is exactly the per-sample evaluation cost the batched tuning
engine exists to remove.

These surrogates are the paper's *benchmark workloads*; the real system under
tune in this repo is the JAX distributed runtime (``repro.core.sut_jax``).
"""
from __future__ import annotations

import math

from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .params import (
    BoolParam,
    Config,
    EnumParam,
    FloatParam,
    IntParam,
    ParameterSpace,
)
from .tuner import PerfMetric

__all__ = [
    "Surrogate",
    "MySQLSurrogate",
    "TomcatSurrogate",
    "SparkSurrogate",
    "FrontendSurrogate",
    "ComposedSUT",
]


def _jitter_unit(cols: Sequence[np.ndarray]) -> np.ndarray:
    """Deterministic pseudo-noise seed in [0, 1) per config (vectorized).

    FNV/Murmur-style mixing of every knob column's float64 bit pattern —
    one batch of vector ops instead of a per-config ``repr``+``crc32``
    round-trip.  Configs differing in any knob (used by the response
    surface or not) draw different noise, like a real measurement would.
    """
    if isinstance(cols, np.ndarray):
        mat = cols  # (n, k) knob matrix
    else:
        mat = np.column_stack(cols) if len(cols) else np.zeros((0, 1))
    bits = np.ascontiguousarray(mat.astype(np.float64, copy=False)) \
        .view(np.uint64)
    h = np.full(len(mat), 0xCBF29CE484222325, dtype=np.uint64)
    for j in range(bits.shape[1]):
        h = (h ^ bits[:, j]) * np.uint64(0x100000001B3)
    h ^= h >> np.uint64(33)
    h = h * np.uint64(0xFF51AFD7ED558CCD)
    h ^= h >> np.uint64(29)
    return (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)


def _jitter_scale(unit: np.ndarray, scale: float = 0.005) -> np.ndarray:
    """Noise multiplier in [1-s, 1+s] from a per-config unit seed."""
    return 1.0 + scale * (2.0 * unit - 1.0)


def _sat(x, x0: float, sharp: float = 1.0):
    """Smooth saturating curve in [0, 1]: 0 at -inf, 1 at +inf, 0.5 at x0.

    Accepts scalars or arrays (vectorized batch path).
    """
    return 1.0 / (1.0 + np.exp(-sharp * (np.asarray(x, dtype=float) - x0)))


# constant offsets that re-zero each gain term at the default setting;
# precomputed once (identical formulas, hoisted out of the batch hot path)
def _const(x) -> float:
    return float(np.asarray(x))


# enum lookup tables (indexed by canonical enum position / knob value)
_QCT_READ = np.array([0.0, 1.20, 0.85])
_QCT_RW = np.array([0.0, -0.18, 0.02])
_FLUSH_RW = np.array([0.85, 0.0, 0.60])
_COMP_TABLE = np.array([1.0, 0.97, 0.90])
_GC_TABLE = np.array([0.97, 1.0, 0.95])
_EVICT_TABLE = np.array([0.05, 0.07, 0.0])
_C_SAT_TC = _const(1.0 / (1.0 + np.exp(-0.05 * (9 - 64))))
_C_BP_READ = _const(0.55 * 2 / (1.0 + np.exp(-6.0 * (0.0 - 0.45))))
_C_CONN_READ = 0.10 * math.exp(-((151 - 1800) / 1200.0) ** 2)
_C_BP_RW = _const(1.0 / (1.0 + np.exp(-5.0 * (0.0 - 0.4))))
_C_CONN_RW = 0.12 * math.exp(-((151 - 900) / 700.0) ** 2)
_C_LF_RW = _const(1.0 / (1.0 + np.exp(-5.0 * (math.log2(12.0) / 10.0 - 0.5))))


def _col(configs: Sequence[Config], knob: str) -> np.ndarray:
    return np.array([c[knob] for c in configs], dtype=float)


def _map_enum(configs: Sequence[Config], knob: str,
              table: Dict[Any, float]) -> np.ndarray:
    return np.array([table[c[knob]] for c in configs], dtype=float)


class Surrogate:
    """Base: a deterministic ``config -> PerfMetric`` SUT with a knob space."""

    name = "surrogate"

    def space(self) -> ParameterSpace:
        raise NotImplementedError

    def test(self, config: Config) -> PerfMetric:
        """Validate + score one configuration (a batch of one)."""
        self.space().validate(config)
        return self.test_batch([config])[0]

    def test_batch(self, configs: Sequence[Config]) -> List[PerfMetric]:
        """Score a whole candidate round in one vectorized call.

        Configs are trusted (no per-config validation) — the tuner only
        sends configs produced by ``ParameterSpace.from_unit_vector``.
        Subclasses override this with a vectorized path; the fallback here
        loops a subclass-provided ``test``.
        """
        if type(self).test is Surrogate.test:  # neither method overridden
            raise NotImplementedError("override test or test_batch")
        return [self.test(c) for c in configs]

    # For Fig.1-style projections.
    def surface(
        self, knob_x: str, knob_y: str, n: int = 25
    ) -> Tuple[list, list, np.ndarray]:
        space = self.space()
        base = space.default_config()
        xs = space[knob_x].grid(n)
        ys = space[knob_y].grid(n)
        cfgs = []
        for xv in xs:
            for yv in ys:
                cfg = dict(base)
                cfg[knob_x] = xv
                cfg[knob_y] = yv
                cfgs.append(cfg)
        vals = np.array([m.value for m in self.test_batch(cfgs)])
        return xs, ys, vals.reshape(len(xs), len(ys))


# ---------------------------------------------------------------------------
# MySQL (§2.2 Fig. 1a/1d, §5.1)
# ---------------------------------------------------------------------------
class MySQLSurrogate(Surrogate):
    """MySQL 5.7 surrogate: 10 real knobs, workload-dependent response.

    Calibrated so the default setting yields 9,815 ops/s and the global
    optimum 118,184 ops/s (12.04×) under ``uniform_read`` — §5.1's numbers.
    """

    name = "mysql"
    DEFAULT_TPUT = 9815.0
    BEST_TPUT = 118184.0

    def __init__(self, workload: str = "uniform_read"):
        if workload not in ("uniform_read", "zipfian_rw"):
            raise ValueError(f"unknown workload {workload!r}")
        self.workload = workload
        self.name = f"mysql[{workload}]"

    def space(self) -> ParameterSpace:
        mb = 1024 * 1024
        return ParameterSpace(
            [
                EnumParam("query_cache_type", ("OFF", "ON", "DEMAND"), "OFF"),
                IntParam("innodb_buffer_pool_size", 128 * mb, 32768 * mb,
                         default=128 * mb, log=True),
                IntParam("max_connections", 50, 4000, default=151),
                IntParam("innodb_log_file_size", 4 * mb, 4096 * mb,
                         default=48 * mb, log=True),
                EnumParam("innodb_flush_log_at_trx_commit", (1, 0, 2), 1),
                IntParam("thread_cache_size", 0, 512, default=9),
                IntParam("table_open_cache", 64, 16384, default=2000, log=True),
                IntParam("innodb_thread_concurrency", 0, 128, default=0),
                BoolParam("sync_binlog", True),
                IntParam("tmp_table_size", 1 * mb, 1024 * mb, default=16 * mb,
                         log=True),
            ]
        )

    _KNOBS = ("query_cache_type", "innodb_buffer_pool_size",
              "max_connections", "innodb_log_file_size",
              "innodb_flush_log_at_trx_commit", "thread_cache_size",
              "table_open_cache", "innodb_thread_concurrency",
              "sync_binlog", "tmp_table_size")
    _QCT_IDX = {"OFF": 0, "ON": 1, "DEMAND": 2}

    # canonical numeric columns: one extraction pass shared by gains + jitter
    def _extract(self, configs: Sequence[Config]) -> Dict[str, np.ndarray]:
        qct_idx = self._QCT_IDX
        mat = np.array(
            [(qct_idx[c["query_cache_type"]], c["innodb_buffer_pool_size"],
              c["max_connections"], c["innodb_log_file_size"],
              c["innodb_flush_log_at_trx_commit"], c["thread_cache_size"],
              c["table_open_cache"], c["innodb_thread_concurrency"],
              c["sync_binlog"], c["tmp_table_size"]) for c in configs],
            dtype=float)
        return dict(zip(self._KNOBS, mat.T))

    # per-knob log-gain terms, vectorized; g(default) == 0 by construction
    def _gain_terms(self, cols: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        mb = 1024 * 1024
        g: Dict[str, np.ndarray] = {}

        qct = cols["query_cache_type"]  # 0=OFF 1=ON 2=DEMAND
        bp = np.log2(cols["innodb_buffer_pool_size"] / (128 * mb)) / 8.0
        lf = np.log2(cols["innodb_log_file_size"] / (4 * mb)) / 10.0
        conn = cols["max_connections"]
        flush = cols["innodb_flush_log_at_trx_commit"]
        tc = cols["thread_cache_size"]
        toc = np.log2(cols["table_open_cache"] / 64.0) / 8.0
        itc = cols["innodb_thread_concurrency"]
        sync = cols["sync_binlog"] != 0
        tmp = np.log2(cols["tmp_table_size"] / mb) / 10.0

        if self.workload == "uniform_read":
            # Fig 1a: query cache dominates — two nearly-parallel "lines".
            g["query_cache_type"] = _QCT_READ[qct.astype(np.int64)]
            g["innodb_buffer_pool_size"] = 0.55 * _sat(bp, 0.45, 6.0) * 2 - _C_BP_READ
            g["max_connections"] = 0.10 * np.exp(-((conn - 1800) / 1200.0) ** 2) - _C_CONN_READ
            g["innodb_log_file_size"] = 0.04 * (lf - math.log2(12.0) / 10.0)
            g["innodb_flush_log_at_trx_commit"] = 0.0  # read-only: irrelevant
            g["thread_cache_size"] = 0.06 * (_sat(tc, 64, 0.05) - _C_SAT_TC)
            g["table_open_cache"] = 0.05 * (toc - math.log2(2000 / 64.0) / 8.0)
            g["innodb_thread_concurrency"] = 0.05 * np.exp(-((itc - 0) / 24.0) ** 2) - 0.05
            g["sync_binlog"] = 0.0
            g["tmp_table_size"] = 0.02 * (tmp - 4.0 / 10.0)
        else:
            # Fig 1d: cache invalidation kills the query cache's dominance.
            g["query_cache_type"] = _QCT_RW[qct.astype(np.int64)]
            g["innodb_buffer_pool_size"] = 0.55 * (_sat(bp, 0.4, 5.0) - _C_BP_RW)
            g["max_connections"] = 0.12 * np.exp(-((conn - 900) / 700.0) ** 2) - _C_CONN_RW
            g["innodb_log_file_size"] = 0.35 * (_sat(lf, 0.5, 5.0) - _C_LF_RW)
            g["innodb_flush_log_at_trx_commit"] = _FLUSH_RW[
                flush.astype(np.int64)]  # indexed by knob value 0/1/2
            g["thread_cache_size"] = 0.08 * (_sat(tc, 64, 0.05) - _C_SAT_TC)
            g["table_open_cache"] = 0.03 * (toc - math.log2(2000 / 64.0) / 8.0)
            g["innodb_thread_concurrency"] = 0.10 * np.exp(-((itc - 32) / 24.0) ** 2) - 0.10 * math.exp(-((0 - 32) / 24.0) ** 2)
            g["sync_binlog"] = np.where(sync, 0.0, 0.40)
            g["tmp_table_size"] = 0.05 * (tmp - 4.0 / 10.0)
        return g

    def _gains(self, cfg: Config) -> Dict[str, float]:
        terms = self._gain_terms(self._extract([cfg]))
        # constant (config-independent) terms are plain floats
        return {k: float(v if np.isscalar(v) else v[0])
                for k, v in terms.items()}

    def _max_log_gain(self) -> float:
        """Analytic max of sum of gains (each term maximized independently)."""
        space = self.space()
        best = 0.0
        default = space.default_config()
        for p in space:
            vals = p.grid(64) if p.cardinality is None or p.cardinality > 64 else p.grid(p.cardinality)
            cfgs = []
            for v in vals:
                cfg = dict(default)
                cfg[p.name] = v
                cfgs.append(cfg)
            best += float(np.max(self._gain_terms(self._extract(cfgs))[p.name]))
        return best

    def test_batch(self, configs: Sequence[Config]) -> List[PerfMetric]:
        cols = self._extract(configs)
        g = sum(self._gain_terms(cols).values())
        if self.workload == "uniform_read":
            # Normalize so the global max hits BEST_TPUT exactly.
            scale = math.log(self.BEST_TPUT / self.DEFAULT_TPUT) / self._max_log_gain_cached()
        else:
            scale = 1.0
        jit = _jitter_scale(_jitter_unit(list(cols.values())))
        tput = self.DEFAULT_TPUT * np.exp(g * scale) * jit
        return [
            PerfMetric(value=float(t), higher_is_better=True,
                       metrics={"ops_per_sec": float(t),
                                "workload": self.workload})
            for t in tput
        ]

    _mlg: Optional[float] = None

    def _max_log_gain_cached(self) -> float:
        if type(self)._mlg is None:
            type(self)._mlg = MySQLSurrogate("uniform_read")._max_log_gain()
        return type(self)._mlg


# ---------------------------------------------------------------------------
# Tomcat (+ co-deployed JVM) (§2.2 Fig. 1b/1e, §5.2 Table 1)
# ---------------------------------------------------------------------------
class TomcatSurrogate(Surrogate):
    """Tomcat on 8-core VM (4 cores pinned to network) — §5.2's deployment.

    The network cores are saturated, so the headroom is small: default 978
    txns/s, attainable optimum ≈ 1020 (+4%).  The surface is bumpy (thread
    scheduling artifacts), and the bump *phase* depends on the co-deployed
    JVM's ``TargetSurvivorRatio`` — tuning both together (the paper's §2.1
    point) is what finds the real optimum.
    """

    name = "tomcat"
    DEFAULT_TXNS = 978.0

    def __init__(self, fully_utilized: bool = True):
        self.fully_utilized = fully_utilized

    def space(self) -> ParameterSpace:
        return ParameterSpace(
            [
                IntParam("maxThreads", 25, 1000, default=200),
                IntParam("acceptCount", 10, 1000, default=100),
                IntParam("maxKeepAliveRequests", 1, 500, default=100),
                IntParam("connectionTimeout_ms", 1000, 60000, default=20000),
                BoolParam("tcpNoDelay", True),
                EnumParam("compression", ("off", "on", "force"), "off"),
                IntParam("jvm_heap_mb", 256, 8192, default=512, log=True),
                IntParam("jvm_TargetSurvivorRatio", 1, 99, default=50),
                EnumParam("jvm_gc", ("ParallelGC", "G1GC", "CMS"), "ParallelGC"),
            ]
        )

    _KNOBS = ("maxThreads", "acceptCount", "maxKeepAliveRequests",
              "connectionTimeout_ms", "tcpNoDelay", "compression",
              "jvm_heap_mb", "jvm_TargetSurvivorRatio", "jvm_gc")
    _COMP_IDX = {"off": 0, "on": 1, "force": 2}
    _GC_IDX = {"ParallelGC": 0, "G1GC": 1, "CMS": 2}

    def _extract(self, configs: Sequence[Config]) -> Dict[str, np.ndarray]:
        comp_idx, gc_idx = self._COMP_IDX, self._GC_IDX
        mat = np.array(
            [(c["maxThreads"], c["acceptCount"], c["maxKeepAliveRequests"],
              c["connectionTimeout_ms"], c["tcpNoDelay"],
              comp_idx[c["compression"]], c["jvm_heap_mb"],
              c["jvm_TargetSurvivorRatio"], gc_idx[c["jvm_gc"]])
             for c in configs],
            dtype=float)
        return dict(zip(self._KNOBS, mat.T))

    def _utilization_score(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        """0..1 'smoothness-free' capacity score (vectorized)."""
        mt = cols["maxThreads"]
        heap = cols["jvm_heap_mb"]
        # concave peak in threads (context-switch cost beyond ~400)
        s_threads = np.exp(-((mt - 420) / 320.0) ** 2)
        s_heap = _sat(np.log2(heap / 256.0), 2.2, 1.6)
        s_accept = _sat(cols["acceptCount"], 150, 0.01)
        s_keep = _sat(cols["maxKeepAliveRequests"], 60, 0.02)
        s_nodelay = np.where(cols["tcpNoDelay"] != 0, 1.0, 0.93)
        s_comp = _COMP_TABLE[cols["compression"].astype(np.int64)]
        s_gc = _GC_TABLE[cols["jvm_gc"].astype(np.int64)]
        return (
            0.45 * s_threads + 0.25 * s_heap + 0.1 * s_accept + 0.1 * s_keep
        ) * s_nodelay * s_comp * s_gc + 0.1

    def _bumps(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        """Irregular bumpy modulation; phase set by the JVM survivor ratio."""
        mt = cols["maxThreads"]
        ac = cols["acceptCount"]
        phase = cols["jvm_TargetSurvivorRatio"] / 99.0 * 2 * math.pi
        b = (
            0.05 * np.sin(mt / 37.0 + phase)
            + 0.04 * np.sin(mt / 11.0 + 2.3 * phase)
            + 0.03 * np.sin(ac / 23.0 - phase)
        )
        return 1.0 + b

    _ref_score: Optional[float] = None

    def _default_score(self) -> float:
        if type(self)._ref_score is None:
            cols = self._extract([self.space().default_config()])
            type(self)._ref_score = float(
                (self._utilization_score(cols) * self._bumps(cols))[0])
        return type(self)._ref_score

    def test_batch(self, configs: Sequence[Config]) -> List[PerfMetric]:
        cols = self._extract(configs)
        score = self._utilization_score(cols) * self._bumps(cols)
        rel = score / self._default_score()
        if self.fully_utilized:
            # §5.2: network cores saturated — compress headroom to ~±5%.
            rel = np.where(rel > 1, 1.0 + 0.28 * (rel - 1.0), rel)
            rel = np.minimum(rel, 1.055)
        jit = _jitter_unit(list(cols.values()))
        txns = self.DEFAULT_TXNS * rel * _jitter_scale(jit)
        hits = 3235.0 * (rel ** 2.8) * _jitter_scale(jit, 0.003)
        failed = np.maximum(0.0, 165.0 / (rel ** 3.2)) * _jitter_scale(jit, 0.01)
        errors = np.maximum(0.0, 37.0 / (rel ** 2.4)) * _jitter_scale(jit, 0.01)
        passed = txns * 3600.0 * 0.904
        return [
            PerfMetric(
                value=float(txns[i]),
                higher_is_better=True,
                metrics={
                    "txns_per_sec": float(txns[i]),
                    "hits_per_sec": float(hits[i]),
                    "passed_txns": float(passed[i]),
                    "failed_txns": float(failed[i]),
                    "errors": float(errors[i]),
                },
            )
            for i in range(len(configs))
        ]


# ---------------------------------------------------------------------------
# Spark (§2.2 Fig. 1c/1f)
# ---------------------------------------------------------------------------
class SparkSurrogate(Surrogate):
    """Spark surrogate: smooth in standalone mode, ridge at cores=4 in cluster."""

    name = "spark"
    DEFAULT_TPUT = 100.0  # normalized job throughput

    def __init__(self, deployment: str = "standalone"):
        if deployment not in ("standalone", "cluster"):
            raise ValueError(f"unknown deployment {deployment!r}")
        self.deployment = deployment
        self.name = f"spark[{deployment}]"

    def space(self) -> ParameterSpace:
        return ParameterSpace(
            [
                IntParam("executor_cores", 1, 8, default=1),
                IntParam("executor_memory_mb", 512, 16384, default=1024, log=True),
                IntParam("default_parallelism", 8, 512, default=16),
                BoolParam("shuffle_compress", True),
                EnumParam("serializer", ("java", "kryo"), "java"),
                FloatParam("memory_fraction", 0.3, 0.9, default=0.6),
            ]
        )

    def test_batch(self, configs: Sequence[Config]) -> List[PerfMetric]:
        cores = _col(configs, "executor_cores")
        mem_mb = _col(configs, "executor_memory_mb")
        parallelism = _col(configs, "default_parallelism")
        kryo = _map_enum(configs, "serializer", {"java": 0, "kryo": 1})
        compress = _col(configs, "shuffle_compress")
        frac = _col(configs, "memory_fraction")
        mem = np.log2(mem_mb / 512.0) / 5.0
        par = np.log2(parallelism / 8.0) / 6.0
        s = (
            0.8 * _sat(cores, 3.0, 1.1)
            + 0.7 * _sat(mem, 0.45, 6.0)
            + 0.3 * np.exp(-((par - 0.55) / 0.35) ** 2)
            + np.where(kryo != 0, 0.12, 0.0)
            + np.where(compress != 0, 0.05, 0.0)
            + 0.2 * np.exp(-((frac - 0.62) / 0.18) ** 2)
        )
        if self.deployment == "cluster":
            # Fig 1f: sharp rise at executor.cores == 4 (NUMA/slot alignment);
            # oversubscription penalty above.
            s = np.where(cores == 4, s * 1.35, np.where(cores > 4, s * 0.92, s))
        jit = _jitter_scale(_jitter_unit(
            [cores, mem_mb, parallelism, compress, kryo, frac]))
        tput = self.DEFAULT_TPUT * s * jit
        return [
            PerfMetric(value=float(t), higher_is_better=True,
                       metrics={"jobs_norm": float(t),
                                "deployment": self.deployment})
            for t in tput
        ]


# ---------------------------------------------------------------------------
# Front-end cache / load balancer + composition (§5.5)
# ---------------------------------------------------------------------------
class FrontendSurrogate(Surrogate):
    """Front-end caching/LB tier whose capacity ceiling is near the *untuned*
    DB throughput — the §5.5 bottleneck."""

    name = "frontend"

    def __init__(self, capacity_ceiling: float = 11000.0):
        self.capacity_ceiling = capacity_ceiling

    def space(self) -> ParameterSpace:
        return ParameterSpace(
            [
                IntParam("cache_size_mb", 64, 8192, default=256, log=True),
                EnumParam("eviction", ("lru", "lfu", "fifo"), "lru"),
                IntParam("worker_threads", 1, 64, default=8),
                BoolParam("pipeline_requests", False),
            ]
        )

    def test_batch(self, configs: Sequence[Config]) -> List[PerfMetric]:
        cache = _col(configs, "cache_size_mb")
        eviction = _map_enum(configs, "eviction",
                             {"lru": 0, "lfu": 1, "fifo": 2})
        workers = _col(configs, "worker_threads")
        pipeline = _col(configs, "pipeline_requests")
        s = (
            0.75
            + 0.10 * _sat(np.log2(cache / 64.0), 3.0, 1.2)
            + _EVICT_TABLE[eviction.astype(np.int64)]
            + 0.06 * _sat(workers, 12, 0.25)
            + np.where(pipeline != 0, 0.05, 0.0)
        )
        jit = _jitter_scale(_jitter_unit([cache, eviction, workers, pipeline]))
        tput = self.capacity_ceiling * s * jit
        return [
            PerfMetric(value=float(t), higher_is_better=True,
                       metrics={"ops_per_sec": float(t)})
            for t in tput
        ]


class ComposedSUT(Surrogate):
    """Co-deployed systems tuned together (§2.1, §5.5).

    The joint knob space is the (prefixed) merge of the member spaces; the
    end-to-end throughput is the pipeline bottleneck min over members, with a
    small interaction drag (shared CPU/memory, §2.2) when both are pushed.
    """

    def __init__(self, members: Dict[str, Surrogate], interaction: float = 0.04):
        self.members = dict(members)
        self.interaction = interaction
        self.name = "+".join(self.members)

    def space(self) -> ParameterSpace:
        import copy

        # Prefix every member's knobs to keep the joint space collision-free.
        params = []
        for prefix, m in self.members.items():
            for p in m.space():
                q = copy.copy(p)
                object.__setattr__(q, "name", f"{prefix}.{p.name}")
                params.append(q)
        return ParameterSpace(params)

    def _split(self, config: Config) -> Dict[str, Config]:
        out: Dict[str, Config] = {k: {} for k in self.members}
        for k, v in config.items():
            prefix, knob = k.split(".", 1)
            out[prefix][knob] = v
        return out

    def test(self, config: Config) -> PerfMetric:
        self.space().validate(config)
        return self.test_batch([config])[0]

    def test_batch(self, configs: Sequence[Config]) -> List[PerfMetric]:
        parts = [self._split(c) for c in configs]
        member_vals: Dict[str, np.ndarray] = {}
        for name, member in self.members.items():
            sub = [p[name] for p in parts]
            batch = getattr(member, "test_batch", None)
            # duck-typed members (plain test-only SUTs) compose too
            metrics = batch(sub) if callable(batch) else \
                [member.test(c) for c in sub]
            member_vals[name] = np.array([m.value for m in metrics])
        stacked = np.stack(list(member_vals.values()))  # (members, n)
        names = list(member_vals)
        overall = stacked.min(axis=0) * (1.0 - self.interaction)
        bottleneck_idx = stacked.argmin(axis=0)
        return [
            PerfMetric(
                value=float(overall[i]),
                higher_is_better=True,
                metrics={
                    "member_values": {n: float(member_vals[n][i])
                                      for n in names},
                    "bottleneck_member": names[int(bottleneck_idx[i])],
                },
            )
            for i in range(len(configs))
        ]
