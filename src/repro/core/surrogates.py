"""Deterministic surrogate SUTs replicating the paper's empirical settings.

The paper's evidence (§2.2 Fig. 1, §5.1-§5.5) comes from live MySQL, Tomcat
and Spark deployments.  A CPU-only container cannot host those servers, so we
rebuild each as a *surrogate performance model*: a deterministic analytic
response surface over the real systems' knobs, shaped to match the published
observations —

* MySQL (Fig. 1a/1d):  ``query_cache_type`` dominates under a uniform-read
  workload (the "two lines" projection) and stops dominating under
  zipfian read-write; default ≈ 9,815 ops/s, tuned optimum ≈ 118,184 ops/s
  (the 12×/"11 times better" result of §5.1).
* Tomcat (Fig. 1b/1e):  an irregular bumpy surface whose optimum location
  shifts when the co-deployed JVM's ``TargetSurvivorRatio`` changes; the
  fully-utilized deployment of §5.2 caps gains at a few percent (Table 1).
* Spark (Fig. 1c/1f):  smooth surface in standalone mode; a sharp ridge
  appears at ``executor.cores == 4`` in cluster mode.
* §5.5:  a front-end cache/load-balancer surrogate whose capacity ceiling
  sits near the *untuned* DB throughput, so tuning the composed deployment
  exposes the front end as the bottleneck.

Surrogates carry a tiny deterministic "measurement jitter" (hash-seeded,
±0.5%) so optimizers face realistic non-smoothness, while every test remains
exactly reproducible — a requirement for the test suite.

These surrogates are the paper's *benchmark workloads*; the real system under
tune in this repo is the JAX distributed runtime (``repro.core.sut_jax``).
"""
from __future__ import annotations

import math
import zlib
from typing import Any, Dict, Optional, Tuple

import numpy as np

from .params import (
    BoolParam,
    Config,
    EnumParam,
    FloatParam,
    IntParam,
    ParameterSpace,
)
from .tuner import PerfMetric

__all__ = [
    "Surrogate",
    "MySQLSurrogate",
    "TomcatSurrogate",
    "SparkSurrogate",
    "FrontendSurrogate",
    "ComposedSUT",
]


def _jitter(config: Config, scale: float = 0.005) -> float:
    """Deterministic pseudo-measurement-noise multiplier in [1-s, 1+s]."""
    h = zlib.crc32(repr(sorted(config.items())).encode()) / 0xFFFFFFFF
    return 1.0 + scale * (2.0 * h - 1.0)


def _sat(x: float, x0: float, sharp: float = 1.0) -> float:
    """Smooth saturating curve in [0, 1]: 0 at -inf, 1 at +inf, 0.5 at x0."""
    return 1.0 / (1.0 + math.exp(-sharp * (x - x0)))


class Surrogate:
    """Base: a deterministic ``config -> PerfMetric`` SUT with a knob space."""

    name = "surrogate"

    def space(self) -> ParameterSpace:
        raise NotImplementedError

    def test(self, config: Config) -> PerfMetric:
        raise NotImplementedError

    # For Fig.1-style projections.
    def surface(
        self, knob_x: str, knob_y: str, n: int = 25
    ) -> Tuple[list, list, np.ndarray]:
        space = self.space()
        base = space.default_config()
        xs = space[knob_x].grid(n)
        ys = space[knob_y].grid(n)
        z = np.zeros((len(xs), len(ys)))
        for i, xv in enumerate(xs):
            for j, yv in enumerate(ys):
                cfg = dict(base)
                cfg[knob_x] = xv
                cfg[knob_y] = yv
                z[i, j] = self.test(cfg).value
        return xs, ys, z


# ---------------------------------------------------------------------------
# MySQL (§2.2 Fig. 1a/1d, §5.1)
# ---------------------------------------------------------------------------
class MySQLSurrogate(Surrogate):
    """MySQL 5.7 surrogate: 10 real knobs, workload-dependent response.

    Calibrated so the default setting yields 9,815 ops/s and the global
    optimum 118,184 ops/s (12.04×) under ``uniform_read`` — §5.1's numbers.
    """

    name = "mysql"
    DEFAULT_TPUT = 9815.0
    BEST_TPUT = 118184.0

    def __init__(self, workload: str = "uniform_read"):
        if workload not in ("uniform_read", "zipfian_rw"):
            raise ValueError(f"unknown workload {workload!r}")
        self.workload = workload
        self.name = f"mysql[{workload}]"

    def space(self) -> ParameterSpace:
        mb = 1024 * 1024
        return ParameterSpace(
            [
                EnumParam("query_cache_type", ("OFF", "ON", "DEMAND"), "OFF"),
                IntParam("innodb_buffer_pool_size", 128 * mb, 32768 * mb,
                         default=128 * mb, log=True),
                IntParam("max_connections", 50, 4000, default=151),
                IntParam("innodb_log_file_size", 4 * mb, 4096 * mb,
                         default=48 * mb, log=True),
                EnumParam("innodb_flush_log_at_trx_commit", (1, 0, 2), 1),
                IntParam("thread_cache_size", 0, 512, default=9),
                IntParam("table_open_cache", 64, 16384, default=2000, log=True),
                IntParam("innodb_thread_concurrency", 0, 128, default=0),
                BoolParam("sync_binlog", True),
                IntParam("tmp_table_size", 1 * mb, 1024 * mb, default=16 * mb,
                         log=True),
            ]
        )

    # per-knob log-gain functions; g(default) == 0 by construction
    def _gains(self, cfg: Config) -> Dict[str, float]:
        mb = 1024 * 1024
        g: Dict[str, float] = {}

        bp = math.log2(cfg["innodb_buffer_pool_size"] / (128 * mb)) / 8.0  # 0..1
        lf = math.log2(cfg["innodb_log_file_size"] / (4 * mb)) / 10.0  # 0..1
        conn = cfg["max_connections"]
        tc = cfg["thread_cache_size"]
        toc = math.log2(cfg["table_open_cache"] / 64.0) / 8.0
        itc = cfg["innodb_thread_concurrency"]
        tmp = math.log2(cfg["tmp_table_size"] / mb) / 10.0

        if self.workload == "uniform_read":
            # Fig 1a: query cache dominates — two nearly-parallel "lines".
            g["query_cache_type"] = {"OFF": 0.0, "ON": 1.20, "DEMAND": 0.85}[
                cfg["query_cache_type"]
            ]
            g["innodb_buffer_pool_size"] = 0.55 * _sat(bp, 0.45, 6.0) * 2 - 0.55 * 2 * _sat(0.0, 0.45, 6.0)
            g["max_connections"] = 0.10 * math.exp(-((conn - 1800) / 1200.0) ** 2) - 0.10 * math.exp(-((151 - 1800) / 1200.0) ** 2)
            g["innodb_log_file_size"] = 0.04 * (lf - math.log2(12.0) / 10.0)
            g["innodb_flush_log_at_trx_commit"] = 0.0  # read-only: irrelevant
            g["thread_cache_size"] = 0.06 * (_sat(tc, 64, 0.05) - _sat(9, 64, 0.05))
            g["table_open_cache"] = 0.05 * (toc - math.log2(2000 / 64.0) / 8.0)
            g["innodb_thread_concurrency"] = 0.05 * math.exp(-((itc - 0) / 24.0) ** 2) - 0.05
            g["sync_binlog"] = 0.0
            g["tmp_table_size"] = 0.02 * (tmp - 4.0 / 10.0)
        else:
            # Fig 1d: cache invalidation kills the query cache's dominance.
            g["query_cache_type"] = {"OFF": 0.0, "ON": -0.18, "DEMAND": 0.02}[
                cfg["query_cache_type"]
            ]
            g["innodb_buffer_pool_size"] = 0.55 * (_sat(bp, 0.4, 5.0) - _sat(0.0, 0.4, 5.0))
            g["max_connections"] = 0.12 * math.exp(-((conn - 900) / 700.0) ** 2) - 0.12 * math.exp(-((151 - 900) / 700.0) ** 2)
            g["innodb_log_file_size"] = 0.35 * (_sat(lf, 0.5, 5.0) - _sat(math.log2(12.0) / 10.0, 0.5, 5.0))
            g["innodb_flush_log_at_trx_commit"] = {1: 0.0, 0: 0.85, 2: 0.60}[
                cfg["innodb_flush_log_at_trx_commit"]
            ]
            g["thread_cache_size"] = 0.08 * (_sat(tc, 64, 0.05) - _sat(9, 64, 0.05))
            g["table_open_cache"] = 0.03 * (toc - math.log2(2000 / 64.0) / 8.0)
            g["innodb_thread_concurrency"] = 0.10 * math.exp(-((itc - 32) / 24.0) ** 2) - 0.10 * math.exp(-((0 - 32) / 24.0) ** 2)
            g["sync_binlog"] = 0.40 if not cfg["sync_binlog"] else 0.0
            g["tmp_table_size"] = 0.05 * (tmp - 4.0 / 10.0)
        return g

    def _max_log_gain(self) -> float:
        """Analytic max of sum of gains (each term maximized independently)."""
        space = self.space()
        best = 0.0
        for p in space:
            vals = p.grid(64) if p.cardinality is None or p.cardinality > 64 else p.grid(p.cardinality)
            gmax = -math.inf
            for v in vals:
                cfg = space.default_config()
                cfg[p.name] = v
                gmax = max(gmax, self._gains(cfg)[p.name])
            best += gmax
        return best

    def test(self, config: Config) -> PerfMetric:
        self.space().validate(config)
        g = sum(self._gains(config).values())
        if self.workload == "uniform_read":
            # Normalize so the global max hits BEST_TPUT exactly.
            scale = math.log(self.BEST_TPUT / self.DEFAULT_TPUT) / self._max_log_gain_cached()
        else:
            scale = 1.0
        tput = self.DEFAULT_TPUT * math.exp(g * scale) * _jitter(config)
        return PerfMetric(value=tput, higher_is_better=True,
                          metrics={"ops_per_sec": tput, "workload": self.workload})

    _mlg: Optional[float] = None

    def _max_log_gain_cached(self) -> float:
        if type(self)._mlg is None:
            type(self)._mlg = MySQLSurrogate("uniform_read")._max_log_gain()
        return type(self)._mlg


# ---------------------------------------------------------------------------
# Tomcat (+ co-deployed JVM) (§2.2 Fig. 1b/1e, §5.2 Table 1)
# ---------------------------------------------------------------------------
class TomcatSurrogate(Surrogate):
    """Tomcat on 8-core VM (4 cores pinned to network) — §5.2's deployment.

    The network cores are saturated, so the headroom is small: default 978
    txns/s, attainable optimum ≈ 1020 (+4%).  The surface is bumpy (thread
    scheduling artifacts), and the bump *phase* depends on the co-deployed
    JVM's ``TargetSurvivorRatio`` — tuning both together (the paper's §2.1
    point) is what finds the real optimum.
    """

    name = "tomcat"
    DEFAULT_TXNS = 978.0

    def __init__(self, fully_utilized: bool = True):
        self.fully_utilized = fully_utilized

    def space(self) -> ParameterSpace:
        mb = 1024 * 1024
        return ParameterSpace(
            [
                IntParam("maxThreads", 25, 1000, default=200),
                IntParam("acceptCount", 10, 1000, default=100),
                IntParam("maxKeepAliveRequests", 1, 500, default=100),
                IntParam("connectionTimeout_ms", 1000, 60000, default=20000),
                BoolParam("tcpNoDelay", True),
                EnumParam("compression", ("off", "on", "force"), "off"),
                IntParam("jvm_heap_mb", 256, 8192, default=512, log=True),
                IntParam("jvm_TargetSurvivorRatio", 1, 99, default=50),
                EnumParam("jvm_gc", ("ParallelGC", "G1GC", "CMS"), "ParallelGC"),
            ]
        )

    def _utilization_score(self, cfg: Config) -> float:
        """0..1 'smoothness-free' capacity score."""
        mt = cfg["maxThreads"]
        heap = cfg["jvm_heap_mb"]
        # concave peak in threads (context-switch cost beyond ~400)
        s_threads = math.exp(-((mt - 420) / 320.0) ** 2)
        s_heap = _sat(math.log2(heap / 256.0), 2.2, 1.6)
        s_accept = _sat(cfg["acceptCount"], 150, 0.01)
        s_keep = _sat(cfg["maxKeepAliveRequests"], 60, 0.02)
        s_nodelay = 1.0 if cfg["tcpNoDelay"] else 0.93
        s_comp = {"off": 1.0, "on": 0.97, "force": 0.90}[cfg["compression"]]
        s_gc = {"ParallelGC": 0.97, "G1GC": 1.0, "CMS": 0.95}[cfg["jvm_gc"]]
        return (
            0.45 * s_threads + 0.25 * s_heap + 0.1 * s_accept + 0.1 * s_keep
        ) * s_nodelay * s_comp * s_gc + 0.1

    def _bumps(self, cfg: Config) -> float:
        """Irregular bumpy modulation; phase set by the JVM survivor ratio."""
        mt = cfg["maxThreads"]
        ac = cfg["acceptCount"]
        phase = cfg["jvm_TargetSurvivorRatio"] / 99.0 * 2 * math.pi
        b = (
            0.05 * math.sin(mt / 37.0 + phase)
            + 0.04 * math.sin(mt / 11.0 + 2.3 * phase)
            + 0.03 * math.sin(ac / 23.0 - phase)
        )
        return 1.0 + b

    def test(self, config: Config) -> PerfMetric:
        self.space().validate(config)
        score = self._utilization_score(config) * self._bumps(config)
        default = dict(self.space().default_config())
        ref = self._utilization_score(default) * self._bumps(default)
        rel = score / ref
        if self.fully_utilized:
            # §5.2: network cores saturated — compress headroom to ~±5%.
            rel = 1.0 + 0.28 * (rel - 1.0) if rel > 1 else rel
            rel = min(rel, 1.055)
        txns = self.DEFAULT_TXNS * rel * _jitter(config)
        hits = 3235.0 * (rel ** 2.8) * _jitter(config, 0.003)  # hits grow faster
        failed = max(0.0, 165.0 / (rel ** 3.2)) * _jitter(config, 0.01)
        errors = max(0.0, 37.0 / (rel ** 2.4)) * _jitter(config, 0.01)
        passed = txns * 3600.0 * 0.904
        return PerfMetric(
            value=txns,
            higher_is_better=True,
            metrics={
                "txns_per_sec": txns,
                "hits_per_sec": hits,
                "passed_txns": passed,
                "failed_txns": failed,
                "errors": errors,
            },
        )


# ---------------------------------------------------------------------------
# Spark (§2.2 Fig. 1c/1f)
# ---------------------------------------------------------------------------
class SparkSurrogate(Surrogate):
    """Spark surrogate: smooth in standalone mode, ridge at cores=4 in cluster."""

    name = "spark"
    DEFAULT_TPUT = 100.0  # normalized job throughput

    def __init__(self, deployment: str = "standalone"):
        if deployment not in ("standalone", "cluster"):
            raise ValueError(f"unknown deployment {deployment!r}")
        self.deployment = deployment
        self.name = f"spark[{deployment}]"

    def space(self) -> ParameterSpace:
        return ParameterSpace(
            [
                IntParam("executor_cores", 1, 8, default=1),
                IntParam("executor_memory_mb", 512, 16384, default=1024, log=True),
                IntParam("default_parallelism", 8, 512, default=16),
                BoolParam("shuffle_compress", True),
                EnumParam("serializer", ("java", "kryo"), "java"),
                FloatParam("memory_fraction", 0.3, 0.9, default=0.6),
            ]
        )

    def test(self, config: Config) -> PerfMetric:
        self.space().validate(config)
        c = config
        mem = math.log2(c["executor_memory_mb"] / 512.0) / 5.0  # 0..1
        par = math.log2(c["default_parallelism"] / 8.0) / 6.0  # 0..1
        s = (
            0.8 * _sat(c["executor_cores"], 3.0, 1.1)
            + 0.7 * _sat(mem, 0.45, 6.0)
            + 0.3 * math.exp(-((par - 0.55) / 0.35) ** 2)
            + (0.12 if c["serializer"] == "kryo" else 0.0)
            + (0.05 if c["shuffle_compress"] else 0.0)
            + 0.2 * math.exp(-((c["memory_fraction"] - 0.62) / 0.18) ** 2)
        )
        if self.deployment == "cluster":
            # Fig 1f: sharp rise at executor.cores == 4 (NUMA/slot alignment).
            if c["executor_cores"] == 4:
                s *= 1.35
            elif c["executor_cores"] > 4:
                s *= 0.92  # oversubscription penalty
        tput = self.DEFAULT_TPUT * s * _jitter(config)
        return PerfMetric(value=tput, higher_is_better=True,
                          metrics={"jobs_norm": tput, "deployment": self.deployment})


# ---------------------------------------------------------------------------
# Front-end cache / load balancer + composition (§5.5)
# ---------------------------------------------------------------------------
class FrontendSurrogate(Surrogate):
    """Front-end caching/LB tier whose capacity ceiling is near the *untuned*
    DB throughput — the §5.5 bottleneck."""

    name = "frontend"

    def __init__(self, capacity_ceiling: float = 11000.0):
        self.capacity_ceiling = capacity_ceiling

    def space(self) -> ParameterSpace:
        mb = 1024 * 1024
        return ParameterSpace(
            [
                IntParam("cache_size_mb", 64, 8192, default=256, log=True),
                EnumParam("eviction", ("lru", "lfu", "fifo"), "lru"),
                IntParam("worker_threads", 1, 64, default=8),
                BoolParam("pipeline_requests", False),
            ]
        )

    def test(self, config: Config) -> PerfMetric:
        self.space().validate(config)
        c = config
        s = (
            0.75
            + 0.10 * _sat(math.log2(c["cache_size_mb"] / 64.0), 3.0, 1.2)
            + {"lru": 0.05, "lfu": 0.07, "fifo": 0.0}[c["eviction"]]
            + 0.06 * _sat(c["worker_threads"], 12, 0.25)
            + (0.05 if c["pipeline_requests"] else 0.0)
        )
        tput = self.capacity_ceiling * s * _jitter(config)
        return PerfMetric(value=tput, higher_is_better=True,
                          metrics={"ops_per_sec": tput})


class ComposedSUT(Surrogate):
    """Co-deployed systems tuned together (§2.1, §5.5).

    The joint knob space is the (prefixed) merge of the member spaces; the
    end-to-end throughput is the pipeline bottleneck min over members, with a
    small interaction drag (shared CPU/memory, §2.2) when both are pushed.
    """

    def __init__(self, members: Dict[str, Surrogate], interaction: float = 0.04):
        self.members = dict(members)
        self.interaction = interaction
        self.name = "+".join(self.members)

    def space(self) -> ParameterSpace:
        import copy

        # Prefix every member's knobs to keep the joint space collision-free.
        params = []
        for prefix, m in self.members.items():
            for p in m.space():
                q = copy.copy(p)
                object.__setattr__(q, "name", f"{prefix}.{p.name}")
                params.append(q)
        return ParameterSpace(params)

    def _split(self, config: Config) -> Dict[str, Config]:
        out: Dict[str, Config] = {k: {} for k in self.members}
        for k, v in config.items():
            prefix, knob = k.split(".", 1)
            out[prefix][knob] = v
        return out

    def test(self, config: Config) -> PerfMetric:
        parts = self._split(config)
        values = {
            name: self.members[name].test(cfg).value for name, cfg in parts.items()
        }
        bottleneck = min(values, key=values.get)
        overall = min(values.values()) * (1.0 - self.interaction)
        return PerfMetric(
            value=overall,
            higher_is_better=True,
            metrics={"member_values": values, "bottleneck_member": bottleneck},
        )
