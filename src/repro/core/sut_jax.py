"""The JAX distributed runtime as an ACTS system-under-tune.

This is the paper's architecture instantiated on this framework:

* **SystemManipulator** — applies a knob configuration by *re-jitting* the
  train/serve step under new sharding rules / remat / microbatching (the
  analogue of rewriting my.cnf and restarting mysqld; the restart cost is
  the XLA compile, which is exactly why the resource limit is counted in
  tests),
* **WorkloadGenerator** — the (architecture × input shape) cell; "running"
  the workload means AOT-compiling it for the production mesh and reading
  the roofline terms off the compiled artifact (the staging-environment
  measurement), or — for CPU-sized configs — actually timing real steps
  (``measured=True``),
* metric — estimated step seconds (max of the three roofline terms), to be
  minimized, with an HBM-capacity penalty so infeasible settings lose.

The knob space mirrors ``repro.train.step.RunKnobs``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

from repro.core.params import (
    BoolParam,
    Config,
    EnumParam,
    IntParam,
    ParameterSpace,
)
from repro.core.tuner import PerfMetric

__all__ = ["JaxDryRunSUT", "knob_space", "knobs_from_config",
           "JaxMeasuredSUT", "TrainStepSUT", "median_wall_clock"]

HBM_GIB = 16.0  # v5e


def knob_space(kind: str = "train", include_mesh_knobs: bool = True
               ) -> ParameterSpace:
    """The configuration-parameter space of the distributed runtime."""
    params = [
        EnumParam("rules_preset",
                  ("fsdp_tp", "tp", "dp", "dp_all", "fsdp_all"), "fsdp_tp"),
        EnumParam("remat", ("full", "dots", "none"), "full"),
        EnumParam("microbatches", (1, 2, 4, 8, 16), 4),
        EnumParam("loss_chunk", (0, 512, 2048), 512),
        EnumParam("moe_group", (1024, 4096, 16384), 4096),
        BoolParam("seq_shard", False),
        BoolParam("sp_residual", False),
        BoolParam("kv_seq_shard", False),
        BoolParam("expert_tp", False),
        BoolParam("pad_heads", False),
        BoolParam("head_dim_shard", False),
        EnumParam("attn_block_q", (0, 256, 512, 1024), 0),
        EnumParam("attn_block_kv", (0, 512, 1024, 2048), 0),
    ]
    if kind != "train":
        # decode/prefill: trainer-only knobs pinned by omission
        params = [p for p in params
                  if p.name not in ("remat", "microbatches", "loss_chunk")]
    return ParameterSpace(params)


def knobs_from_config(config: Config):
    from repro.train.step import RunKnobs

    fields = {f.name for f in dataclasses.fields(RunKnobs)}
    kwargs = {k: v for k, v in config.items() if k in fields}
    return RunKnobs(**kwargs)


class JaxDryRunSUT:
    """config -> compile the cell -> roofline-estimated step seconds."""

    def __init__(self, arch: str, shape: str, multi_pod: bool = False,
                 hbm_gib: float = HBM_GIB, verbose: bool = False):
        self.arch = arch
        self.shape = shape
        self.multi_pod = multi_pod
        self.hbm_gib = hbm_gib
        self.verbose = verbose
        self.name = f"jax[{arch}×{shape}]"
        self.records = []  # full dry-run records of every test

    def test(self, config: Config) -> PerfMetric:
        from repro.launch.dryrun import run_cell
        from repro.launch.roofline import roofline_terms

        knobs = knobs_from_config(config)
        try:
            rec = run_cell(self.arch, self.shape, multi_pod=self.multi_pod,
                           knobs=knobs, verbose=False)
        except Exception as e:  # invalid configs lose, but don't crash ACTS
            if self.verbose:
                print(f"[sut_jax] compile failed for {config}: {e}")
            return PerfMetric(value=math.inf, higher_is_better=False,
                              metrics={"error": str(e)})
        if rec.get("status") != "ok":
            return PerfMetric(value=math.inf, higher_is_better=False,
                              metrics={"error": rec.get("reason", "skipped")})
        terms = roofline_terms(rec)
        t = terms["t_est_s"]
        # HBM feasibility penalty on the resident estimate (exact argument
        # bytes + modeled activations; the CPU backend's temp_size is kept
        # as a diagnostic only): +1x per HBM of overflow steers the search
        # back into feasible territory instead of a cliff.
        mem = terms.get("resident_gib")
        penalty = 1.0
        if mem is not None and mem > self.hbm_gib:
            penalty += (mem - self.hbm_gib) / self.hbm_gib
        value = t * penalty
        rec["tuner_config"] = dict(config)
        rec["tuner_value"] = value
        self.records.append(rec)
        if self.verbose:
            print(f"[sut_jax] t_est={t:.4f}s penalty={penalty:.2f} "
                  f"dom={terms['dominant']} cfg={config}")
        return PerfMetric(
            value=value, higher_is_better=False,
            metrics={
                "t_est_s": t,
                "compute_s": terms["compute_s"],
                "memory_s": terms["memory_s"],
                "collective_s": terms["collective_s"],
                "dominant": terms["dominant"],
                "roofline_fraction": terms["roofline_fraction"],
                "resident_gib": mem,
                "mem_gib_per_device": terms.get("mem_gib_per_device"),
                "penalty": penalty,
            })


def _measured_train_setup(cfg, knobs, seq_len: int, global_batch: int,
                          n_batches: int, seed: int, donate: bool = False):
    """Shared scaffolding for wall-clock train-step SUTs: build the model,
    init state, jit the step under the knobs, and materialize the batch
    list (synthetic frontend embeddings included for frontend/encoder
    models).  Returns (step_fn, params, opt_state, batches)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.data import DataConfig, SyntheticLMDataset
    from repro.models import Model
    from repro.optim import OptimizerConfig
    from repro.train.step import init_train_state, make_train_step

    model = Model(cfg)
    params, opt_state = init_train_state(
        model, jax.random.PRNGKey(seed), knobs)
    data = SyntheticLMDataset(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=seq_len,
        global_batch=global_batch, seed=seed))
    step_fn = jax.jit(make_train_step(model, OptimizerConfig(), knobs),
                      donate_argnums=(0, 1) if donate else ())
    batches = [
        {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        for i in range(n_batches)
    ]
    if cfg.frontend or cfg.encoder:
        rng = np.random.default_rng(seed)
        for b in batches:
            b["frontend_embeds"] = jnp.asarray(rng.normal(
                size=(global_batch, cfg.frontend_tokens,
                      cfg.frontend_dim)).astype(np.float32))
    return step_fn, params, opt_state, batches


def median_wall_clock(fn, warmup: int = 1, repeats: int = 3) -> float:
    """Median wall-clock seconds of ``fn()`` after trimming warmup runs.

    The shared timing methodology of the live (``--real``) co-tuning path:
    ``warmup`` untimed calls absorb compilation and cache effects, then the
    median of ``repeats`` timed calls rejects scheduler-noise outliers that
    a mean (or a single run) would leak into the tuner's objective.
    ``fn`` must block until its work is done (e.g. ``block_until_ready``).
    """
    import time

    for _ in range(max(0, warmup)):
        fn()
    times = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


class TrainStepSUT:
    """The REAL train step as a system-under-tune (live co-tuning member).

    Each test applies the candidate knobs (``repro.train.space``) by
    re-jitting ``make_train_step`` — the paper's apply-config-and-restart —
    and wall-clocks a short microbatch training loop: ``warmup`` untimed
    loops (compile included), then the median of ``repeats`` timed loops of
    ``steps`` steps each.  The metric is training tokens/sec (higher is
    better); step seconds and the final loss ride along as provenance.
    """

    def __init__(self, cfg, seq_len: int = 32, global_batch: int = 8,
                 steps: int = 2, warmup: int = 1, repeats: int = 3,
                 seed: int = 0, rules_preset: str = "dp"):
        self.cfg = cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.steps = steps
        self.warmup = warmup
        self.repeats = repeats
        self.seed = seed
        self.rules_preset = rules_preset
        self.name = f"train-step[{cfg.name}]"

    def space(self) -> ParameterSpace:
        from repro.train.space import train_knob_space

        return train_knob_space(max_microbatches=self.global_batch)

    def test(self, config: Config) -> PerfMetric:
        import jax

        from repro.train.space import apply_train_knobs
        from repro.train.step import RunKnobs

        knobs = apply_train_knobs(
            config, RunKnobs(rules_preset=self.rules_preset))
        step_fn, params, opt_state, batches = _measured_train_setup(
            self.cfg, knobs, self.seq_len, self.global_batch, self.steps,
            self.seed)
        state = {"params": params, "opt": opt_state, "m": None}

        def loop():
            p, o = state["params"], state["opt"]
            for b in batches:
                p, o, m = step_fn(p, o, b)
            jax.block_until_ready(m["loss"])
            state.update(params=p, opt=o, m=m)

        sec = median_wall_clock(loop, self.warmup, self.repeats) / self.steps
        tput = self.seq_len * self.global_batch / sec
        return PerfMetric(
            value=tput, higher_is_better=True,
            metrics={"step_seconds": sec, "tokens_per_sec": tput,
                     "loss": float(state["m"]["loss"]),
                     "warmup": self.warmup, "repeats": self.repeats})


class JaxMeasuredSUT:
    """Real measured tuning for CPU-scale configs: config -> steps/sec.

    This exercises the paper's actual loop (apply config, restart system,
    run workload, measure) end-to-end on hardware we do have.
    """

    def __init__(self, cfg, seq_len: int = 128, global_batch: int = 8,
                 steps: int = 6, warmup: int = 2, seed: int = 0):
        self.cfg = cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.steps = steps
        self.warmup = warmup
        self.seed = seed
        self.name = f"jax-measured[{cfg.name}]"

    def space(self) -> ParameterSpace:
        return ParameterSpace([
            EnumParam("remat", ("full", "dots", "none"), "full"),
            EnumParam("microbatches", (1, 2, 4), 1),
            EnumParam("loss_chunk", (0, 32, 64), 0),
            BoolParam("donate", True),
            EnumParam("scan_unroll", (1, 2), 1),
        ])

    def test(self, config: Config) -> PerfMetric:
        import time

        import jax

        from repro.train.step import RunKnobs

        knobs = RunKnobs(
            remat=config["remat"], microbatches=config["microbatches"],
            loss_chunk=config["loss_chunk"], donate=config["donate"],
            scan_unroll=config["scan_unroll"], rules_preset="dp")
        step_fn, params, opt_state, batches = _measured_train_setup(
            self.cfg, knobs, self.seq_len, self.global_batch,
            self.warmup + self.steps, self.seed, donate=knobs.donate)
        for i in range(self.warmup):  # includes compile
            params, opt_state, m = step_fn(params, opt_state, batches[i])
        jax.block_until_ready(m["loss"])
        t0 = time.time()
        for i in range(self.warmup, self.warmup + self.steps):
            params, opt_state, m = step_fn(params, opt_state, batches[i])
        jax.block_until_ready(m["loss"])
        dt = (time.time() - t0) / self.steps
        tput = self.seq_len * self.global_batch / dt
        return PerfMetric(value=tput, higher_is_better=True,
                          metrics={"step_seconds": dt,
                                   "tokens_per_sec": tput,
                                   "loss": float(m["loss"])})
