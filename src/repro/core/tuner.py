"""The ACTS flexible architecture (paper §4.2, Figure 2).

Three components, deliberately decoupled so each scalability axis can vary
independently:

* ``SystemManipulator`` — knows how to apply a configuration setting to the
  SUT and (re)start it.  Swapping the manipulator swaps the SUT/deployment
  (SUT + deployment-environment scalability).
* ``WorkloadGenerator`` — knows how to drive the configured SUT and measure a
  ``PerfMetric`` (workload scalability).
* ``Tuner`` — owns the parameter space, the resource limit, the sampler and
  the optimizer; it never touches the SUT directly (parameter-set and
  resource-limit scalability).

The tuner runs every test through a cache keyed on the concrete config, so
duplicate settings (common once enum/int knobs quantize) never burn budget —
the resource limit counts *actual tests on the SUT*, which is what costs
machine-time in the paper's staging environment.
"""
from __future__ import annotations

import json
import math
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Protocol, Sequence, \
    Tuple

import numpy as np

from .base import BudgetExhausted, Trial, TuningResult
from .optimizers import get_optimizer
from .params import Config, ParameterSpace
from .sampling import lhs_unit

__all__ = [
    "PerfMetric",
    "SystemManipulator",
    "WorkloadGenerator",
    "BatchEvaluator",
    "TunableSystem",
    "CallableSUT",
    "Tuner",
    "TuningReport",
]


@dataclass
class PerfMetric:
    """A single performance measurement of the SUT under the workload."""

    value: float  # primary metric (e.g. ops/sec or est. step seconds)
    higher_is_better: bool = True
    metrics: Dict[str, Any] = field(default_factory=dict)  # secondary metrics

    def objective(self) -> float:
        """Minimization view of the metric."""
        v = float(self.value)
        if math.isnan(v):
            return math.inf
        return -v if self.higher_is_better else v


class SystemManipulator(Protocol):
    """Controls the SUT in its deployment environment (start/stop/configure)."""

    def apply(self, config: Config) -> Any:
        """Apply a configuration and (re)start the SUT; returns a handle."""
        ...

    def teardown(self, handle: Any) -> None:
        ...


class WorkloadGenerator(Protocol):
    """Drives the configured SUT and measures performance."""

    def run(self, handle: Any) -> PerfMetric:
        ...


class BatchEvaluator(Protocol):
    """A SUT that can score a whole sample set in one call.

    ``test_batch`` must return one ``PerfMetric`` per config, in order, and
    must be *value-equivalent* to calling ``test`` per config — the tuner
    relies on that equivalence for batched-vs-sequential parity.  SUTs whose
    evaluation is vectorizable (analytic surrogates, ``jax.vmap``-able
    models) implement this to collapse each optimizer round into a single
    Python call; the trial cache, budget accounting and ``TuningReport``
    are unaffected.
    """

    def test_batch(self, configs: Sequence[Config]) -> Sequence[PerfMetric]:
        ...


class TunableSystem:
    """Manipulator + workload generator == one testable SUT deployment."""

    def __init__(
        self,
        manipulator: SystemManipulator,
        workload: WorkloadGenerator,
        name: str = "sut",
    ):
        self.manipulator = manipulator
        self.workload = workload
        self.name = name

    def test(self, config: Config) -> PerfMetric:
        handle = self.manipulator.apply(config)
        try:
            return self.workload.run(handle)
        finally:
            self.manipulator.teardown(handle)


class CallableSUT:
    """Adapter: a plain ``config -> PerfMetric`` function as a TunableSystem.

    Pass ``batch_fn`` (configs -> metrics) to make the adapter a
    ``BatchEvaluator``; without it the tuner falls back to per-config calls.
    """

    def __init__(self, fn: Callable[[Config], PerfMetric], name: str = "sut",
                 batch_fn: Optional[
                     Callable[[Sequence[Config]], Sequence[PerfMetric]]
                 ] = None):
        self.fn = fn
        self.name = name
        if batch_fn is not None:
            # instance attribute, so hasattr-based batch detection only
            # fires for adapters that actually provide one
            self.test_batch = batch_fn

    def test(self, config: Config) -> PerfMetric:
        return self.fn(config)


@dataclass
class TuningReport:
    sut_name: str
    best_config: Config
    best_metric: PerfMetric
    default_config: Config
    default_metric: PerfMetric
    n_tests: int
    budget: int
    wall_seconds: float
    history: List[Trial]
    optimizer: str
    sampler: str
    # candidates the static feasibility model rejected before the SUT —
    # uncharged, unrecorded; the budget they would have burned went to
    # feasible candidates instead (0 when no model is attached)
    n_infeasible_pruned: int = 0

    @property
    def improvement(self) -> float:
        """best/default ratio in the *user-facing* direction (≥1 is better)."""
        d, b = self.default_metric, self.best_metric
        if d.value == 0:
            return math.inf
        ratio = b.value / d.value
        return ratio if d.higher_is_better else (1.0 / ratio if ratio else math.inf)

    def best_so_far_values(self) -> List[float]:
        """Best metric value (user-facing direction) after each test."""
        sign = -1.0 if self.default_metric.higher_is_better else 1.0
        return [sign * v for v in TuningResult(
            self.best_config, self.best_metric.objective(), self.history,
            self.n_tests).best_so_far()]

    def to_json(self) -> str:
        return json.dumps(
            {
                "sut": self.sut_name,
                "optimizer": self.optimizer,
                "sampler": self.sampler,
                "budget": self.budget,
                "n_tests": self.n_tests,
                "n_infeasible_pruned": self.n_infeasible_pruned,
                "wall_seconds": self.wall_seconds,
                "default": {
                    "config": _jsonable(self.default_config),
                    "value": self.default_metric.value,
                    "metrics": _jsonable(self.default_metric.metrics),
                },
                "best": {
                    "config": _jsonable(self.best_config),
                    "value": self.best_metric.value,
                    "metrics": _jsonable(self.best_metric.metrics),
                },
                "improvement": self.improvement,
                "history": [
                    {
                        "test": t.test_index,
                        "phase": t.phase,
                        "value": t.value,
                        "config": _jsonable(t.config),
                    }
                    for t in self.history
                ],
            },
            indent=2,
        )


def _jsonable(obj: Any) -> Any:
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj


class Tuner:
    """The ACTS tuner: resource-limited LHS + RRS over a TunableSystem.

    ``budget`` is the number of allowed tests (§3: the resource limit).  The
    given/default configuration is always tested first — the ACTS contract is
    to return a setting *at least as good as* the given one, so the default's
    measurement both anchors the improvement ratio and participates in the
    search history.

    ``batch`` selects the evaluation engine: ``None`` (default) batches
    whenever the SUT implements the ``BatchEvaluator`` protocol, ``True``
    forces batching (falling back to an internal loop for test-only SUTs)
    and ``False`` forces one ``sut.test`` call per trial.  Both engines run
    the identical trial sequence — same seed + budget gives the same best
    config and test count — because the optimizers generate candidates
    round-by-round independent of how rounds are scored.

    ``feasibility`` attaches a static feasibility model (a ``Config ->
    bool`` callable; see ``repro.analysis.feasibility``): candidates it
    rejects are pruned inside the optimizer's ``BudgetedRun`` without
    charging budget or touching the SUT, and the count surfaces as
    ``TuningReport.n_infeasible_pruned``.  ``None`` (default) auto-detects
    the SUT's ``feasibility_model`` attribute; ``False`` disables pruning
    outright.  The default configuration is still tested unconditionally —
    the ACTS contract anchors on the given config, feasible or not.

    ``warm_start`` seeds the run with prior winners (transfer from a
    related tuning context — another workload signature, an earlier
    deployment): each seed is tested right after the default, before any
    sampling, and joins the history as an ordinary ``"warm"`` trial — so
    the "best tested config" contract returns a seed that still holds up
    even when the budget leaves no room for search, and the optimizer's
    budget share shrinks by exactly the seeds' test cost.  Seeds must
    validate in ``space`` (snap out-of-space transfers first — see
    ``repro.serve.workload.coerce_config``); statically infeasible seeds
    are skipped uncharged.
    """

    def __init__(
        self,
        space: ParameterSpace,
        sut,
        budget: int,
        optimizer: str = "rrs",
        sampler: str = "lhs",
        init_fraction: float = 0.3,
        seed: int = 0,
        optimizer_kwargs: Optional[Dict[str, Any]] = None,
        verbose: bool = False,
        batch: Optional[bool] = None,
        feasibility: Any = None,
        warm_start: Optional[Sequence[Config]] = None,
    ):
        if budget < 1:
            raise ValueError("budget (resource limit) must be >= 1")
        self.space = space
        self.sut = sut
        self.budget = budget
        if feasibility is None:
            feasibility = getattr(sut, "feasibility_model", None)
        elif feasibility is False:
            feasibility = None
        if feasibility is not None and not callable(feasibility):
            raise TypeError("feasibility must be callable (Config -> "
                            f"bool), False, or None; got {feasibility!r}")
        self.feasibility = feasibility
        self.optimizer_name = optimizer
        self.sampler_name = sampler
        self.init_fraction = init_fraction
        self.seed = seed
        self.optimizer_kwargs = dict(optimizer_kwargs or {})
        self.verbose = verbose
        self.warm_start = [dict(c) for c in (warm_start or [])]
        if batch is None:
            batch = callable(getattr(sut, "test_batch", None))
        self.batch = bool(batch)

        self._cache: Dict[Tuple, PerfMetric] = {}
        self._n_tests = 0
        self._higher_is_better: Optional[bool] = None
        # SUT invocations: one per test() call plus one per test_batch()
        # call — the quantity the batched engine minimizes.
        self.n_evaluator_calls = 0

    # ------------------------------------------------------------------
    def _run_sut(self, configs: List[Config]) -> List[PerfMetric]:
        """Uncached, unbudgeted SUT evaluation of distinct configs."""
        if self.batch and callable(getattr(self.sut, "test_batch", None)):
            self.n_evaluator_calls += 1
            metrics = list(self.sut.test_batch(configs))
            if len(metrics) != len(configs):
                raise ValueError(
                    f"{getattr(self.sut, 'name', 'sut')}.test_batch returned "
                    f"{len(metrics)} metrics for {len(configs)} configs")
            return metrics
        out = []
        for cfg in configs:
            self.n_evaluator_calls += 1
            out.append(self.sut.test(cfg))
        return out

    def _record(self, keys: List[Tuple], metrics: List[PerfMetric]) -> None:
        for key, metric in zip(keys, metrics):
            self._n_tests += 1
            self._cache[key] = metric
            if self._higher_is_better is None:
                self._higher_is_better = metric.higher_is_better
            if self.verbose:
                print(
                    f"[tuner] test {self._n_tests}/{self.budget}: "
                    f"value={metric.value:.6g} config={dict(key)}"
                )

    def _test(self, config: Config) -> PerfMetric:
        """Budgeted, cached test of one configuration on the real SUT."""
        key = self.space.config_key(config)
        if key in self._cache:
            return self._cache[key]
        if self._n_tests >= self.budget:
            raise BudgetExhausted
        self._record([key], self._run_sut([config]))
        return self._cache[key]

    def _test_many(self, configs: Sequence[Config]) -> List[PerfMetric]:
        """Budgeted, cached test of a candidate round.

        Returns metrics for the longest *prefix* of ``configs`` the resource
        limit allows (cache hits are free; only distinct new configs count).
        A short return signals budget exhaustion to the optimizer, matching
        what a per-config loop would have evaluated before stopping.
        """
        plan: List[Tuple] = []  # key per prefix config, in order
        miss_keys: List[Tuple] = []
        miss_cfgs: List[Config] = []
        pending = set()
        for cfg in configs:
            key = self.space.config_key(cfg)
            if key not in self._cache and key not in pending:
                if self._n_tests + len(miss_cfgs) >= self.budget:
                    break  # this config would exceed the resource limit
                pending.add(key)
                miss_keys.append(key)
                miss_cfgs.append(cfg)  # SUTs must not mutate configs
            plan.append(key)
        if miss_cfgs:
            self._record(miss_keys, self._run_sut(miss_cfgs))
        return [self._cache[k] for k in plan]

    def run(self) -> TuningReport:
        t0 = time.time()
        rng = np.random.default_rng(self.seed)
        history: List[Trial] = []

        # 1. Measure the given (default) configuration first.
        default_cfg = self.space.default_config()
        default_metric = self._test(default_cfg)
        history.append(
            Trial(default_cfg, default_metric.objective(), self._n_tests, "default",
                  metrics=dict(default_metric.metrics))
        )

        # 1b. Warm-start round: transfer seeds are tested before any
        # sampling and join the history like ordinary trials.  Duplicate
        # seeds (and seeds equal to the default) are cache hits — free;
        # statically infeasible seeds are skipped uncharged; a short
        # _test_many prefix means the budget ran out mid-round.  The rng
        # is untouched, so seeding never perturbs the sampled sequence
        # beyond the budget it consumes.
        if self.warm_start:
            seeds = []
            for cfg in self.warm_start:
                self.space.validate(cfg)
                if self.feasibility is None or self.feasibility(cfg):
                    seeds.append(cfg)
            for cfg, metric in zip(seeds, self._test_many(seeds)):
                history.append(
                    Trial(cfg, metric.objective(), self._n_tests, "warm",
                          metrics=dict(metric.metrics)))

        # 2. Initial LHS round (§4.3): coverage at any budget.
        n_init = min(
            max(0, self.budget - self._n_tests),
            max(1, int(self.budget * self.init_fraction)),
        )
        init_points = lhs_unit(n_init, self.space.dim, rng) if n_init else None

        # 3. Optimizer consumes the remaining budget (RRS by default).
        def objective(cfg: Config) -> float:
            return self._test(cfg).objective()

        def batch_objective(cfgs: Sequence[Config]) -> List[float]:
            return [m.objective() for m in self._test_many(cfgs)]

        opt = get_optimizer(self.optimizer_name, **self.optimizer_kwargs)
        remaining = self.budget - self._n_tests
        n_pruned = 0
        if remaining > 0:
            # The optimizer gets head-room over the real limit because cached
            # (duplicate) configs don't consume SUT tests; the tuner's own
            # short-prefix/BudgetExhausted signal is what stops the run.
            result = opt.optimize(
                self.space,
                objective,
                budget=remaining * 4,
                rng=rng,
                init_unit_points=init_points,
                batch_objective=batch_objective,
                feasible=self.feasibility,
            )
            n_pruned = result.n_infeasible_pruned
            # Re-index trials to global test counters (optimizer counts its own).
            offset = len(history)
            for t in result.history:
                history.append(
                    Trial(t.config, t.value, offset + t.test_index, t.phase)
                )

        # 4. Pick the best *tested* configuration (ACTS contract: >= default).
        best_trial = min(history, key=lambda t: t.value)
        best_cfg = best_trial.config
        best_metric = self._cache[self.space.config_key(best_cfg)]

        return TuningReport(
            sut_name=getattr(self.sut, "name", "sut"),
            best_config=best_cfg,
            best_metric=best_metric,
            default_config=default_cfg,
            default_metric=default_metric,
            n_tests=self._n_tests,
            budget=self.budget,
            wall_seconds=time.time() - t0,
            history=history,
            optimizer=self.optimizer_name,
            sampler=self.sampler_name,
            n_infeasible_pruned=n_pruned,
        )
