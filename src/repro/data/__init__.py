"""Data substrate."""
from .pipeline import DataConfig, SyntheticLMDataset, batch_specs

__all__ = ["DataConfig", "SyntheticLMDataset", "batch_specs"]
