"""Deterministic, host-sharded, restart-safe synthetic LM data pipeline.

Fault-tolerance requirement: after a crash/restart (or an elastic rescale to
a different host count), the pipeline must reproduce exactly the batch for
any given step.  We therefore derive every batch *functionally* from
``(seed, step, host)`` with a counter-based Philox generator — no iterator
state exists to lose.  Tokens follow a Zipfian marginal with short-range
Markov structure so the LM loss actually decreases during the examples.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

__all__ = ["DataConfig", "SyntheticLMDataset", "batch_specs"]


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    n_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2


class SyntheticLMDataset:
    """batch_at(step) -> {"tokens": (B_host, S) i32, "labels": ...}."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        self.cfg = cfg
        self.host_batch = cfg.global_batch // cfg.n_hosts
        # fixed per-seed "bigram" permutation for Markov structure
        perm_rng = np.random.Generator(np.random.Philox(key=cfg.seed))
        self._perm = perm_rng.permutation(cfg.vocab_size)

    def _rng_for(self, step: int) -> np.random.Generator:
        c = self.cfg
        key = (c.seed, step, c.host_id)
        return np.random.Generator(np.random.Philox(key=np.uint64(
            (key[0] * 1_000_003 + key[1]) * 1_000_003 + key[2])))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = self._rng_for(step)
        B, S = self.host_batch, c.seq_len
        # zipf marginal, clipped into vocab
        base = rng.zipf(c.zipf_a, size=(B, S + 1)) % c.vocab_size
        # Markov structure: with p=0.5 the next token is perm[prev]
        follow = rng.random((B, S)) < 0.5
        seq = base.copy()
        for t in range(1, S + 1):
            seq[:, t] = np.where(follow[:, t - 1],
                                 self._perm[seq[:, t - 1]], base[:, t])
        tokens = seq[:, :S].astype(np.int32)
        labels = seq[:, 1:S + 1].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def batch_specs(vocab_size: int, seq_len: int, global_batch: int,
                frontend: Optional[Tuple[int, int]] = None):
    """ShapeDtypeStructs for a *global* batch (dry-run input stand-ins)."""
    import jax
    import jax.numpy as jnp

    specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
    }
    if frontend:
        n_tok, dim = frontend
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (global_batch, n_tok, dim), jnp.bfloat16)
    return specs
