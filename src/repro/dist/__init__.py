"""Distributed-execution substrate: logical-axis sharding rules."""
from .sharding import (
    DEFAULT_RULES,
    DP_ALL_RULES,
    RULE_PRESETS,
    AxisRules,
    axis_rules,
    constrain,
    spec_for_shape,
)

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "DP_ALL_RULES",
    "RULE_PRESETS",
    "axis_rules",
    "constrain",
    "spec_for_shape",
]
