"""Logical-axis sharding: named-rule mapping from model axes to mesh axes.

Models annotate every parameter/activation dimension with a *logical* axis
name ("batch", "heads", "ff", ...).  An ``AxisRules`` table maps logical
axes to physical mesh axes ("pod", "data", "model"); swapping the table
re-shards the whole program without touching model code — this is the knob
surface the ACTS tuner drives (``RunKnobs.rules_preset`` and friends).

Safety properties of ``spec_for_shape`` (what makes *any* ruleset a valid
configuration rather than a compile error):

* a mesh axis absent from the active mesh is silently dropped (the same
  rules work on 16x16 and 2x16x16 meshes),
* a mapping whose mesh-axis product does not divide the dimension is
  dropped entirely (e.g. 40 heads on a 16-way model axis falls back to
  replication instead of failing to lower),
* each mesh axis is used at most once per tensor (first dimension wins),
  so joint rules never produce an over-constrained spec.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Dict, Iterator, Mapping, Optional, Sequence, Tuple, Union

from jax.sharding import NamedSharding, PartitionSpec

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "DP_ALL_RULES",
    "KNOWN_LOGICAL_AXES",
    "REPLICATED_AXES",
    "RULE_PRESETS",
    "SERVE_REPLICAS_RULES",
    "SERVE_TP_RULES",
    "axis_rules",
    "constrain",
    "spec_for_shape",
]

# A logical axis maps to one mesh axis, a tuple of mesh axes, or None.
AxisTarget = Union[str, Tuple[str, ...], None]


def _canon_target(t: Any) -> AxisTarget:
    if t is None or isinstance(t, str):
        return t
    return tuple(t)


class AxisRules:
    """Immutable logical-axis -> mesh-axis mapping.

    ``replace`` returns a new table with the given entries overridden (or
    added; mapping to ``None`` unmaps).  ``lookup`` returns ``None`` for any
    unmapped logical axis, so rule tables stay sparse.
    """

    __slots__ = ("_rules",)

    def __init__(self, rules: Optional[Mapping[str, AxisTarget]] = None,
                 **kwargs: AxisTarget):
        merged: Dict[str, AxisTarget] = {}
        for k, v in dict(rules or {}, **kwargs).items():
            v = _canon_target(v)
            if v is not None:
                merged[k] = v
        object.__setattr__(self, "_rules", merged)

    def __setattr__(self, name, value):  # immutability guard
        raise AttributeError("AxisRules is immutable; use .replace()")

    def lookup(self, logical: Optional[str]) -> AxisTarget:
        if logical is None:
            return None
        return self._rules.get(logical)

    def replace(self, **updates: AxisTarget) -> "AxisRules":
        merged = dict(self._rules)
        for k, v in updates.items():
            v = _canon_target(v)
            if v is None:
                merged.pop(k, None)
            else:
                merged[k] = v
        return AxisRules(merged)

    def items(self):
        return self._rules.items()

    def __eq__(self, other) -> bool:
        return isinstance(other, AxisRules) and self._rules == other._rules

    def __hash__(self) -> int:
        return hash(tuple(sorted(self._rules.items())))

    def __repr__(self) -> str:
        body = ", ".join(f"{k}={v!r}" for k, v in sorted(self._rules.items()))
        return f"AxisRules({body})"


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------
# FSDP over the data axis + tensor parallelism over the model axis: the
# production default ("fsdp_tp" in RunKnobs).
DEFAULT_RULES = AxisRules(
    batch=("pod", "data"),
    embed_fsdp="data",
    heads="model",
    kv_heads="model",
    ff="model",
    vocab="model",
    experts="model",
)

# Pure data parallelism over *every* mesh axis (batch spread over the model
# axis too; params fully replicated) — the small-model/throughput extreme.
DP_ALL_RULES = AxisRules(batch=("pod", "data", "model"))

# Serve-side presets: inference meshes are (data, model) with no pod axis,
# and serving never FSDP-shards weights (no ``embed_fsdp``) — replicas need
# the full parameter set resident per data-axis slice, and the TP split
# streams each shard's own heads/ff columns.  The ``data`` axis carries
# engine *replicas* (batch slots spread across them); the ``model`` axis is
# the tensor-parallel split of heads / ff / vocab.
SERVE_TP_RULES = AxisRules(batch="data", heads="model", kv_heads="model",
                           ff="model", vocab="model", experts="model")
SERVE_REPLICAS_RULES = AxisRules(batch="data")

RULE_PRESETS: Dict[str, AxisRules] = {
    "dp": AxisRules(batch=("pod", "data")),
    "dp_all": DP_ALL_RULES,
    # fsdp_all spreads the batch over the model axis too (no TP), sharding
    # params across every axis — the regression the qwen hillclimb hit.
    "fsdp_all": AxisRules(batch=("pod", "data", "model"),
                          embed_fsdp=("data", "model")),
    "tp": AxisRules(batch=("pod", "data"), heads="model", kv_heads="model",
                    ff="model", vocab="model", experts="model"),
    "fsdp_tp": DEFAULT_RULES,
    "serve_tp": SERVE_TP_RULES,
    "serve_replicas": SERVE_REPLICAS_RULES,
}

# Logical axes that are *deliberately* never mapped by any preset: they
# must stay replicated (sequence positions interleave through KV caches;
# head_dim/conv_dim/cap tiles feed kernels whole).  ``constrain`` calls
# naming an axis outside the preset-mapped or deliberately-replicated
# sets silently replicate — the ``constrain-unknown-axis`` lint rule
# flags them against this registry.
REPLICATED_AXES = frozenset({
    "seq", "seq_res", "embed", "head_dim", "cap", "expert_ff", "conv_dim",
})

KNOWN_LOGICAL_AXES = REPLICATED_AXES | frozenset(
    axis for rules in RULE_PRESETS.values() for axis, _ in rules.items())


# ---------------------------------------------------------------------------
# shape -> PartitionSpec
# ---------------------------------------------------------------------------
def _normalize_entries(entries: Sequence[Any]) -> Tuple[Any, ...]:
    out = []
    for e in entries:
        if isinstance(e, (list, tuple)) and len(e) == 1:
            out.append(e[0])
        elif isinstance(e, list):
            out.append(tuple(e))
        else:
            out.append(e)
    while out and out[-1] is None:
        out.pop()
    return tuple(out)


class _SemanticSpec(PartitionSpec):
    """A PartitionSpec comparing by *meaning*, not entry spelling.

    ``PartitionSpec`` is a plain tuple subclass, so ``P(("data",)) !=
    P("data")`` even though they shard identically.  Specs produced by
    ``spec_for_shape`` normalize single-axis tuples and ignore trailing
    ``None`` entries on comparison, matching how ``NamedSharding``
    interprets them.
    """

    def __new__(cls, *partitions):
        # PartitionSpec.__new__ hard-codes its own class; rebuild here so
        # subclass instances actually get the semantic comparison.
        return tuple.__new__(cls, partitions)

    def __eq__(self, other) -> bool:
        if isinstance(other, (PartitionSpec, tuple)):
            return _normalize_entries(self) == _normalize_entries(other)
        return NotImplemented

    def __ne__(self, other) -> bool:
        eq = self.__eq__(other)
        return NotImplemented if eq is NotImplemented else not eq

    def __hash__(self) -> int:
        return hash(_normalize_entries(self))


def spec_for_shape(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    rules: AxisRules,
    mesh: Any = None,
) -> PartitionSpec:
    """PartitionSpec for a tensor of ``shape`` with logical ``axes``.

    ``mesh`` only needs a ``.shape`` mapping of axis name -> size (a real
    ``jax.sharding.Mesh`` or any duck-typed stand-in).  See the module
    docstring for the drop/fallback rules.
    """
    if len(shape) != len(axes):
        raise ValueError(f"shape {tuple(shape)} vs axes {tuple(axes)}")
    mesh_shape: Mapping[str, int] = dict(getattr(mesh, "shape", None) or {})
    used: set = set()
    entries = []
    for dim, logical in zip(shape, axes):
        target = rules.lookup(logical)
        if target is None:
            entries.append(None)
            continue
        cand = (target,) if isinstance(target, str) else tuple(target)
        picked = []
        size = 1
        for a in cand:
            n = mesh_shape.get(a)
            if n is None or a in used:
                continue  # absent from mesh / already used by an earlier dim
            picked.append(a)
            size *= int(n)
        if not picked or size <= 1 or dim % size:
            entries.append(None)  # divisibility fallback: replicate
            continue
        used.update(picked)
        entries.append(picked[0] if len(picked) == 1 else tuple(picked))
    while entries and entries[-1] is None:
        entries.pop()
    return _SemanticSpec(*entries)


# ---------------------------------------------------------------------------
# activation constraints (the `constrain` the model code calls)
# ---------------------------------------------------------------------------
class _ActiveRules(threading.local):
    def __init__(self):
        self.stack = []  # list of (AxisRules, mesh)


_ACTIVE = _ActiveRules()


@contextmanager
def axis_rules(rules: AxisRules, mesh: Any = None) -> Iterator[None]:
    """Activate a rule table (+ mesh) for ``constrain`` calls underneath.

    Tracing a jitted step inside this context attaches sharding constraints
    to every annotated activation; outside any context ``constrain`` is a
    no-op, so the same model code runs unsharded in unit tests.
    """
    _ACTIVE.stack.append((rules, mesh))
    try:
        yield
    finally:
        _ACTIVE.stack.pop()


def current_rules() -> Optional[Tuple[AxisRules, Any]]:
    return _ACTIVE.stack[-1] if _ACTIVE.stack else None


def constrain(x: Any, *axes: Optional[str]) -> Any:
    """Constrain an activation's sharding under the active axis rules.

    ``axes`` are logical names per dimension (``None`` = unsharded).  A
    no-op unless inside an ``axis_rules`` context with a mesh.
    """
    active = current_rules()
    if active is None:
        return x
    rules, mesh = active
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"constrain: {len(axes)} axes for rank-{x.ndim} "
                         f"array {x.shape}")
    spec = spec_for_shape(x.shape, axes, rules, mesh)
    import jax

    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec)))
