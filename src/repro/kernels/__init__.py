"""Pallas TPU kernels for the compute hot spots (flash attention, fused
RMSNorm, chunked gated linear attention, paged decode attention), each
with a pure-jnp oracle in ``ref.py``/its module and a jit'd wrapper in
``ops.py``."""
from . import ops, ref
from .decode_attention import flash_decode_pallas
from .flash_attention import flash_attention_pallas
from .gla import gla_pallas
from .paged_attention import paged_attention_ref, paged_flash_decode_pallas
from .rmsnorm import rmsnorm_pallas

__all__ = ["ops", "ref", "flash_attention_pallas", "flash_decode_pallas",
           "gla_pallas", "paged_attention_ref",
           "paged_flash_decode_pallas", "rmsnorm_pallas"]
