"""Flash-decode Pallas kernel: one query token vs a long KV cache.

Decode attention is bandwidth-bound (stream the cache once, trivial
compute), so the kernel's job is to keep the cache read perfectly
sequential and VMEM-tiled while handling GQA and a *dynamic* valid length
(`kv_len`, the number of tokens written so far — decode caches are
pre-allocated at max_seq).

Layout: grid (B, KV-head, kv-blocks); all G query heads of a KV group are
processed together as a (G, D) tile so each cache block is read ONCE per
group (the GQA bandwidth win).  Online-softmax state (m, l, acc) lives in
VMEM scratch across the kv-block dimension; fully-invalid blocks are
skipped with ``pl.when`` (so a cache filled to 2k of 32k only streams 2k).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .launch import launch_params

__all__ = ["flash_decode_pallas"]

NEG_INF = -1e30


def _kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_kv: int, scale: float):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)
    kv_len = kvlen_ref[0]

    @pl.when(ik == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ik * block_kv

    @pl.when(k_start < kv_len)  # skip never-written cache tail
    def compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale  # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bkv, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G,bkv)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_decode_pallas(
    q: jax.Array,  # (B, H, D) — one new token per sequence
    k: jax.Array,  # (B, S, KV, D) cache buffer
    v: jax.Array,  # (B, S, KV, D)
    kv_len: jax.Array,  # scalar int32: valid cache entries
    *,
    block_kv: int = 256,
    dimension_semantics: Optional[str] = None,
    num_warps: Optional[int] = None,  # GPU-lowering hint; inert on TPU
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    if H % KV:
        raise ValueError(f"H={H} not a multiple of KV={KV}")
    G = H // KV
    block_kv = min(block_kv, S)
    pad = (-S) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = k.shape[1] // block_kv
    qg = q.reshape(B, KV, G, D)
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(1)

    # the kv-block dim carries the online-softmax scratch; B/KV parallel
    params = launch_params(dimension_semantics, 3, 1, interpret)
    del num_warps
    out = pl.pallas_call(
        functools.partial(_kernel, block_kv=block_kv,
                          scale=1.0 / math.sqrt(D)),
        grid=(B, KV, nk),
        **({"compiler_params": params} if params else {}),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # kv_len scalar
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D), lambda b, h, ik: (b, ik, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len, qg, k, v)
    return out.reshape(B, H, D)
