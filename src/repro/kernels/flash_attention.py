"""Flash attention as a Pallas TPU kernel.

TPU adaptation (vs. the CUDA flash-attention algorithm):
* tiling is chosen for the MXU (128-aligned q/kv blocks) and VMEM residency —
  one (block_q × head_dim) query tile and one (block_kv × head_dim) KV tile
  live in VMEM per grid step; the online-softmax running state (m, l, acc)
  sits in VMEM scratch and persists across the sequential kv grid dimension,
* the kv loop is a *grid dimension* (TPU grids iterate minor-to-major, so
  scratch carries across kv steps for a fixed query tile), not an in-kernel
  loop — this lets Mosaic double-buffer the HBM→VMEM streams of K and V,
* GQA is handled in the index maps (kv head = q head // group), so KV tiles
  are fetched once per group without materializing repeated heads,
* causal + sliding-window masking short-circuits fully-masked tiles with
  ``pl.when`` (block-level skip ≈ the CUDA early-exit) — causal attention
  does ~half the tile work of the full square.

Numerics: f32 accumulation regardless of input dtype; output cast back.
Validated on CPU in interpret mode against ``ref.attention_ref``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .launch import launch_params

__all__ = ["flash_attention_pallas"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            block_q: int, block_kv: int, seq_kv: int, causal: bool,
            window: int, q_offset: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = q_offset + iq * block_q
    k_start = ik * block_kv
    # Block-level reachability: skip tiles fully above the causal diagonal
    # or fully left of the sliding window.
    reachable = k_start < seq_kv
    if causal:
        reachable &= k_start <= q_start + block_q - 1
    if window:
        reachable &= k_start + block_kv - 1 > q_start - window

    @pl.when(reachable)
    def compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bkv, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # (bkv, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_kv
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,  # (B, Sk, KV, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    dimension_semantics: Optional[str] = None,
    num_warps: Optional[int] = None,  # GPU-lowering hint; inert on TPU
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if H % KV:
        raise ValueError(f"H={H} not a multiple of KV={KV}")
    group = H // KV
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)

    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = q.shape[1] // block_q
    nk = k.shape[1] // block_kv

    kernel = functools.partial(
        _kernel, block_q=block_q, block_kv=block_kv, seq_kv=Sk,
        causal=causal, window=window, q_offset=q_offset,
        scale=1.0 / math.sqrt(D))

    # the kv dim carries the online-softmax scratch; B/H/q-tiles parallel
    params = launch_params(dimension_semantics, 4, 1, interpret)
    del num_warps
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        **({"compiler_params": params} if params else {}),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, iq, ik: (b, ik, h // group, 0)),
            pl.BlockSpec((1, block_kv, 1, D),
                         lambda b, h, iq, ik: (b, ik, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),  # running max m
            pltpu.VMEM((block_q,), jnp.float32),  # running denom l
            pltpu.VMEM((block_q, D), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
