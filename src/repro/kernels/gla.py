"""Chunked gated linear attention (GLA) as a Pallas TPU kernel.

This is the recurrence core shared by Mamba2 (SSD) and xLSTM's mLSTM
(``repro.models.gla`` is the pure-jnp reference implementation used by the
models; ``ref.gla_ref`` is the O(S²) oracle).  TPU adaptation:

* the sequential chunk scan is the *last grid dimension*; the (dk × dv)
  state lives in VMEM scratch and carries across chunk steps — the HBM
  traffic per chunk is exactly q/k/v/g tiles in, y tile out,
* the intra-chunk part is two (L×L)·(L×d) MXU matmuls with a decay mask
  computed from an in-tile cumulative sum — chunk length L is the tiling
  knob that trades VMEM footprint against MXU utilization (ACTS tunes it),
* all gating math is performed as exp(difference-of-cumsums) in f32, so
  sigmoid/softplus log-decays never overflow.

Layout: one grid step owns one (batch, head) pair; heads are independent.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .launch import launch_params

__all__ = ["gla_pallas"]


def _kernel(q_ref, k_ref, v_ref, g_ref, y_ref, state_out_ref, state_ref, *,
            chunk: int, seq: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (L, dk)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (L, dk)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (L, dv)
    g = g_ref[0, :, 0].astype(jnp.float32)  # (L,)

    # mask padding steps: zero decay, zero k contribution
    pos = ic * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
    valid = pos < seq
    g = jnp.where(valid, g, 0.0)
    k = jnp.where(valid[:, None], k, 0.0)

    c = jnp.cumsum(g)  # inclusive (L,)
    state = state_ref[...]  # (dk, dv)

    # inter-chunk: y += exp(c_t) · q_t S_in
    y_inter = jax.lax.dot(q * jnp.exp(c)[:, None], state,
                          preferred_element_type=jnp.float32)
    # intra-chunk: decay matrix exp(c_t − c_s) for s ≤ t
    dmat = c[:, None] - c[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    att = jnp.where(tri, att * jnp.exp(jnp.where(tri, dmat, 0.0)), 0.0)
    y = y_inter + jax.lax.dot(att, v, preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: S = exp(c_L) S + Σ_s exp(c_L − c_s) k_s v_sᵀ
    cL = c[-1]
    k_dec = k * jnp.exp(cL - c)[:, None]
    state_ref[...] = jnp.exp(cL) * state + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ic == nc - 1)
    def finalize():
        state_out_ref[0, 0, :, :] = state_ref[...]


def gla_pallas(
    q: jax.Array,  # (B, S, H, dk)
    k: jax.Array,  # (B, S, H, dk)
    v: jax.Array,  # (B, S, H, dv)
    log_g: jax.Array,  # (B, S, H)
    chunk: int = 128,
    dimension_semantics: Optional[str] = None,
    num_warps: Optional[int] = None,  # GPU-lowering hint; inert on TPU
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y, final_state (B,H,dk,dv) f32). Zero initial state."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        padq = ((0, 0), (0, pad), (0, 0), (0, 0))
        q = jnp.pad(q, padq)
        k = jnp.pad(k, padq)
        v = jnp.pad(v, padq)
        log_g = jnp.pad(log_g, ((0, 0), (0, pad), (0, 0)))
    nc = q.shape[1] // chunk

    # the chunk dim carries the recurrent state scratch; B/H parallel
    params = launch_params(dimension_semantics, 3, 1, interpret)
    del num_warps
    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, seq=S),
        grid=(B, H, nc),
        **({"compiler_params": params} if params else {}),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, dk), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1, dk), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1, dv), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, ic: (b, ic, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, dv), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(v.shape, v.dtype),
            jax.ShapeDtypeStruct((B, H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_g)
    return y[:, :S], state
