"""Shared launch-knob plumbing for the Pallas kernels.

Every kernel space carries two launch knobs (``repro.autotune.space``):

* ``dim_semantics`` — "parallel" marks the embarrassingly-parallel outer
  grid dims for Mosaic (``TPUCompilerParams.dimension_semantics``), which
  lets the two TPU cores split them (megacore); dims that carry VMEM
  scratch across steps (online-softmax/kv, GLA state) stay "arbitrary".
* ``num_warps`` — the GPU-lowering occupancy hint.  Mosaic has no analog,
  so on TPU it is a modelled knob only (the roofline ``_dispatch_s``
  term); kernels accept it for signature parity with a Triton lowering.

``launch_params`` builds the compiler params (or ``None``) so each kernel
declares just its grid shape and how many trailing dims are sequential.
"""
from __future__ import annotations

from typing import Optional

from jax.experimental.pallas import tpu as pltpu

__all__ = ["launch_params"]


def launch_params(dimension_semantics: Optional[str], n_grid_dims: int,
                  n_sequential: int, interpret: bool):
    """``TPUCompilerParams`` for the launch knobs, or ``None``.

    ``n_sequential`` trailing grid dims are always "arbitrary" (they carry
    scratch state); the leading dims become "parallel" when requested.
    Interpret mode takes no compiler params.
    """
    if interpret or dimension_semantics != "parallel":
        return None
    sem = (("parallel",) * (n_grid_dims - n_sequential)
           + ("arbitrary",) * n_sequential)
    return pltpu.TPUCompilerParams(dimension_semantics=sem)
