"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
*body* runs in Python/XLA per grid step, which validates semantics; on a real
TPU the same calls compile through Mosaic.  ``interpret`` is resolved from
the backend unless forced.

Block sizes are no longer hard-coded: when a caller does not pass an
explicit override, the wrapper resolves the tiling through the kernel
autotune cache (``repro.autotune``) keyed by (kernel, problem signature,
dtype, backend), falling back to the builtin defaults below.  Resolution
happens at Python/trace time (block sizes are static arguments), so a
tuned cache entry re-specializes the jitted kernel exactly like passing
the blocks by hand.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax

from .decode_attention import flash_decode_pallas
from .flash_attention import flash_attention_pallas
from .gla import gla_pallas
from .paged_attention import paged_flash_decode_pallas
from .rmsnorm import rmsnorm_pallas

__all__ = ["flash_attention", "flash_decode", "paged_flash_decode",
           "rmsnorm", "gla", "default_interpret", "DEFAULT_BLOCKS"]

# dim_semantics rides with every kernel's resolvable args so a tuned
# winner (block sizes co-selected WITH its grid semantics) deploys as
# measured; num_warps is TPU-inert, so only the paged kernel carries it
# (GPU-lowering signature parity).
DEFAULT_BLOCKS: Dict[str, Dict[str, Any]] = {
    "flash_attention": {"block_q": 128, "block_kv": 128,
                        "dim_semantics": "parallel"},
    "decode_attention": {"block_kv": 256, "dim_semantics": "parallel"},
    # pages_per_block is resolved by the ENGINE when it lays the pool out
    # (the allocator group size IS the kernel tile); the launch knobs are
    # resolved here at call time like any other block arg.
    "paged_attention": {"pages_per_block": 4, "dim_semantics": "parallel",
                        "num_warps": 4},
    "gla": {"chunk": 128, "dim_semantics": "parallel"},
    "rmsnorm": {"block_rows": 256, "dim_semantics": "parallel"},
}


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _resolve(kernel: str, dims: Dict[str, int], dtype: Any,
             overrides: Dict[str, Optional[int]]) -> Dict[str, int]:
    """Explicit override > autotune cache > builtin default, per knob."""
    blocks = dict(DEFAULT_BLOCKS[kernel])
    if any(v is None for v in overrides.values()):
        from repro.autotune import resolve_blocks

        blocks = resolve_blocks(kernel, dims, str(dtype), blocks)
    blocks.update({k: v for k, v in overrides.items() if v is not None})
    return blocks


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_kv",
                                             "dimension_semantics",
                                             "interpret"))
def _flash_attention(q, k, v, *, causal, window, q_offset, block_q, block_kv,
                     dimension_semantics, interpret):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_kv=block_kv,
        dimension_semantics=dimension_semantics, interpret=interpret)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: Optional[int] = None,
                    block_kv: Optional[int] = None,
                    interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    B, S, H, D = q.shape
    # SK (the KV sequence length) must enter the signature: cross-attention
    # and cache-prefill calls share S but differ in k.shape[1], and an
    # SK-less key would collide them onto one cache entry.
    blocks = _resolve(
        "flash_attention",
        {"B": B, "S": S, "SK": k.shape[1], "H": H, "KV": k.shape[2],
         "D": D}, q.dtype,
        {"block_q": block_q, "block_kv": block_kv, "dim_semantics": None})
    return _flash_attention(q, k, v, causal=causal, window=window,
                            q_offset=q_offset, block_q=blocks["block_q"],
                            block_kv=blocks["block_kv"],
                            dimension_semantics=blocks["dim_semantics"],
                            interpret=interp)


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "dimension_semantics",
                                             "interpret"))
def _rmsnorm(x, scale, *, eps, block_rows, dimension_semantics, interpret):
    return rmsnorm_pallas(x, scale, eps=eps, block_rows=block_rows,
                          dimension_semantics=dimension_semantics,
                          interpret=interpret)


def rmsnorm(x, scale, *, eps: float = 1e-6,
            block_rows: Optional[int] = None,
            interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    blocks = _resolve("rmsnorm", {"ROWS": rows, "D": x.shape[-1]}, x.dtype,
                      {"block_rows": block_rows, "dim_semantics": None})
    return _rmsnorm(x, scale, eps=eps, block_rows=blocks["block_rows"],
                    dimension_semantics=blocks["dim_semantics"],
                    interpret=interp)


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("chunk", "dimension_semantics",
                                             "interpret"))
def _gla(q, k, v, log_g, *, chunk, dimension_semantics, interpret):
    return gla_pallas(q, k, v, log_g, chunk=chunk,
                      dimension_semantics=dimension_semantics,
                      interpret=interpret)


def gla(q, k, v, log_g, *, chunk: Optional[int] = None,
        interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    B, S, H, dk = q.shape
    blocks = _resolve("gla",
                      {"B": B, "S": S, "H": H, "DK": dk,
                       "DV": v.shape[-1]}, q.dtype,
                      {"chunk": chunk, "dim_semantics": None})
    return _gla(q, k, v, log_g, chunk=blocks["chunk"],
                dimension_semantics=blocks["dim_semantics"],
                interpret=interp)


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("block_kv",
                                             "dimension_semantics",
                                             "interpret"))
def _flash_decode(q, k, v, kv_len, *, block_kv, dimension_semantics,
                  interpret):
    return flash_decode_pallas(q, k, v, kv_len, block_kv=block_kv,
                               dimension_semantics=dimension_semantics,
                               interpret=interpret)


def flash_decode(q, k, v, kv_len, *, block_kv: Optional[int] = None,
                 interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    B, H, D = q.shape
    blocks = _resolve(
        "decode_attention",
        {"B": B, "S": k.shape[1], "H": H, "KV": k.shape[2], "D": D},
        q.dtype, {"block_kv": block_kv, "dim_semantics": None})
    return _flash_decode(q, k, v, kv_len, block_kv=blocks["block_kv"],
                         dimension_semantics=blocks["dim_semantics"],
                         interpret=interp)


# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("dimension_semantics",
                                             "num_warps", "interpret"))
def _paged_flash_decode(q, k_pages, v_pages, page_table, lengths, *,
                        dimension_semantics, num_warps, interpret):
    return paged_flash_decode_pallas(
        q, k_pages, v_pages, page_table, lengths,
        dimension_semantics=dimension_semantics, num_warps=num_warps,
        interpret=interpret)


def paged_flash_decode(q, k_pages, v_pages, page_table, lengths, *,
                       dimension_semantics: Optional[str] = None,
                       num_warps: Optional[int] = None,
                       interpret: Optional[bool] = None):
    """Paged decode attention over a (groups, tokens, KV, D) pool.

    ``pages_per_block`` is baked into the pool layout by the caller (the
    serve engine sizes its allocator groups from the tuned config); the
    launch knobs resolve through the autotune cache here.  The signature
    is keyed at the pool's *logical* sequence capacity so the engine's
    tuning entry and this consult point agree.
    """
    interp = default_interpret() if interpret is None else interpret
    B, H, D = q.shape
    T, KV = k_pages.shape[1], k_pages.shape[2]
    blocks = _resolve(
        "paged_attention",
        {"B": B, "S": page_table.shape[1] * T, "H": H, "KV": KV, "D": D},
        q.dtype,
        {"pages_per_block": None, "dim_semantics": None,
         "num_warps": num_warps})
    ds = dimension_semantics if dimension_semantics is not None \
        else blocks["dim_semantics"]
    return _paged_flash_decode(
        q, k_pages, v_pages, page_table, lengths,
        dimension_semantics=ds, num_warps=blocks["num_warps"],
        interpret=interp)
