"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode — the kernel
*body* runs in Python/XLA per grid step, which validates semantics; on a real
TPU the same calls compile through Mosaic.  ``interpret`` is resolved from
the backend unless forced.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from .decode_attention import flash_decode_pallas
from .flash_attention import flash_attention_pallas
from .gla import gla_pallas
from .rmsnorm import rmsnorm_pallas

__all__ = ["flash_attention", "flash_decode", "rmsnorm", "gla",
           "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128,
                    block_kv: int = 128,
                    interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_kv=block_kv, interpret=interp)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    return rmsnorm_pallas(x, scale, eps=eps, block_rows=block_rows,
                          interpret=interp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def gla(q, k, v, log_g, *, chunk: int = 128,
        interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    return gla_pallas(q, k, v, log_g, chunk=chunk, interpret=interp)


@functools.partial(jax.jit, static_argnames=("block_kv", "interpret"))
def flash_decode(q, k, v, kv_len, *, block_kv: int = 256,
                 interpret: Optional[bool] = None):
    interp = default_interpret() if interpret is None else interpret
    return flash_decode_pallas(q, k, v, kv_len, block_kv=block_kv,
                               interpret=interp)
