"""Paged flash-decode Pallas kernel: gather K/V through a page table.

The continuous-batching engine stores KV cache in a pool of fixed-size
page *groups* (``repro.serve.paging``); a request's tokens live in
whatever groups the allocator handed it, in logical order given by its
page-table row.  Dense decode attention would first gather the pool into
a contiguous per-slot buffer — an extra O(B·S) HBM round trip per step.
This kernel streams the pool *directly*: the page table rides in as a
scalar-prefetch operand, so each grid step's K/V block is DMA'd straight
from its physical group (``index_map`` reads the page table — the Pallas
TPU idiom for data-dependent addressing).

Layout: grid (B, KV-head, logical-groups); all G query heads of a KV
group processed together as a (G, D) tile (decode_attention's GQA
bandwidth win, unchanged).  Online-softmax state lives in VMEM scratch
across the group dimension; groups past a sequence's valid length are
skipped with ``pl.when`` — a 2k-token request in a 32k-capacity pool
streams 2k tokens, and *only its own* pages.

``pages_per_block`` is structural here: the pool's second axis is
``pages_per_block * PAGE_TOKENS`` tokens, so the tuning knob is applied
where the pool is laid out (engine/allocator) and this kernel simply
tiles one group per grid step.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["POOL_AXES", "paged_flash_decode_pallas", "paged_attention_ref",
           "shardable_kv_heads"]

NEG_INF = -1e30

# Logical sharding axes of the (G, T, KV, D) KV pool this kernel streams.
# The group and token axes are deliberately unsharded: the grid tiles ONE
# physical group per step through the scalar-prefetched page table, so a
# split along groups would scatter a request's logically-contiguous pages
# across devices and break the ``index_map`` addressing.  Only the KV-head
# axis splits (tensor parallelism): each model-axis shard streams its own
# heads over the full pool, and the kernel's (pages_per_block x
# PAGE_TOKENS) group tile stays aligned with the allocator's group size on
# every shard.  ``repro.models.transformer.paged_cache_block_defs`` builds
# pool ParamDefs from this tuple — one source for the kernel/allocator/
# sharding coupling.
POOL_AXES = (None, None, "kv_heads", "head_dim")


def shardable_kv_heads(n_kv_heads: int, model_size: int) -> bool:
    """Whether a ``model_size``-way TP split actually shards the KV pool.

    Mirrors ``spec_for_shape``'s divisibility fallback for the pool's
    ``kv_heads`` axis: when ``n_kv_heads % model_size != 0`` the pool is
    silently *replicated* per device instead — deployable (the kernel
    sees the full head set on every shard) but without the memory win,
    which is why ``serve_feasibility`` surfaces it as a warn-severity
    advisory rather than hard infeasibility.
    """
    m = max(1, int(model_size))
    return m == 1 or int(n_kv_heads) % m == 0


def _kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, group_tokens: int, scale: float):
    b = pl.program_id(0)
    g = pl.program_id(2)
    ng = pl.num_programs(2)
    length = len_ref[b]

    @pl.when(g == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    base = g * group_tokens

    @pl.when(base < length)  # skip groups past the valid length
    def compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * scale  # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (gt, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # (gt, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(g == ng - 1)
    def finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def paged_flash_decode_pallas(
    q: jax.Array,           # (B, H, D) — one new token per sequence
    k_pages: jax.Array,     # (G, T, KV, D) pool; T tokens per group
    v_pages: jax.Array,     # (G, T, KV, D)
    page_table: jax.Array,  # (B, MAXG) int32: logical group -> physical
    lengths: jax.Array,     # (B,) int32: valid tokens per sequence
    *,
    dimension_semantics: Optional[str] = None,  # None|'arbitrary'|'parallel'
    num_warps: Optional[int] = None,  # GPU-lowering hint; inert on TPU
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    G_pool, T, KV, _ = k_pages.shape
    MAXG = page_table.shape[1]
    if H % KV:
        raise ValueError(f"H={H} not a multiple of KV={KV}")
    Gq = H // KV
    qg = q.reshape(B, KV, Gq, D)
    page_table = jnp.asarray(page_table, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)

    # The batch and head grid dims are embarrassingly parallel; the group
    # dim carries the online-softmax scratch and must stay "arbitrary".
    # num_warps is accepted for signature parity with the GPU lowering
    # (where it would reach the Triton compiler); Mosaic has no analog.
    from .launch import launch_params

    params = launch_params(dimension_semantics, 3, 1, interpret)
    del num_warps

    kwargs = {"compiler_params": params} if params else {}
    out = pl.pallas_call(
        functools.partial(_kernel, group_tokens=T,
                          scale=1.0 / math.sqrt(D)),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # page_table, lengths
            grid=(B, KV, MAXG),
            in_specs=[
                pl.BlockSpec((1, 1, Gq, D),
                             lambda b, h, g, pt, ln: (b, h, 0, 0)),
                pl.BlockSpec((1, T, 1, D),
                             lambda b, h, g, pt, ln: (pt[b, g], 0, h, 0)),
                pl.BlockSpec((1, T, 1, D),
                             lambda b, h, g, pt, ln: (pt[b, g], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, Gq, D),
                                   lambda b, h, g, pt, ln: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((Gq,), jnp.float32),
                pltpu.VMEM((Gq,), jnp.float32),
                pltpu.VMEM((Gq, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, Gq, D), q.dtype),
        interpret=interpret,
        **kwargs,
    )(page_table, lengths, qg, k_pages, v_pages)
    return out.reshape(B, H, D)


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths):
    """Pure-jnp oracle: gather the pool into logical order, then masked
    attention.  Also the CPU execution path of the paged serve engine
    (interpret-mode Pallas times the Python emulator, not the TPU)."""
    B, H, D = q.shape
    G_pool, T, KV, _ = k_pages.shape
    k = k_pages[page_table].reshape(B, -1, KV, D).astype(jnp.float32)
    v = v_pages[page_table].reshape(B, -1, KV, D).astype(jnp.float32)
    Gq = H // KV
    qg = q.reshape(B, KV, Gq, D).astype(jnp.float32) / math.sqrt(D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k)
    kpos = jnp.arange(k.shape[1])
    s = jnp.where(kpos[None, None, None, :] < lengths[:, None, None, None],
                  s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v)
    return out.reshape(B, H, D).astype(q.dtype)
