"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "rmsnorm_ref", "gla_ref"]


def attention_ref(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KV, D)
    v: jax.Array,  # (B, Sk, KV, D)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Materialized-logits GQA attention, f32 softmax."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32) / math.sqrt(D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Sq, KV, G, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf)
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, vf)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def gla_ref(
    q: jax.Array,  # (B, S, H, dk)
    k: jax.Array,  # (B, S, H, dk)
    v: jax.Array,  # (B, S, H, dv)
    log_g: jax.Array,  # (B, S, H)  (≤ 0)
    initial_state: Optional[jax.Array] = None,  # (B, H, dk, dv)
) -> Tuple[jax.Array, jax.Array]:
    """O(S²) direct evaluation of gated linear attention:
    y_t = Σ_{s≤t} exp(c_t − c_s) (q_t·k_s) v_s + exp(c_t)·q_tᵀS₀."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    qf, kf, vf = q.astype(f32), k.astype(f32), v.astype(f32)
    c = jnp.cumsum(log_g.astype(f32), axis=1)  # (B,S,H)
    dmat = c[:, :, None, :] - c[:, None, :, :]  # (B,t,s,H)
    tri = jnp.tril(jnp.ones((S, S), bool))
    dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
    att = jnp.einsum("bthd,bshd->btsh", qf, kf) * jnp.exp(dmat)
    y = jnp.einsum("btsh,bshv->bthv", att, vf)
    S0 = (initial_state.astype(f32) if initial_state is not None
          else jnp.zeros((B, H, dk, dv), f32))
    y = y + jnp.einsum("bthd,bhdv->bthv", qf * jnp.exp(c)[..., None], S0)
    cL = c[:, -1, :]
    k_decay = jnp.exp(cL[:, None, :] - c)
    state = jnp.exp(cL)[:, :, None, None] * S0 + jnp.einsum(
        "bshd,bshv->bhdv", kf * k_decay[..., None], vf)
    return y.astype(v.dtype), state
