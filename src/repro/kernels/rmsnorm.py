"""Fused RMSNorm Pallas kernel: one HBM round-trip per row tile.

Unfused, XLA emits square→reduce→rsqrt→mul→mul as separate HBM passes for
large rows; the kernel keeps a (block_rows × d) tile VMEM-resident and does
the whole normalization in registers.  f32 statistics for any input dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .launch import launch_params

__all__ = ["rmsnorm_pallas"]


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,  # (..., d)
    scale: jax.Array,  # (d,)
    eps: float = 1e-6,
    block_rows: int = 256,
    dimension_semantics: Optional[str] = None,
    num_warps: Optional[int] = None,  # GPU-lowering hint; inert on TPU
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n = x2.shape[0] // block_rows

    # row tiles are fully independent: the whole grid may parallelize
    params = launch_params(dimension_semantics, 1, 0, interpret)
    del num_warps
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n,),
        **({"compiler_params": params} if params else {}),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out[:rows].reshape(orig_shape)
