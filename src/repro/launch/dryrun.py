import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init).  This module is the ONLY place the 512 placeholder
# devices exist; smoke tests and benches see 1 device.
"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
  * build the production mesh (16×16 single pod / 2×16×16 multi-pod),
  * construct abstract params / optimizer state / batch / KV-cache
    (ShapeDtypeStruct stand-ins — no allocation),
  * ``jax.jit(step, in_shardings=…, out_shardings=…).lower(...).compile()``,
  * record ``memory_analysis()`` (fits-on-chip proof), ``cost_analysis()``
    (FLOPs/bytes for §Roofline) and the per-device collective traffic parsed
    from the post-SPMD HLO.

Results append to a JSONL file consumed by ``repro.launch.roofline``.

Usage:
  python -m repro.launch.dryrun                       # all cells, both meshes
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --knob remat=none --knob rules_preset=tp --tag x
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import (
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeSpec,
    get_config,
    shape_applicable,
)
from repro.data import batch_specs
from repro.dist.sharding import axis_rules, spec_for_shape
from repro.launch.mesh import describe_mesh, make_production_mesh
from repro.models import Model
from repro.models.common import abstract_params, param_specs
from repro.optim import OptimizerConfig, opt_state_defs
from repro.train.step import RunKnobs, make_serve_step, make_train_step
from repro.utils.hlo import count_ops, parse_collectives
from repro.utils.hlo_cost import analyze_hlo

__all__ = ["input_specs", "run_cell", "main"]


def _spec_to_sharding(tree_specs, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, PartitionSpec))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    frontend = None
    if cfg.frontend or cfg.encoder:
        frontend = (cfg.frontend_tokens, cfg.frontend_dim)
    if shape.kind in ("train", "prefill"):
        return batch_specs(cfg.vocab_size, shape.seq_len, shape.global_batch,
                           frontend=frontend)
    # decode: one new token against a seq_len KV cache
    return {
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
    }


def _batch_sharding(specs: Dict[str, Any], rules, mesh):
    out = {}
    for k, v in specs.items():
        axes = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, spec_for_shape(v.shape, axes, rules, mesh))
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             knobs: RunKnobs = RunKnobs(),
             opt_cfg: OptimizerConfig = OptimizerConfig(),
             verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one cell; returns the roofline-input record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cfg_updates: Dict[str, Any] = {}
    if knobs.attn_impl:
        cfg_updates["attn_impl"] = knobs.attn_impl
    # explicit knob > autotune cache (if enabled) > ModelConfig default
    bq, bkv = knobs.resolved_attn_blocks(cfg, shape.seq_len)
    if bq != cfg.attn_block_q:
        cfg_updates["attn_block_q"] = bq
    if bkv != cfg.attn_block_kv:
        cfg_updates["attn_block_kv"] = bkv
    if knobs.pad_heads:
        cfg_updates["pad_heads_to_multiple"] = 16
    if cfg_updates:
        cfg = dataclasses.replace(cfg, **cfg_updates)
    ok, reason = shape_applicable(cfg, shape)
    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "knobs": dataclasses.asdict(knobs),
        "time": time.time(),
    }
    if not ok:
        record.update(status="skipped", reason=reason)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rules = knobs.axis_rules()
    model = Model(cfg)
    t0 = time.time()

    with axis_rules(rules, mesh):
        p_abs = model.abstract_params()
        p_shard = _spec_to_sharding(model.param_specs(rules, mesh), mesh)
        if shape.kind == "train":
            o_defs = opt_state_defs(model.param_defs())
            o_abs = abstract_params(o_defs)
            o_shard = _spec_to_sharding(param_specs(o_defs, rules, mesh), mesh)
            b_specs = input_specs(cfg, shape)
            b_shard = _batch_sharding(b_specs, rules, mesh)
            step = make_train_step(model, opt_cfg, knobs)
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1) if knobs.donate else (),
            )
            lowered = jitted.lower(p_abs, o_abs, b_specs)
        elif shape.kind == "prefill":
            b_specs = input_specs(cfg, shape)
            b_shard = _batch_sharding(b_specs, rules, mesh)
            c_defs = model.cache_defs(shape.global_batch, shape.seq_len)
            c_abs = abstract_params(c_defs)
            c_shard = _spec_to_sharding(param_specs(c_defs, rules, mesh), mesh)

            def prefill_step(params, batch, cache):
                return model.prefill(params, batch, cache)

            jitted = jax.jit(
                prefill_step,
                in_shardings=(p_shard, b_shard, c_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(2,) if knobs.donate else (),
            )
            lowered = jitted.lower(p_abs, b_specs, c_abs)
        else:  # decode
            t_specs = input_specs(cfg, shape)
            t_shard = _batch_sharding(t_specs, rules, mesh)
            c_defs = model.cache_defs(shape.global_batch, shape.seq_len)
            c_abs = abstract_params(c_defs)
            c_shard = _spec_to_sharding(param_specs(c_defs, rules, mesh), mesh)
            serve_step = make_serve_step(model)
            jitted = jax.jit(
                serve_step,
                in_shardings=(p_shard, c_shard, t_shard["tokens"]),
                out_shardings=(None, c_shard),
                donate_argnums=(1,) if knobs.donate else (),
            )
            lowered = jitted.lower(p_abs, c_abs, t_specs["tokens"])

        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    # ---- analyses --------------------------------------------------------
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    mem_per_device = None
    mem_details: Dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                         "output_size_in_bytes", "alias_size_in_bytes",
                         "generated_code_size_in_bytes"):
                if hasattr(ma, attr):
                    mem_details[attr] = float(getattr(ma, attr))
            mem_per_device = (
                mem_details.get("temp_size_in_bytes", 0.0)
                + mem_details.get("argument_size_in_bytes", 0.0)
                + mem_details.get("output_size_in_bytes", 0.0)
                - mem_details.get("alias_size_in_bytes", 0.0)
            )
    except Exception as e:  # CPU backend may not implement it
        mem_details["error"] = str(e)

    hlo = compiled.as_text()
    ops = count_ops(hlo)
    # trip-count-aware static analysis (XLA's cost_analysis counts while
    # bodies once — useless for scan-over-layers programs; see hlo_cost.py)
    st = analyze_hlo(hlo)

    record.update(
        status="ok",
        n_chips=int(n_chips),
        lower_seconds=t_lower,
        compile_seconds=t_compile,
        flops_per_device=st.flops,
        bytes_per_device=st.mem_bytes,
        boundary_bytes_per_device=st.bytes_accessed,
        collective_bytes_per_device=float(st.collective_bytes),
        collectives={k: dict(v) for k, v in st.collectives.items()},
        n_while=st.n_while,
        trip_counts=st.trip_counts,
        unresolved_trips=st.unresolved_trips,
        xla_flops_per_device=float(cost.get("flops", -1.0)),
        xla_bytes_per_device=float(cost.get("bytes accessed", -1.0)),
        hlo_ops=ops,
        memory_per_device_bytes=mem_per_device,
        memory_details=mem_details,
        hlo_chars=len(hlo),
    )
    if verbose:
        colls = ", ".join(
            f"{k}×{int(v['count'])} ({v['bytes'] / 2**20:.0f}MiB)"
            for k, v in sorted(st.collectives.items()))
        print(f"[dryrun] {arch} × {shape_name} × {record['mesh']}: "
              f"compile {t_compile:.1f}s, "
              f"flops/dev {st.flops:.3g}, "
              f"bytes/dev {st.bytes_accessed:.3g}, "
              f"mem/dev {0 if mem_per_device is None else mem_per_device / 2**30:.2f} GiB")
        print(f"  collectives/dev: {colls or 'none'}")
        print(f"  memory_analysis: {mem_details}")
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", action="append", choices=ARCH_IDS)
    ap.add_argument("--shape", action="append", choices=sorted(SHAPES))
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="both")
    ap.add_argument("--knob", action="append", default=[],
                    help="RunKnobs override, e.g. --knob remat=none")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    knob_kwargs: Dict[str, Any] = {}
    for kv in args.knob:
        k, v = kv.split("=", 1)
        field_types = {f.name: f.type for f in dataclasses.fields(RunKnobs)}
        if k not in field_types:
            raise SystemExit(f"unknown knob {k!r}")
        cur = getattr(RunKnobs(), k)
        if isinstance(cur, bool):
            knob_kwargs[k] = v.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            knob_kwargs[k] = int(v)
        elif cur is None:
            knob_kwargs[k] = v
        else:
            knob_kwargs[k] = type(cur)(v)
    knobs = RunKnobs(**knob_kwargs)

    archs = args.arch or ARCH_IDS
    shapes = args.shape or list(SHAPES)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[
        args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"], r.get("tag")))
                except json.JSONDecodeError:
                    pass

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi_pod in meshes:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                key = (arch, shape, mesh_name, args.tag)
                if key in done:
                    print(f"[dryrun] skip existing {key}")
                    continue
                try:
                    rec = run_cell(arch, shape, multi_pod=multi_pod,
                                   knobs=knobs)
                except Exception:
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_name,
                        "status": "error",
                        "error": traceback.format_exc(limit=20),
                    }
                    failures += 1
                    print(f"[dryrun] ERROR {arch} × {shape} × {mesh_name}:")
                    print(rec["error"])
                rec["tag"] = args.tag
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
    print(f"[dryrun] complete; {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
