"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before *any* jax
initialization, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "describe_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment: one v5e pod (16x16) or two pods (2x16x16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pod: int = 1):
    """An arbitrary (pod×)data×model mesh — used by ACTS mesh-factorization
    knobs and by CPU-scale tests (e.g. 2x2 over 4 host devices)."""
    if pod > 1:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def describe_mesh(mesh) -> str:
    return "x".join(f"{k}={v}" for k, v in mesh.shape.items())
