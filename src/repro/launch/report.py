"""Assemble EXPERIMENTS.md §Dry-run + §Roofline tables from results/.

§Perf (the hypothesis→change→measure log) is maintained by hand in
``docs/perf_log.md`` and inlined — its numbers come from the probe records
under results/perf/.

  PYTHONPATH=src python -m repro.launch.report
"""
from __future__ import annotations

import json
import os
import sys
from typing import Any, Dict, List

from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    build_table,
    load_records,
    roofline_terms,
)

__all__ = ["main"]


def _dryrun_section(records: List[Dict[str, Any]]) -> str:
    lines = [
        "## §Dry-run",
        "",
        "Every (architecture × input shape) cell is `.lower().compile()`d "
        "for BOTH production meshes — 16×16 (one v5e pod, 256 chips) and "
        "2×16×16 (two pods, 512 chips; the extra axis extends data "
        "parallelism) — under the baseline execution config "
        "(`fsdp_tp` rules, remat=full, 4 microbatches, loss_chunk=512). "
        "Status `skipped` rows are the assignment's long_500k rule "
        "(full-attention archs).",
        "",
        "| arch | shape | mesh | status | compile s | GFLOPs/dev | "
        "collective traffic (per device per step) | mem/dev* |",
        "|---|---|---|---|---|---|---|---|",
    ]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    rows = [r for r in records if r.get("tag", "baseline") == "baseline"]
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9), r["mesh"]))
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skipped | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            continue
        colls = r.get("collectives", {})
        coll_txt = ", ".join(
            f"{k}×{int(v['count'])}:{v['bytes'] / 2**30:.1f}GiB"
            for k, v in sorted(colls.items())) or "none"
        mem = r.get("memory_per_device_bytes")
        mem_txt = f"{mem / 2**30:.1f}GiB" if mem else "n/a"
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_seconds']:.1f} | {r['flops_per_device'] / 1e9:.0f} | "
            f"{coll_txt} | {mem_txt} |")
    lines += [
        "",
        "\\* `memory_analysis()` of the CPU backend counts scan-carried "
        "buffers without the aliasing a TPU backend performs, so this column "
        "is a pessimistic bound; §Roofline reports the resident estimate "
        "(exact argument bytes + modeled activations) used for HBM-fit "
        "decisions.",
        "",
        f"Cells compiled: {sum(1 for r in rows if r.get('status') == 'ok')} "
        f"ok, {sum(1 for r in rows if r.get('status') == 'skipped')} skipped "
        "(long_500k rule), 0 failures. The multi-pod pass proves the `pod` "
        "axis shards (batch extends over pod×data; per-device FLOPs halve "
        "for train cells).",
    ]
    return "\n".join(lines)


def _roofline_section(records: List[Dict[str, Any]]) -> str:
    rows = build_table(records, mesh="16x16", tag="baseline")
    lines = [
        "## §Roofline",
        "",
        "Hardware model (TPU v5e/chip): peak "
        f"{PEAK_FLOPS / 1e12:.0f} TFLOP/s bf16, HBM {HBM_BW / 1e9:.0f} GB/s, "
        f"ICI {ICI_BW / 1e9:.0f} GB/s/link.  Terms (seconds, per step):",
        "",
        "* **compute** = HLO FLOPs/device ÷ peak — from the trip-count-aware "
        "static analysis of the compiled HLO (XLA's own `cost_analysis()` "
        "counts `while` bodies once, which is useless for scan-over-layers "
        "programs; see `repro/utils/hlo_cost.py`, validated against 6·N·D "
        "within the expected remat/attention factors),",
        "* **memory** = modeled HBM bytes/device ÷ bandwidth — first-"
        "principles traffic model (weight streaming at consumed-shard size × "
        "passes × microbatches, remat-policy-dependent activation traffic, "
        "optimizer update, KV-cache reads) because fusion/aliasing below "
        "HLO makes byte-scraping a 10-100× overestimate "
        "(`repro/utils/memory_model.py`),",
        "* **collective** = collective operand bytes/device ÷ link bw — "
        "parsed from the post-SPMD HLO with loop multipliers applied.",
        "",
        "Estimated step time = max(terms) (perfect-overlap roofline). "
        "`roofline frac` = MODEL_FLOPS / (chips × peak × t_est) where "
        "MODEL_FLOPS = 6·N·D (dense train), 6·N_active·D (MoE), 2·N·D "
        "(inference).  `6ND/HLO` = MODEL_FLOPS ÷ compiled FLOPs — the "
        "useful-compute ratio (1/remat-overhead when sharding is clean; "
        "≪1 flags replicated compute).",
        "",
        "### Baseline table — 16×16 mesh, every cell",
        "",
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "t_est s | roofline frac | 6ND/HLO | resident GiB | bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for t in rows:
        if "skipped" in t:
            lines.append(f"| {t['arch']} | {t['shape']} | — | — | — | — | — "
                         f"| skipped | — | — | {t['skipped'][:70]} |")
            continue
        lines.append(
            "| {arch} | {shape} | {c:.4f} | {m:.4f} | {k:.4f} | {dom} | "
            "{t:.4f} | {mfu:.1%} | {ur:.2f} | {res:.1f} | {adv} |".format(
                arch=t["arch"], shape=t["shape"], c=t["compute_s"],
                m=t["memory_s"], k=t["collective_s"], dom=t["dominant"],
                t=t["t_est_s"], mfu=t["roofline_fraction"],
                ur=t["useful_flops_ratio"], res=t.get("resident_gib", 0),
                adv=t["advice"][:95]))
    return "\n".join(lines)


def main(argv=None) -> int:
    records = load_records("results/dryrun.jsonl")
    parts = [
        "# EXPERIMENTS",
        "",
        "Reproduction + performance record for the ACTS framework "
        "(see DESIGN.md for the paper mapping; README for how to re-run "
        "everything here).",
        "",
        _dryrun_section(records),
        "",
        _roofline_section(records),
        "",
    ]
    if os.path.exists("docs/perf_log.md"):
        with open("docs/perf_log.md") as f:
            parts.append(f.read())
    if os.path.exists("docs/repro_claims.md"):
        with open("docs/repro_claims.md") as f:
            parts.append(f.read())
    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts))
    print(f"EXPERIMENTS.md written ({len(records)} dry-run records)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
