"""Roofline analysis (deliverable g) over the dry-run records.

Hardware model (TPU v5e per chip):
    peak bf16 compute  197 TFLOP/s
    HBM bandwidth      819 GB/s
    ICI link bandwidth ~50 GB/s per link

Terms (per cell, in seconds; all inputs are *per-device* quantities from the
trip-count-aware HLO analysis, which equals the global quantity divided by
the chip count for SPMD programs):

    compute    = HLO_FLOPs_per_device / peak
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

The estimated step time is the max of the three (perfect-overlap roofline);
the dominant term is the bottleneck the §Perf loop iterates on.  MFU-style
"roofline fraction" = MODEL_FLOPS / (chips × peak × est_step_time).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

__all__ = ["roofline_terms", "load_records", "build_table", "main"]


def roofline_terms(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rec.get("status") != "ok":
        return None
    from repro.configs import SHAPES, get_config
    from repro.train.step import RunKnobs
    from repro.utils.flops import model_flops
    from repro.utils.memory_model import analytic_memory_bytes

    knob_fields = {f.name for f in __import__("dataclasses").fields(RunKnobs)}
    knobs = RunKnobs(**{k: v for k, v in rec.get("knobs", {}).items()
                        if k in knob_fields})
    mesh_shape = ({"pod": 2, "data": 16, "model": 16}
                  if rec["mesh"] == "2x16x16" else {"data": 16, "model": 16})
    mem = analytic_memory_bytes(
        get_config(rec["arch"]), SHAPES[rec["shape"]],
        rules=knobs.axis_rules(), mesh_shape=mesh_shape,
        remat=knobs.remat, microbatches=knobs.microbatches)

    compute_s = rec["flops_per_device"] / PEAK_FLOPS
    memory_s = mem["total"] / HBM_BW
    coll_s = rec["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    t_est = max(terms.values())
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    mf = model_flops(cfg, shape)
    chips = rec["n_chips"]
    hlo_total = rec["flops_per_device"] * chips
    useful_ratio = mf / hlo_total if hlo_total > 0 else float("nan")
    mfu = mf / (chips * PEAK_FLOPS * t_est) if t_est > 0 else float("nan")
    mem_gib = rec.get("memory_per_device_bytes")
    # Resident estimate: exact per-device argument bytes (weights/opt/cache,
    # from XLA) + modeled activation residency.  The CPU backend's
    # temp_size double-counts scan carries it would alias on TPU, so the raw
    # memory_analysis is kept as a pessimistic bound alongside this.
    args_b = rec.get("memory_details", {}).get("argument_size_in_bytes", 0.0)
    act_b = mem.get("activations", 0.0)
    if SHAPES[rec["shape"]].kind == "train":
        act_b = act_b / max(knobs.microbatches, 1) + mem.get("logits", 0.0) / 8
    resident_gib = (args_b + act_b) / 2**30
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "tag": rec.get("tag", "baseline"),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "t_est_s": t_est,
        "model_flops": mf,
        "hlo_flops": hlo_total,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": mfu,
        "memory_breakdown_gib": {k: v / 2**30 for k, v in mem.items()},
        "hlo_boundary_bytes_s": rec.get("boundary_bytes_per_device",
                                        rec.get("bytes_per_device", 0))
        / HBM_BW,
        "mem_gib_per_device": (mem_gib / 2**30) if mem_gib else None,
        "resident_gib": resident_gib,
        "fits_hbm": resident_gib <= 16.0,
        "advice": _advice(dominant, rec),
    }


def _advice(dominant: str, rec: Dict[str, Any]) -> str:
    arch, shape = rec["arch"], rec["shape"]
    colls = rec.get("collectives", {})
    big_coll = max(colls, key=lambda k: colls[k]["bytes"]) if colls else None
    if dominant == "collective":
        if big_coll == "all-reduce":
            return ("dominant all-reduce is TP activation reduction — move to "
                    "sequence-parallel reduce-scatter/all-gather or shrink the "
                    "TP extent in favour of DP")
        return (f"dominant {big_coll}: reshard so the hot tensor stays local "
                "(different axis mapping) or overlap with compute")
    if dominant == "memory":
        if rec["kind"] == "decode":
            return ("decode is weight/cache-streaming bound — shard the KV "
                    "cache along sequence (kv_seq->model), quantize it, or "
                    "raise arithmetic intensity with larger decode batches")
        return ("reduce activation traffic: lighter remat policy, fused "
                "kernels (flash attention / fused rmsnorm), bigger microbatch")
    return ("compute-bound — reduce recompute (remat policy), skip masked "
            "attention tiles (Pallas causal kernel), or accept (good place "
            "to be)")


def load_records(path: str) -> List[Dict[str, Any]]:
    out = []
    with open(path) as f:
        for line in f:
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return out


def build_table(records: List[Dict[str, Any]], mesh: str = "16x16",
                tag: str = "baseline") -> List[Dict[str, Any]]:
    rows = []
    for rec in records:
        if rec.get("mesh") != mesh or rec.get("tag", "baseline") != tag:
            continue
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "skipped": rec["reason"]})
            continue
        t = roofline_terms(rec)
        if t:
            rows.append(t)
    return rows


def format_markdown(rows: List[Dict[str, Any]]) -> str:
    def fmt(r):
        if "skipped" in r:
            return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped |"
                    f" {r['skipped'][:60]}… |")
        return ("| {arch} | {shape} | {c:.4f} | {m:.4f} | {k:.4f} | "
                "{dom} | {mfu:.1%} | {ur:.2f} |").format(
            arch=r["arch"], shape=r["shape"], c=r["compute_s"],
            m=r["memory_s"], k=r["collective_s"], dom=r["dominant"],
            mfu=r["roofline_fraction"], ur=r["useful_flops_ratio"])

    header = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
              "dominant | roofline frac | 6ND/HLO |\n"
              "|---|---|---|---|---|---|---|---|")
    return header + "\n" + "\n".join(fmt(r) for r in rows)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun.jsonl")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args(argv)
    records = load_records(args.inp)
    rows = build_table(records, mesh=args.mesh, tag=args.tag)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)
    print(format_markdown(rows))
    return 0


if __name__ == "__main__":
    sys.exit(main())
