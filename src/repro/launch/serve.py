"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the batched prefill+decode engine on this host (reduced configs by
default).  This is the interactive counterpart of the decode dry-run cells.
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine

__all__ = ["main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, ServeConfig(
        max_seq=args.prompt_len + args.max_new + 8,
        batch_slots=args.batch_slots, temperature=args.temperature,
        seed=args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(1, cfg.vocab_size,
                           size=(args.requests, args.prompt_len)).tolist()
    fe = None
    if cfg.frontend or cfg.encoder:
        fe = rng.normal(size=(args.requests, cfg.frontend_tokens,
                              cfg.frontend_dim)).astype(np.float32)
    res = engine.generate(prompts, max_new_tokens=args.max_new,
                          frontend_embeds=fe)
    print(f"{cfg.name}: {args.requests} requests, "
          f"prefill {res.prefill_seconds:.2f}s, "
          f"decode {res.decode_seconds:.2f}s "
          f"({res.decode_tokens_per_sec:.1f} tok/s)")
    for i, toks in enumerate(res.tokens[:3]):
        print(f"  req {i}: {toks[:16]}{'...' if len(toks) > 16 else ''}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
