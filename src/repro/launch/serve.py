"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the serve engine on this host (reduced configs by default) under
either runtime: the continuous-batching scheduler (default; mixed prompt
and generation lengths via ``--mixed``, tuned ``--schedule`` acting at
admission time, ``--kv-layout paged`` for the real page allocator) or the
legacy equal-length wave loop (``--runtime wave``).  ``--mesh DxM`` runs
the engine sharded over a (data, model) device grid — data-axis replicas
widen slot capacity, model-axis tensor parallelism splits heads/ff — on
CPU hosts the requested device count is faked via XLA host devices, so
the sharded paths exercise end-to-end without an accelerator.  This is
the interactive counterpart of the decode dry-run cells.
"""
from __future__ import annotations

import argparse
import os
import sys


def _mesh_argv(argv):
    """The ``--mesh`` value from a raw argv, pre-argparse.

    Needed before ``import jax``: on CPU hosts a multi-device mesh only
    exists if ``XLA_FLAGS`` fakes the host devices, and that flag is
    read once at backend init.
    """
    for i, a in enumerate(argv):
        if a == "--mesh" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--mesh="):
            return a.split("=", 1)[1]
    return None


def _parse_mesh(s):
    try:
        data, model = (int(x) for x in s.lower().split("x"))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"mesh must be DATAxMODEL (e.g. 1x2, 2x4); got {s!r}")
    if data < 1 or model < 1:
        raise argparse.ArgumentTypeError(f"mesh axes must be >= 1: {s!r}")
    return (data, model)


_mesh = _mesh_argv(sys.argv)
if _mesh:
    try:
        _d, _m = (int(x) for x in _mesh.lower().split("x"))
    except ValueError:
        _d = _m = 1  # argparse reports the malformed value later
    if _d * _m > 1:
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={_d * _m}")

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import Model
from repro.serve import ServeConfig, ServeEngine
from repro.serve.scheduler import PAGE_POLICIES, SCHEDULES, TP_MODES

__all__ = ["main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--runtime", choices=("continuous", "wave"),
                    default="continuous")
    ap.add_argument("--kv-layout", choices=("dense", "paged"),
                    default="dense")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="KV pool size in 16-token pages (paged layout: "
                         "bounds how many requests stay resident)")
    ap.add_argument("--schedule", choices=SCHEDULES, default="fifo")
    ap.add_argument("--page-policy", choices=PAGE_POLICIES,
                    default="reserve",
                    help="paged-layout KV reservation policy: worst-case "
                         "up-front (reserve) or prompt-only + on-demand "
                         "growth with recompute preemption (on_demand)")
    ap.add_argument("--prefill-chunk", type=int, default=512)
    ap.add_argument("--mesh", type=_parse_mesh, default=None,
                    metavar="DATAxMODEL",
                    help="run sharded over a (data, model) device grid, "
                         "e.g. 2x1 (two replicated engines), 1x2 (one "
                         "2-way tensor-parallel engine), 2x4; CPU hosts "
                         "fake the devices via XLA_FLAGS automatically")
    ap.add_argument("--rules-preset", choices=("serve_tp", "serve_replicas"),
                    default="serve_tp",
                    help="logical-axis sharding rules for the mesh "
                         "(serve_tp also covers pure-replica meshes: its "
                         "size-1 model axis drops out)")
    ap.add_argument("--tp-vs-replicas", choices=TP_MODES, default="tp",
                    help="how a flat tuned device count would map onto "
                         "the mesh (recorded on the config; --mesh fixes "
                         "the grid explicitly)")
    ap.add_argument("--mixed", action="store_true",
                    help="mixed-length workload: prompt lengths in "
                         "[2, prompt-len], generation lengths in "
                         "[1, max-new] (continuous runtime only)")
    ap.add_argument("--retune", action="store_true",
                    help="online workload-aware retuning: fingerprint the "
                         "live request window, detect drift from the "
                         "deployed knobs' tuned signature and swap in a "
                         "warm-started retune mid-run (continuous "
                         "runtime; see repro.serve.workload)")
    ap.add_argument("--retune-threshold", type=float, default=0.25,
                    help="fingerprint distance that triggers a retune")
    ap.add_argument("--retune-budget", type=int, default=16,
                    help="surrogate tests per retune")
    ap.add_argument("--drift", action="store_true",
                    help="with --mixed: the second half of the requests "
                         "shifts to short-tail shared-prefix prompts, so "
                         "--retune has a drift to catch")
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, ServeConfig(
        max_seq=args.prompt_len + args.max_new + 8,
        batch_slots=args.batch_slots, temperature=args.temperature,
        seed=args.seed, runtime=args.runtime, kv_layout=args.kv_layout,
        kv_cache_pages=args.kv_pages, schedule=args.schedule,
        page_policy=args.page_policy, prefill_chunk=args.prefill_chunk,
        retune=args.retune, retune_threshold=args.retune_threshold,
        retune_budget=args.retune_budget, mesh_shape=args.mesh,
        rules_preset=args.rules_preset,
        tp_vs_replicas=args.tp_vs_replicas))
    rng = np.random.default_rng(args.seed)
    if args.mixed and engine._continuous:
        plens = rng.integers(2, args.prompt_len + 1, size=args.requests)
        prompts = [rng.integers(1, cfg.vocab_size, size=n).tolist()
                   for n in plens]
        max_new = [int(m) for m in
                   rng.integers(1, args.max_new + 1, size=args.requests)]
        if args.drift:
            # second half: shared-prefix short-tail requests — a
            # workload shift the retuner's fingerprint can see
            half = args.requests // 2
            head = rng.integers(1, cfg.vocab_size,
                                size=max(2, args.prompt_len - 2)).tolist()
            for i in range(half, args.requests):
                prompts[i] = head + rng.integers(
                    1, cfg.vocab_size, size=2).tolist()
                max_new[i] = max(1, args.max_new // 4)
    else:
        prompts = rng.integers(1, cfg.vocab_size,
                               size=(args.requests,
                                     args.prompt_len)).tolist()
        max_new = args.max_new
    fe = None
    if cfg.frontend or cfg.encoder:
        fe = rng.normal(size=(args.requests, cfg.frontend_tokens,
                              cfg.frontend_dim)).astype(np.float32)
    res = engine.generate(prompts, max_new, frontend_embeds=fe)
    mode = f"{args.runtime}/{args.kv_layout}/{args.schedule}" \
        if engine._continuous else "wave"
    if engine.mesh is not None:
        d, m = engine.mesh_shape
        mode += f"/mesh{d}x{m}({args.rules_preset})"
    print(f"{cfg.name} [{mode}]: {args.requests} requests, "
          f"prefill {res.prefill_seconds:.2f}s, "
          f"decode {res.decode_seconds:.2f}s "
          f"({res.decode_tokens_per_sec:.1f} tok/s, {res.steps} steps, "
          f"p50 {res.p50_latency_s:.3f}s, p95 {res.p95_latency_s:.3f}s)")
    if getattr(engine, "last_alloc", None) is not None:
        a = engine.last_alloc
        print(f"  kv pool: {a.n_groups} groups x {a.group_tokens} tokens, "
              f"high water {a.high_water} groups "
              f"[{args.page_policy}, {res.preemptions} preemptions]")
    if args.retune:
        if not res.retunes:
            print("  retune: no workload shift detected")
        for ev in res.retunes:
            moved = ", ".join(f"{k} {old}->{new}"
                              for k, (old, new) in ev["applied"].items()) \
                or "no knob moved"
            print(f"  retune @step {ev['step']}: drift {ev['distance']:.2f}"
                  f" [{ev['warm_source']}] -> {moved} "
                  f"(accept {ev['measured_accept']:.2f})")
    for i, toks in enumerate(res.tokens[:3]):
        print(f"  req {i}: {toks[:16]}{'...' if len(toks) > 16 else ''}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
