"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant training loop on this host.  Assigned architectures
run at their REDUCED config by default (full configs belong on the pod; use
``--full`` to try anyway).  Execution knobs mirror ``RunKnobs``.
"""
from __future__ import annotations

import argparse
import sys

from repro.configs import ARCH_IDS, get_config, reduced
from repro.optim import OptimizerConfig
from repro.train import RunKnobs, TrainLoopConfig, train

__all__ = ["main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (pod-scale!)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--remat", default="none",
                    choices=("none", "dots", "full"))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=("none", "int8", "topk"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    loop = TrainLoopConfig(
        steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, seed=args.seed, log_every=10,
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        opt=OptimizerConfig(learning_rate=args.lr, warmup_steps=10,
                            total_steps=args.steps),
        knobs=RunKnobs(rules_preset="dp", remat=args.remat,
                       microbatches=args.microbatches, loss_chunk=0,
                       compression=args.compression),
    )
    out = train(cfg, loop)
    h = out["history"]
    print(f"\n{cfg.name}: loss {h[0]['loss']:.4f} -> {h[-1]['loss']:.4f} "
          f"in {out['final_step']} steps ({out['wall_seconds']:.0f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
