import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# Must precede any jax import: the tuner compiles against the production mesh.
"""ACTS over the JAX runtime (the paper's technique applied to this system).

Three modes:

* ``--probe knob=v[,knob=v...]`` — one manual hypothesis test: compile the
  cell under the given knobs, print the roofline terms (the
  hypothesis→change→measure loop of EXPERIMENTS.md §Perf).
* ``--tune-kernels`` — ACTS over the *Pallas kernels* of the given cell:
  tune block configs for the cell's attention/rmsnorm shapes and persist
  them in the autotune cache, which later runs (``--kernel-autotune``,
  the serve engine, and bare ``repro.kernels.ops`` calls) consult.
* default — full ACTS run: LHS + RRS over the knob space within ``--budget``
  tests (each test = one AOT compile of the real system on the production
  mesh), reporting default vs. best and writing the full history.

Examples:
  python -m repro.launch.tune --arch qwen2.5-32b --shape train_4k --budget 24
  python -m repro.launch.tune --arch qwen2.5-32b --shape train_4k \
      --tune-kernels
  python -m repro.launch.tune --arch grok-1-314b --shape train_4k \
      --probe expert_tp=true,rules_preset=dp
"""
import argparse
import json
import sys
import time

from repro.configs import ARCH_IDS, SHAPES

__all__ = ["main"]


def _parse_value(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    try:
        return int(v)
    except ValueError:
        return v


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--budget", type=int, default=24)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--optimizer", default="rrs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--probe", default=None,
                    help="knob=v[,knob=v...]: single manual hypothesis test")
    ap.add_argument("--tune-kernels", action="store_true",
                    help="ACTS over the cell's Pallas kernel block configs; "
                         "winners persist in the autotune cache")
    ap.add_argument("--kernel-budget", type=int, default=16)
    ap.add_argument("--out-dir", default="results/tune")
    args = ap.parse_args(argv)

    from repro.core.sut_jax import JaxDryRunSUT, knob_space
    from repro.core.tuner import Tuner

    kind = SHAPES[args.shape].kind

    if args.tune_kernels:
        from repro import autotune
        from repro.configs import get_config

        cfg = get_config(args.arch)
        shape = SHAPES[args.shape]
        attn_dims = {"B": 1, "S": shape.seq_len, "H": cfg.padded_heads,
                     "KV": cfg.n_kv_heads, "D": cfg.head_dim_}
        rn_dims = {"ROWS": shape.seq_len, "D": cfg.d_model}
        results = []
        for kernel, dims in (("flash_attention", attn_dims),
                             ("decode_attention", attn_dims),
                             ("rmsnorm", rn_dims)):
            res = autotune.autotune_kernel(kernel, dims,
                                           dtype=cfg.compute_dtype,
                                           budget=args.kernel_budget,
                                           seed=args.seed)
            results.append(res)
            print(f"[autotune] {kernel} {res['sig']}: {res['config']} "
                  f"({res['mode']}, {res['n_tests']} tests, "
                  f"value {res['value']:.3g})")
        print(json.dumps({"cache": autotune.default_cache().path,
                          "entries": results}, indent=2))
        return 0
    sut = JaxDryRunSUT(args.arch, args.shape, multi_pod=args.multi_pod,
                       verbose=True)
    space = knob_space(kind)

    if args.probe is not None:
        config = space.default_config()
        if args.probe:
            for kv in args.probe.split(","):
                k, v = kv.split("=", 1)
                config[k] = _parse_value(v)
        space.validate(config)
        t0 = time.time()
        metric = sut.test(config)
        print(json.dumps({
            "arch": args.arch, "shape": args.shape,
            "config": {k: config[k] for k in sorted(config)},
            "value_s": metric.value,
            "metrics": metric.metrics,
            "wall_s": time.time() - t0,
        }, indent=2, default=str))
        return 0

    tuner = Tuner(space, sut, budget=args.budget,
                  optimizer=args.optimizer, seed=args.seed, verbose=True)
    rep = tuner.run()

    os.makedirs(args.out_dir, exist_ok=True)
    tag = f"{args.arch}_{args.shape}" + ("_mp" if args.multi_pod else "")
    with open(os.path.join(args.out_dir, f"{tag}.json"), "w") as f:
        f.write(rep.to_json())
    with open(os.path.join(args.out_dir, f"{tag}_records.jsonl"), "w") as f:
        for rec in sut.records:
            f.write(json.dumps(rec, default=str) + "\n")

    d, b = rep.default_metric, rep.best_metric
    print("\n=== ACTS result ===")
    print(f"cell: {args.arch} × {args.shape} "
          f"({'2x16x16' if args.multi_pod else '16x16'})")
    print(f"default: t_est={d.value:.4f}s dominant={d.metrics.get('dominant')}")
    print(f"best:    t_est={b.value:.4f}s dominant={b.metrics.get('dominant')}")
    print(f"speedup: {rep.improvement:.2f}x in {rep.n_tests} tests "
          f"({rep.wall_seconds:.0f}s wall)")
    print(f"best config: {rep.best_config}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
