import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# Must precede any jax import: the tuner compiles against the production mesh.
"""ACTS over the JAX runtime (the paper's technique applied to this system).

Four modes:

* ``--probe knob=v[,knob=v...]`` — one manual hypothesis test: compile the
  cell under the given knobs, print the roofline terms (the
  hypothesis→change→measure loop of EXPERIMENTS.md §Perf).
* ``--tune-kernels`` — ACTS over the *Pallas kernels* of the given cell:
  tune block configs for the cell's attention/rmsnorm shapes and persist
  them in the autotune cache, which later runs (``--kernel-autotune``,
  the serve engine, and bare ``repro.kernels.ops`` calls) consult.
* ``--joint`` — cross-system co-tuning: the serve engine's knobs AND the
  decode kernel's block config as ONE ``CompositeSUT`` under one budget
  (BestConfig-style subspace round-robin by default); ``--max-devices N``
  widens the serve subspace with sharding knobs (device count ×
  tp-vs-replicas layout) so the mesh is co-tuned too and the winner
  persists under its mesh-topology cache key.  The default scorer
  is the analytic co-deployment surrogate (``repro.serve.space``; the
  CI/benchmark path); ``--real`` instead wall-clocks the LIVE system per
  trial — the real ``ServeEngine`` rebuilt and timed under each candidate
  config, the real train step re-jitted and timed, train-step knobs
  joining the composite.  Winners persist to the autotune cache — kernel
  blocks under the tuned decode shape, serve knobs as a serve-config
  entry, and (``--real``) train knobs as a train-step entry.
* default — full ACTS run: LHS + RRS over the knob space within ``--budget``
  tests (each test = one AOT compile of the real system on the production
  mesh), reporting default vs. best and writing the full history.

Examples:
  python -m repro.launch.tune --arch qwen2.5-32b --shape train_4k --budget 24
  python -m repro.launch.tune --arch qwen2.5-32b --shape train_4k \
      --tune-kernels
  python -m repro.launch.tune --arch xlstm-350m --shape decode_32k \
      --joint --surrogate --budget 96
  python -m repro.launch.tune --arch grok-1-314b --shape train_4k \
      --probe expert_tp=true,rules_preset=dp
"""
import argparse
import json
import sys
import time

from repro.configs import ARCH_IDS, SHAPES

__all__ = ["main"]


def _parse_value(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    try:
        return int(v)
    except ValueError:
        return v


def _joint_main(args) -> int:
    """--joint: serve knobs + decode kernel blocks (+ train-step knobs in
    --real mode) co-tuned as one SUT under one budget."""
    from repro.configs import get_config
    from repro.core.tuner import Tuner

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    train_seq, train_batch = 32, 8  # the live train-step workload

    if args.real:
        from repro.configs import reduced
        from repro.serve.space import make_live_cotune_sut

        # Live wall-clock co-tuning: every trial rebuilds the REAL serve
        # engine and re-jits the REAL train step under the candidate knobs
        # and times them (warmup trimmed, median of repeats).  On this
        # host the model is the reduced same-family config so a budget-8
        # run finishes in CI time; pointing the same code path at the full
        # config on a TPU pod is a parameter change, not a port.
        model_cfg = reduced(cfg)
        max_seq = min(shape.seq_len, 128)
        sut = make_live_cotune_sut(model_cfg, max_seq=max_seq,
                                   train_seq=train_seq,
                                   train_batch=train_batch, seed=args.seed,
                                   repeats=args.real_repeats,
                                   max_devices=args.max_devices)
        mode = "joint-real"
        dtype = model_cfg.compute_dtype
        # Honest provenance: the live kernel member scored every candidate
        # at ONE fixed decode shape (the default batch), so the winner is
        # keyed at those dims — not at the tuned serve batch it was never
        # evaluated under.  (The surrogate path re-costs the kernel at the
        # candidate batch inside its scalarizer, so it keys at the tuned
        # batch; its dims are resolved after the run.)
        kernel_sig_dims = dict(sut.members["kernel"].dims)
        serve_sig_dims = {"S": max_seq, "H": model_cfg.padded_heads,
                          "KV": model_cfg.n_kv_heads,
                          "D": model_cfg.head_dim_}
    else:
        from repro.serve.space import CotuneParams, make_cotune_sut

        if not args.surrogate:
            print("[joint] scoring on the analytic co-deployment surrogate "
                  "(pass --real to wall-clock the live engine + train "
                  "step instead, or --surrogate to silence this note)")
        params = CotuneParams.from_model(cfg,
                                         max_seq=min(shape.seq_len, 32768))
        sut = make_cotune_sut(params, max_devices=args.max_devices)
        mode = "joint-surrogate"
        dtype = params.dtype
        kernel_sig_dims = None  # tuned-batch decode dims, known post-run
        serve_sig_dims = {"S": params.max_seq, "H": params.heads,
                          "KV": params.kv_heads, "D": params.head_dim}

    space = sut.space()
    tuner = Tuner(space, sut, budget=args.budget, optimizer=args.optimizer,
                  seed=args.seed, verbose=True)
    rep = tuner.run()

    parts = space.split(rep.best_config)
    serve_cfg, kernel_cfg = parts["serve"], parts["kernel"]
    train_cfg = parts.get("train")

    # Persist every winner in ONE cache file: kernel blocks under the
    # decode shape the tuned engine will actually run, serve knobs as the
    # serve-config entry, train-step knobs (live mode) as the train entry.
    from repro import autotune

    cache = autotune.default_cache()
    meta = {"mode": mode, "n_tests": rep.n_tests}
    if kernel_sig_dims is None:  # surrogate: key at the tuned serve batch
        kernel_sig_dims = params.decode_dims(serve_cfg["max_batch"])
    cache.put("decode_attention", autotune.shape_sig(kernel_sig_dims),
              dtype, autotune.backend_name(), kernel_cfg,
              rep.best_metric.value, meta=meta)
    # The serve winner keys at the mesh topology its own knobs chose:
    # a tuned 4-way TP layout must never be resolved by (or clobber)
    # the single-device entry the unsharded engine deploys from.
    n_dev = int(serve_cfg.get("mesh_devices", 1))
    if n_dev > 1 and str(serve_cfg.get("tp_vs_replicas")) == "replicas":
        winner_mesh = autotune.mesh_sig((n_dev, 1))
    elif n_dev > 1:
        winner_mesh = autotune.mesh_sig((1, n_dev))
    else:
        winner_mesh = autotune.mesh_sig(None)
    autotune.put_serve_config(serve_sig_dims, dtype, serve_cfg,
                              rep.best_metric.value, cache=cache, meta=meta,
                              mesh=winner_mesh)
    if train_cfg is not None:
        train_sig_dims = dict(serve_sig_dims, S=train_seq, B=train_batch)
        autotune.put_train_config(train_sig_dims, dtype, train_cfg,
                                  rep.best_metric.value, cache=cache,
                                  meta=meta)

    os.makedirs(args.out_dir, exist_ok=True)
    tag = f"joint_{args.arch}_{args.shape}" + \
        ("_real" if args.real else "")
    with open(os.path.join(args.out_dir, f"{tag}.json"), "w") as f:
        f.write(rep.to_json())

    d, b = rep.default_metric, rep.best_metric
    print("\n=== ACTS joint co-tuning result ===")
    print(f"cell: {args.arch} × {args.shape} "
          f"({'live wall-clock' if args.real else 'surrogate'}, "
          f"optimizer={args.optimizer})")
    print(f"default: {d.value:.1f} tok/s  (all-member defaults)")
    print(f"best:    {b.value:.1f} tok/s  "
          f"latency={b.metrics.get('latency_s', float('nan')):.3f}s")
    print(f"improvement: {rep.improvement:.2f}x in {rep.n_tests} tests "
          f"({rep.wall_seconds:.1f}s wall)")
    print(f"serve knobs:   {serve_cfg}")
    print(f"kernel blocks: {kernel_cfg}")
    if train_cfg is not None:
        print(f"train knobs:   {train_cfg}")
    print(f"persisted to {cache.path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--budget", type=int, default=24)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--optimizer", default=None,
                    help="optimizer name (default: rrs; subspace_rr "
                         "for --joint)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--probe", default=None,
                    help="knob=v[,knob=v...]: single manual hypothesis test")
    ap.add_argument("--tune-kernels", action="store_true",
                    help="ACTS over the cell's Pallas kernel block configs; "
                         "winners persist in the autotune cache")
    ap.add_argument("--joint", action="store_true",
                    help="co-tune serve-engine knobs + decode kernel blocks "
                         "as one SUT (CompositeSpace, shared budget)")
    ap.add_argument("--surrogate", action="store_true",
                    help="with --joint: score on the analytic co-deployment "
                         "surrogate (the default/CI path; the flag just "
                         "silences the which-scorer note)")
    ap.add_argument("--real", action="store_true",
                    help="with --joint: wall-clock the LIVE system per "
                         "trial — rebuild the real ServeEngine and re-jit "
                         "the real train step under each candidate config "
                         "(reduced model on CPU hosts; warmup-trimmed "
                         "median timing); adds train-step knobs to the "
                         "composite and persists their winner too")
    ap.add_argument("--max-devices", type=int, default=1,
                    help="with --joint: widen the serve subspace with "
                         "sharding knobs (mesh_devices in powers of two "
                         "up to this count, tp_vs_replicas) so layout is "
                         "co-tuned with schedule and kernel blocks; the "
                         "winner persists under its mesh-topology cache "
                         "key; 1 = the historical unsharded space")
    ap.add_argument("--real-repeats", type=int, default=3,
                    help="with --joint --real: timed repeats per trial "
                         "(median taken); 1 = fastest smoke, 3 = default "
                         "noise rejection")
    ap.add_argument("--kernel-budget", type=int, default=16)
    ap.add_argument("--out-dir", default="results/tune")
    args = ap.parse_args(argv)
    if args.optimizer is None:
        args.optimizer = "subspace_rr" if args.joint else "rrs"
    if args.real and not args.joint:
        ap.error("--real only applies to --joint (live co-tuning)")
    if args.real and args.surrogate:
        ap.error("--surrogate and --real are mutually exclusive joint "
                 "scorers")

    if args.joint:
        return _joint_main(args)

    from repro.core.sut_jax import JaxDryRunSUT, knob_space
    from repro.core.tuner import Tuner

    kind = SHAPES[args.shape].kind

    if args.tune_kernels:
        from repro import autotune
        from repro.configs import get_config

        cfg = get_config(args.arch)
        shape = SHAPES[args.shape]
        attn_dims = {"B": 1, "S": shape.seq_len, "H": cfg.padded_heads,
                     "KV": cfg.n_kv_heads, "D": cfg.head_dim_}
        fa_dims = dict(attn_dims, SK=shape.seq_len)
        rn_dims = {"ROWS": shape.seq_len, "D": cfg.d_model}
        results = []
        # paged_attention shares the decode signature: its winner seeds the
        # continuous engine's pool layout (pages_per_block -> group size)
        for kernel, dims in (("flash_attention", fa_dims),
                             ("decode_attention", attn_dims),
                             ("paged_attention", attn_dims),
                             ("rmsnorm", rn_dims)):
            res = autotune.autotune_kernel(kernel, dims,
                                           dtype=cfg.compute_dtype,
                                           budget=args.kernel_budget,
                                           seed=args.seed)
            results.append(res)
            print(f"[autotune] {kernel} {res['sig']}: {res['config']} "
                  f"({res['mode']}, {res['n_tests']} tests, "
                  f"value {res['value']:.3g})")
        print(json.dumps({"cache": autotune.default_cache().path,
                          "entries": results}, indent=2))
        return 0
    sut = JaxDryRunSUT(args.arch, args.shape, multi_pod=args.multi_pod,
                       verbose=True)
    space = knob_space(kind)

    if args.probe is not None:
        config = space.default_config()
        if args.probe:
            for kv in args.probe.split(","):
                k, v = kv.split("=", 1)
                config[k] = _parse_value(v)
        space.validate(config)
        t0 = time.time()
        metric = sut.test(config)
        print(json.dumps({
            "arch": args.arch, "shape": args.shape,
            "config": {k: config[k] for k in sorted(config)},
            "value_s": metric.value,
            "metrics": metric.metrics,
            "wall_s": time.time() - t0,
        }, indent=2, default=str))
        return 0

    tuner = Tuner(space, sut, budget=args.budget,
                  optimizer=args.optimizer, seed=args.seed, verbose=True)
    rep = tuner.run()

    os.makedirs(args.out_dir, exist_ok=True)
    tag = f"{args.arch}_{args.shape}" + ("_mp" if args.multi_pod else "")
    with open(os.path.join(args.out_dir, f"{tag}.json"), "w") as f:
        f.write(rep.to_json())
    with open(os.path.join(args.out_dir, f"{tag}_records.jsonl"), "w") as f:
        for rec in sut.records:
            f.write(json.dumps(rec, default=str) + "\n")

    d, b = rep.default_metric, rep.best_metric
    print("\n=== ACTS result ===")
    print(f"cell: {args.arch} × {args.shape} "
          f"({'2x16x16' if args.multi_pod else '16x16'})")
    print(f"default: t_est={d.value:.4f}s dominant={d.metrics.get('dominant')}")
    print(f"best:    t_est={b.value:.4f}s dominant={b.metrics.get('dominant')}")
    print(f"speedup: {rep.improvement:.2f}x in {rep.n_tests} tests "
          f"({rep.wall_seconds:.0f}s wall)")
    print(f"best config: {rep.best_config}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
