import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# Must precede any jax import: the tuner compiles against the production mesh.
"""ACTS over the JAX runtime (the paper's technique applied to this system).

Four modes:

* ``--probe knob=v[,knob=v...]`` — one manual hypothesis test: compile the
  cell under the given knobs, print the roofline terms (the
  hypothesis→change→measure loop of EXPERIMENTS.md §Perf).
* ``--tune-kernels`` — ACTS over the *Pallas kernels* of the given cell:
  tune block configs for the cell's attention/rmsnorm shapes and persist
  them in the autotune cache, which later runs (``--kernel-autotune``,
  the serve engine, and bare ``repro.kernels.ops`` calls) consult.
* ``--joint`` — cross-system co-tuning: the serve engine's knobs AND the
  decode kernel's block config as ONE ``CompositeSUT`` under one budget
  (BestConfig-style subspace round-robin by default).  On this CPU
  container the SUT is the analytic co-deployment surrogate
  (``repro.serve.space``); winners persist to the autotune cache — kernel
  blocks under the tuned decode shape, serve knobs as a serve-config
  entry.
* default — full ACTS run: LHS + RRS over the knob space within ``--budget``
  tests (each test = one AOT compile of the real system on the production
  mesh), reporting default vs. best and writing the full history.

Examples:
  python -m repro.launch.tune --arch qwen2.5-32b --shape train_4k --budget 24
  python -m repro.launch.tune --arch qwen2.5-32b --shape train_4k \
      --tune-kernels
  python -m repro.launch.tune --arch xlstm-350m --shape decode_32k \
      --joint --surrogate --budget 96
  python -m repro.launch.tune --arch grok-1-314b --shape train_4k \
      --probe expert_tp=true,rules_preset=dp
"""
import argparse
import json
import sys
import time

from repro.configs import ARCH_IDS, SHAPES

__all__ = ["main"]


def _parse_value(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    try:
        return int(v)
    except ValueError:
        return v


def _joint_main(args) -> int:
    """--joint: serve knobs + decode kernel blocks as one SUT."""
    from repro.configs import get_config
    from repro.core.tuner import Tuner
    from repro.serve.space import CotuneParams, make_cotune_sut

    if not args.surrogate:
        # There is no real-engine joint scorer yet (wall-clocking the live
        # engine per trial is future work), so every run uses the analytic
        # surrogate; say so rather than silently implying a measurement.
        print("[joint] scoring on the analytic co-deployment surrogate "
              "(currently the only joint scorer; pass --surrogate to "
              "silence this note)")

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    params = CotuneParams.from_model(cfg, max_seq=min(shape.seq_len, 32768))
    sut = make_cotune_sut(params)
    space = sut.space()
    tuner = Tuner(space, sut, budget=args.budget, optimizer=args.optimizer,
                  seed=args.seed, verbose=True)
    rep = tuner.run()

    parts = space.split(rep.best_config)
    serve_cfg, kernel_cfg = parts["serve"], parts["kernel"]

    # Persist both winners: kernel blocks under the decode shape the tuned
    # engine will actually run, serve knobs as the serve-config entry.
    from repro import autotune

    cache = autotune.default_cache()
    kernel_dims = params.decode_dims(serve_cfg["max_batch"])
    cache.put("decode_attention", autotune.shape_sig(kernel_dims),
              params.dtype, autotune.backend_name(), kernel_cfg,
              rep.best_metric.value,
              meta={"mode": "joint-surrogate", "n_tests": rep.n_tests})
    serve_sig_dims = {"S": params.max_seq, "H": params.heads,
                      "KV": params.kv_heads, "D": params.head_dim}
    autotune.put_serve_config(serve_sig_dims, params.dtype, serve_cfg,
                              rep.best_metric.value, cache=cache,
                              meta={"mode": "joint-surrogate",
                                    "n_tests": rep.n_tests})

    os.makedirs(args.out_dir, exist_ok=True)
    tag = f"joint_{args.arch}_{args.shape}"
    with open(os.path.join(args.out_dir, f"{tag}.json"), "w") as f:
        f.write(rep.to_json())

    d, b = rep.default_metric, rep.best_metric
    print("\n=== ACTS joint co-tuning result ===")
    print(f"cell: {args.arch} × {args.shape} (surrogate, "
          f"optimizer={args.optimizer})")
    print(f"default: {d.value:.0f} tok/s  (serve+kernel defaults)")
    print(f"best:    {b.value:.0f} tok/s  "
          f"latency={b.metrics.get('latency_s', float('nan')):.3f}s")
    print(f"improvement: {rep.improvement:.2f}x in {rep.n_tests} tests "
          f"({rep.wall_seconds:.1f}s wall)")
    print(f"serve knobs:   {serve_cfg}")
    print(f"kernel blocks: {kernel_cfg}")
    print(f"persisted to {cache.path}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--budget", type=int, default=24)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--optimizer", default=None,
                    help="optimizer name (default: rrs; subspace_rr "
                         "for --joint)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--probe", default=None,
                    help="knob=v[,knob=v...]: single manual hypothesis test")
    ap.add_argument("--tune-kernels", action="store_true",
                    help="ACTS over the cell's Pallas kernel block configs; "
                         "winners persist in the autotune cache")
    ap.add_argument("--joint", action="store_true",
                    help="co-tune serve-engine knobs + decode kernel blocks "
                         "as one SUT (CompositeSpace, shared budget)")
    ap.add_argument("--surrogate", action="store_true",
                    help="with --joint: score on the analytic co-deployment "
                         "surrogate — currently the ONLY joint scorer "
                         "(real-engine wall-clock co-tuning is future "
                         "work); the flag just records intent")
    ap.add_argument("--kernel-budget", type=int, default=16)
    ap.add_argument("--out-dir", default="results/tune")
    args = ap.parse_args(argv)
    if args.optimizer is None:
        args.optimizer = "subspace_rr" if args.joint else "rrs"

    if args.joint:
        return _joint_main(args)

    from repro.core.sut_jax import JaxDryRunSUT, knob_space
    from repro.core.tuner import Tuner

    kind = SHAPES[args.shape].kind

    if args.tune_kernels:
        from repro import autotune
        from repro.configs import get_config

        cfg = get_config(args.arch)
        shape = SHAPES[args.shape]
        attn_dims = {"B": 1, "S": shape.seq_len, "H": cfg.padded_heads,
                     "KV": cfg.n_kv_heads, "D": cfg.head_dim_}
        fa_dims = dict(attn_dims, SK=shape.seq_len)
        rn_dims = {"ROWS": shape.seq_len, "D": cfg.d_model}
        results = []
        for kernel, dims in (("flash_attention", fa_dims),
                             ("decode_attention", attn_dims),
                             ("rmsnorm", rn_dims)):
            res = autotune.autotune_kernel(kernel, dims,
                                           dtype=cfg.compute_dtype,
                                           budget=args.kernel_budget,
                                           seed=args.seed)
            results.append(res)
            print(f"[autotune] {kernel} {res['sig']}: {res['config']} "
                  f"({res['mode']}, {res['n_tests']} tests, "
                  f"value {res['value']:.3g})")
        print(json.dumps({"cache": autotune.default_cache().path,
                          "entries": results}, indent=2))
        return 0
    sut = JaxDryRunSUT(args.arch, args.shape, multi_pod=args.multi_pod,
                       verbose=True)
    space = knob_space(kind)

    if args.probe is not None:
        config = space.default_config()
        if args.probe:
            for kv in args.probe.split(","):
                k, v = kv.split("=", 1)
                config[k] = _parse_value(v)
        space.validate(config)
        t0 = time.time()
        metric = sut.test(config)
        print(json.dumps({
            "arch": args.arch, "shape": args.shape,
            "config": {k: config[k] for k in sorted(config)},
            "value_s": metric.value,
            "metrics": metric.metrics,
            "wall_s": time.time() - t0,
        }, indent=2, default=str))
        return 0

    tuner = Tuner(space, sut, budget=args.budget,
                  optimizer=args.optimizer, seed=args.seed, verbose=True)
    rep = tuner.run()

    os.makedirs(args.out_dir, exist_ok=True)
    tag = f"{args.arch}_{args.shape}" + ("_mp" if args.multi_pod else "")
    with open(os.path.join(args.out_dir, f"{tag}.json"), "w") as f:
        f.write(rep.to_json())
    with open(os.path.join(args.out_dir, f"{tag}_records.jsonl"), "w") as f:
        for rec in sut.records:
            f.write(json.dumps(rec, default=str) + "\n")

    d, b = rep.default_metric, rep.best_metric
    print("\n=== ACTS result ===")
    print(f"cell: {args.arch} × {args.shape} "
          f"({'2x16x16' if args.multi_pod else '16x16'})")
    print(f"default: t_est={d.value:.4f}s dominant={d.metrics.get('dominant')}")
    print(f"best:    t_est={b.value:.4f}s dominant={b.metrics.get('dominant')}")
    print(f"speedup: {rep.improvement:.2f}x in {rep.n_tests} tests "
          f"({rep.wall_seconds:.0f}s wall)")
    print(f"best config: {rep.best_config}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
