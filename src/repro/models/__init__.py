"""Pure-JAX model zoo: dense/GQA transformers, MoE, xLSTM, Mamba2, enc-dec,
vision/audio cross-attention — assembled from ModelConfig superblock patterns."""
from .transformer import Model, count_params, model_defs

__all__ = ["Model", "count_params", "model_defs"]
