"""Attention: GQA/MQA/MHA, causal, sliding-window, cross — with KV caches.

Three interchangeable inner implementations (``cfg.attn_impl``):

* ``dense``   — materialized logits; reference semantics, smoke tests.
* ``blocked`` — flash-style online-softmax over KV blocks in pure JAX
                (O(S·block) memory); the default for long sequences.
* ``local``   — banded chunk attention for sliding-window layers:
                each Q chunk attends its own + previous chunk only
                (compute O(S·2W) instead of O(S²)).
* the Pallas TPU kernel (``repro.kernels``) plugs in via ``pallas`` and is
  numerically validated against ``dense`` in interpret mode.

All softmax math runs in f32 regardless of activation dtype.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.dist.sharding import constrain
from repro.models.common import (
    ParamDef,
    apply_rope,
    fan_in_init,
    rope_freqs,
    zeros_init,
)

__all__ = [
    "attention_defs",
    "self_attention",
    "cross_attention",
    "init_attn_cache_defs",
    "attend",
]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------
def _valid_head_mask(cfg: ModelConfig):
    """(padded_heads,) bool — which padded head slots are real.

    Pads are interleaved *within* each GQA group (slot h belongs to kv group
    h // G_pad), so real heads keep their original kv-head assignment."""
    H_pad, KV = cfg.padded_heads, cfg.n_kv_heads
    g_pad, g_orig = H_pad // KV, cfg.n_heads // KV
    return (jnp.arange(H_pad) % g_pad) < g_orig


def _head_padded_init(base, cfg: ModelConfig, head_axis: int):
    """Zero the padded head slots so they contribute exactly 0 (their q rows
    and wo rows are zero => exact semantics)."""

    def init(key, shape, dtype):
        # head_axis is negative: superblock stacking prepends a layer dim
        w = base(key, shape, dtype)
        mask = _valid_head_mask(cfg)
        bc = [1] * len(shape)
        bc[head_axis] = shape[head_axis]
        return (w * mask.reshape(bc).astype(w.dtype)).astype(dtype)

    return init


def attention_defs(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamDef]:
    d, KV, Dh = cfg.d_model, cfg.n_kv_heads, cfg.head_dim_
    H = cfg.padded_heads
    pdt = cfg.param_dtype
    kv_src = d  # memory is projected to d_model before blocks; keep uniform
    q_init, o_init = fan_in_init(0), fan_in_init(1)
    if H != cfg.n_heads:
        q_init = _head_padded_init(q_init, cfg, -2)  # (..., d, H, Dh)
        o_init = _head_padded_init(o_init, cfg, -3)  # (..., H, Dh, d)
    defs = {
        "wq": ParamDef((d, H, Dh), ("embed_fsdp", "heads", "head_dim"),
                       q_init, _dt(pdt)),
        "wk": ParamDef((kv_src, KV, Dh), ("embed_fsdp", "kv_heads", "head_dim"),
                       fan_in_init(0), _dt(pdt)),
        "wv": ParamDef((kv_src, KV, Dh), ("embed_fsdp", "kv_heads", "head_dim"),
                       fan_in_init(0), _dt(pdt)),
        "wo": ParamDef((H, Dh, d), ("heads", "head_dim", "embed_fsdp"),
                       o_init, _dt(pdt)),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H, Dh), ("heads", "head_dim"), zeros_init(), _dt(pdt))
        defs["bk"] = ParamDef((KV, Dh), ("kv_heads", "head_dim"), zeros_init(), _dt(pdt))
        defs["bv"] = ParamDef((KV, Dh), ("kv_heads", "head_dim"), zeros_init(), _dt(pdt))
    return defs


def _dt(name: str):
    from repro.models.common import dtype_of

    return dtype_of(name)


def init_attn_cache_defs(
    cfg: ModelConfig, batch: int, max_seq: int, window: int = 0
) -> Dict[str, ParamDef]:
    """KV-cache buffer shapes for one attention block (ring buffer for SWA)."""
    KV, Dh = cfg.n_kv_heads, cfg.head_dim_
    S = min(window, max_seq) if window else max_seq
    return {
        "k": ParamDef((batch, S, KV, Dh), ("batch", "kv_seq", "kv_heads", "head_dim"),
                      zeros_init(), _dt(cfg.compute_dtype)),
        "v": ParamDef((batch, S, KV, Dh), ("batch", "kv_seq", "kv_heads", "head_dim"),
                      zeros_init(), _dt(cfg.compute_dtype)),
    }


# ---------------------------------------------------------------------------
# inner attention
# ---------------------------------------------------------------------------
def _gqa_logits(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,Sq,H,D), k: (B,Sk,KV,D) -> logits (B,H,Sq,Sk) without
    materializing repeated KV heads."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    return logits.reshape(B, KV * G, Sq, k.shape[1])


def _gqa_out(weights: jax.Array, v: jax.Array) -> jax.Array:
    """weights: (B,H,Sq,Sk), v: (B,Sk,KV,D) -> (B,Sq,H,D)."""
    B, H, Sq, Sk = weights.shape
    KV = v.shape[2]
    G = H // KV
    wg = weights.reshape(B, KV, G, Sq, Sk)
    out = jnp.einsum("bkgqs,bskd->bqkgd", wg, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1])


def _dense_attend(
    q, k, v, *, causal: bool, window: int, q_offset, kv_len: Optional[jax.Array],
) -> jax.Array:
    """``q_offset`` and ``kv_len`` may be scalars (the classic paths) or
    (B,)-vectors — the continuous-batching engine decodes slots sitting at
    *different* cache lengths in one dispatch.  The mask is built in a
    (B-or-1, Sq, Sk) frame so both shapes share one code path."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    logits = _gqa_logits(q, k) / math.sqrt(D)
    qoff = jnp.asarray(q_offset)
    qpos = qoff.reshape(-1, 1, 1) + jnp.arange(Sq)[None, :, None]
    kpos = jnp.arange(Sk)[None, None, :]
    mask = jnp.ones((1, Sq, Sk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window:
        mask = mask & (kpos > qpos - window)
    if kv_len is not None:
        mask = mask & (kpos < jnp.asarray(kv_len).reshape(-1, 1, 1))
    logits = jnp.where(mask[:, None], logits, NEG_INF)
    weights = jax.nn.softmax(logits, axis=-1)
    return _gqa_out(weights, v).astype(v.dtype)


def _blocked_attend(q, k, v, *, causal: bool, block_q: int, block_kv: int,
                    q_offset=0) -> jax.Array:
    """Flash-style two-level scan: memory O(block_q × block_kv)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_kv
    qb = qp.reshape(B, nq, block_q, KV, G, D).astype(jnp.float32) / math.sqrt(D)
    kb = kp.reshape(B, nk, block_kv, KV, D).astype(jnp.float32)
    vb = vp.reshape(B, nk, block_kv, KV, D).astype(jnp.float32)

    qpos = (q_offset + jnp.arange(nq * block_q)).reshape(nq, block_q)
    kpos = jnp.arange(nk * block_kv).reshape(nk, block_kv)
    kvalid = (jnp.arange(nk * block_kv) < Sk).reshape(nk, block_kv)

    def q_block(carry, qi):
        qblk, qp_blk = qi  # (B, bq, KV, G, D), (bq,)

        def kv_block(state, ki):
            m, l, acc = state
            kblk, vblk, kp_blk, kval = ki
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qblk, kblk)
            mask = kval[None, :]
            if causal:
                mask = mask & (kp_blk[None, :] <= qp_blk[:, None])
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            blk_max = logits.max(-1)
            new_m = jnp.maximum(m, blk_max)
            scale = jnp.exp(m - new_m)
            p = jnp.exp(logits - new_m[..., None])
            new_l = l * scale + p.sum(-1)
            new_acc = acc * scale[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vblk
            )
            return (new_m, new_l, new_acc), None

        m0 = jnp.full((B, KV, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KV, G, block_q, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (kb.swapaxes(0, 1), vb.swapaxes(0, 1),
                                     kpos, kvalid)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,KV,G,bq,D)
        return carry, out.transpose(0, 3, 1, 2, 4)  # (B,bq,KV,G,D)

    _, outs = jax.lax.scan(q_block, None, (qb.swapaxes(0, 1), qpos))
    # outs: (nq, B, bq, KV, G, D)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * block_q, H, D)
    return out[:, :Sq].astype(v.dtype)


def _local_attend(q, k, v, *, window: int, q_offset=0) -> jax.Array:
    """Banded attention: chunk size W; each Q chunk sees [prev|own] chunks.
    Exact for causal sliding-window of width ≤ W."""
    B, Sq, H, D = q.shape
    W = window
    pad = (-Sq) % W
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = qp.shape[1]
    nc = S // W
    KV = k.shape[2]
    G = H // KV
    qc = qp.reshape(B, nc, W, KV, G, D).astype(jnp.float32) / math.sqrt(D)
    kc = kp.reshape(B, nc, W, KV, D)
    vc = vp.reshape(B, nc, W, KV, D)
    # previous chunk (zeros for the first)
    kprev = jnp.pad(kc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    vprev = jnp.pad(vc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    k2 = jnp.concatenate([kprev, kc], axis=2).astype(jnp.float32)  # (B,nc,2W,KV,D)
    v2 = jnp.concatenate([vprev, vc], axis=2).astype(jnp.float32)
    logits = jnp.einsum("bnqkgd,bnskd->bnkgqs", qc, k2)
    qpos = jnp.arange(W)[:, None] + W  # position within the 2W window frame
    kpos = jnp.arange(2 * W)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - window)
    # first chunk: "previous" half is padding
    first_mask = mask & (kpos >= W)
    chunk_idx = jnp.arange(nc)
    full_mask = jnp.where((chunk_idx == 0)[:, None, None], first_mask[None],
                          mask[None])  # (nc, W, 2W)
    # global padding validity on kv side
    kvalid = jnp.concatenate(
        [jnp.pad((jnp.arange(S) < Sq).reshape(nc, W)[:-1], ((1, 0), (0, 0))),
         (jnp.arange(S) < Sq).reshape(nc, W)], axis=1)  # (nc, 2W)
    full_mask = full_mask & kvalid[:, None, :]
    logits = jnp.where(full_mask[None, :, None, None], logits, NEG_INF)
    weights = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnkgqs,bnskd->bnqkgd", weights, v2)
    out = out.reshape(B, S, H, D)[:, :Sq]
    return out.astype(v.dtype)


def attend(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    cfg: ModelConfig,
    causal: bool = True,
    window: int = 0,
    impl: Optional[str] = None,
    q_offset: Any = 0,
    kv_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Dispatch to an inner attention implementation."""
    impl = impl or cfg.attn_impl
    Sq, Sk = q.shape[1], k.shape[1]
    if window and causal and Sq == Sk and Sk <= window:
        window = 0  # the window covers the whole causal context: no-op
    if impl == "auto":
        if Sq == 1 or kv_len is not None:
            impl = "dense"  # decode: one query row, einsum over the cache
        elif window and causal and Sq == Sk and Sk > 2 * window:
            impl = "local"
        elif Sk >= 2 * cfg.attn_block_kv:
            impl = "blocked"
        else:
            impl = "dense"
    if impl == "pallas":
        from repro.kernels.ops import flash_attention as pallas_flash

        return pallas_flash(q, k, v, causal=causal, window=window)
    if impl == "local":
        return _local_attend(q, k, v, window=window, q_offset=q_offset)
    if impl == "blocked":
        out = _blocked_attend(
            q, k, v, causal=causal, block_q=cfg.attn_block_q,
            block_kv=cfg.attn_block_kv, q_offset=q_offset,
        )
        if window:  # blocked path is exact only without a window; guard
            raise ValueError("blocked impl does not support sliding window")
        return out
    if impl == "dense":
        return _dense_attend(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, kv_len=kv_len)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# block-level wrappers
# ---------------------------------------------------------------------------
def _mask_padded_heads(y: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Zero the attention output of padded heads: blocks gradient flow into
    the zero-initialized pad weights, making head padding training-exact."""
    if cfg.padded_heads == cfg.n_heads:
        return y
    valid = _valid_head_mask(cfg)
    return y * valid[None, None, :, None].astype(y.dtype)


def _project_qkv(params, x, memory, cfg: ModelConfig):
    cdt = _dt(cfg.compute_dtype)
    src = x.astype(cdt)
    mem = (memory if memory is not None else x).astype(cdt)
    q = jnp.einsum("bsd,dhk->bshk", src, params["wq"].astype(cdt))
    k = jnp.einsum("bsd,dhk->bshk", mem, params["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", mem, params["wv"].astype(cdt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(cdt)
        k = k + params["bk"].astype(cdt)
        v = v + params["bv"].astype(cdt)
    return q, k, v


def self_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,
    *,
    cfg: ModelConfig,
    positions: jax.Array,  # (B, S) absolute positions of x tokens
    window: int = 0,
    cache: Optional[Dict[str, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,  # scalar or (B,): cached tokens
    impl: Optional[str] = None,
    causal: bool = True,
    page_table: Optional[jax.Array] = None,  # (B, MAXG): paged KV layout
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Causal self-attention; updates the KV cache when one is given.

    Without a cache: full-sequence training/prefill-style attention.
    With a cache: ``x`` holds new token(s); K/V are appended (ring-buffer
    writes for sliding-window blocks) and attention runs against the buffer.
    A (B,)-vector ``cache_index`` is the continuous-batching decode path:
    every slot appends its single token at its *own* position.  With
    ``page_table`` the cache is a (groups, group_tokens, KV, D) pool and
    the append/attend go through the table (``repro.serve.paging``).
    """
    q, k, v = _project_qkv(params, x, None, cfg)
    cos, sin = rope_freqs(positions, cfg.head_dim_, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")

    new_cache = None
    if cache is not None and page_table is not None:
        B = x.shape[0]
        T = cache["k"].shape[1]
        S_new = k.shape[1]
        pos = jnp.asarray(cache_index, jnp.int32).reshape(B)
        if S_new == 1:
            # Paged pool: single-token decode append through the table.
            gid = page_table[jnp.arange(B), pos // T]
            off = pos % T
            ck = cache["k"].at[gid, off].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[gid, off].set(
                v[:, 0].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}
            y = _paged_decode_attend(q, ck, cv, page_table, pos + 1)
        else:
            # Speculative verify: C tokens per slot, positions
            # pos..pos+C-1.  Columns past the page table (a draft chain
            # overrunning max_seq on a request that will finish first)
            # are routed out of range and dropped by the scatter; columns
            # past a slot's reservation land in the scratch entries of
            # its table row — either way they are masked KV no valid
            # query ever reads, so the accepted prefix stays exact.
            G_pool = cache["k"].shape[0]
            MAXG = page_table.shape[1]
            ppos = pos[:, None] + jnp.arange(S_new, dtype=jnp.int32)
            lg = ppos // T
            gid = jnp.where(
                lg < MAXG,
                page_table[jnp.arange(B)[:, None],
                           jnp.minimum(lg, MAXG - 1)],
                G_pool)
            off = ppos % T
            ck = cache["k"].at[gid, off].set(
                k.astype(cache["k"].dtype), mode="drop")
            cv = cache["v"].at[gid, off].set(
                v.astype(cache["v"].dtype), mode="drop")
            new_cache = {"k": ck, "v": cv}
            y = _paged_verify_attend(q, ck, cv, page_table, pos)
    elif cache is not None:
        Sbuf = cache["k"].shape[1]
        S_new = k.shape[1]
        if jnp.ndim(cache_index) == 1:
            # Continuous batching: each slot appends token(s) at its own
            # cache length (scatter write; per-slot masks in the attend).
            # S_new > 1 is the speculative-verify chain — the dense mask
            # already handles vector q_offset with multi-token queries,
            # and writes past the buffer (overrunning draft columns) are
            # dropped rather than clamped onto live positions.
            B = x.shape[0]
            idx = cache_index.astype(jnp.int32)
            if S_new == 1:
                ck = cache["k"].at[jnp.arange(B), idx].set(
                    k[:, 0].astype(cache["k"].dtype))
                cv = cache["v"].at[jnp.arange(B), idx].set(
                    v[:, 0].astype(cache["v"].dtype))
            else:
                ppos = idx[:, None] + jnp.arange(S_new, dtype=jnp.int32)
                ck = cache["k"].at[jnp.arange(B)[:, None], ppos].set(
                    k.astype(cache["k"].dtype), mode="drop")
                cv = cache["v"].at[jnp.arange(B)[:, None], ppos].set(
                    v.astype(cache["v"].dtype), mode="drop")
            new_cache = {"k": ck, "v": cv}
            y = attend(q, ck, cv, cfg=cfg, causal=True, window=0,
                       impl="dense", kv_len=idx + S_new, q_offset=idx)
        else:
            if window and Sbuf == window:
                write_pos = (cache_index % window).astype(jnp.int32)
            else:
                write_pos = cache_index.astype(jnp.int32)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, write_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, write_pos, 0, 0))
            new_cache = {"k": ck, "v": cv}
            total = cache_index + S_new
            if window and Sbuf == window:
                # Ring buffer (sliding window): single-step decode writes.
                y = _ring_decode_attend(q, ck, cv, cache_index, window)
            else:
                # Causal over the buffer: new tokens sit at
                # q_offset=cache_index; only the first `total` slots valid.
                y = attend(q, ck, cv, cfg=cfg, causal=True, window=0,
                           impl="dense", kv_len=total, q_offset=cache_index)
    else:
        y = attend(q, k, v, cfg=cfg, causal=causal, window=window, impl=impl)

    y = _mask_padded_heads(y, cfg)
    y = constrain(y, "batch", "seq", "heads", "head_dim")
    cdt = _dt(cfg.compute_dtype)
    out = jnp.einsum("bshk,hkd->bsd", y.astype(cdt), params["wo"].astype(cdt))
    return constrain(out, "batch", "seq_res", "embed"), new_cache


def _paged_decode_attend(q, k_pages, v_pages, page_table, lengths):
    """Decode attention over a paged pool (single-step q).

    On accelerator backends this is the Pallas paged kernel (the page
    table rides in as a scalar-prefetch operand, so K/V stream straight
    from their physical groups); on CPU the pure-jnp gather reference —
    interpret-mode Pallas times the Python emulator, not the hardware,
    exactly like the other kernel entry points."""
    from repro.kernels.ops import default_interpret, paged_flash_decode
    from repro.kernels.paged_attention import paged_attention_ref

    qs = q[:, 0]
    if default_interpret():
        out = paged_attention_ref(qs, k_pages, v_pages, page_table, lengths)
    else:
        out = paged_flash_decode(qs, k_pages, v_pages, page_table, lengths)
    return out[:, None].astype(v_pages.dtype)


def _paged_verify_attend(q, k_pages, v_pages, page_table, base):
    """Multi-token decode attention over a paged pool (speculative verify).

    ``paged_attention_ref`` generalized to C query columns per slot:
    gather the pool into logical order through the page table, then
    masked attention where column i (absolute position ``base + i``)
    sees key positions ``< base + i + 1``.  Scratch-group and
    rejected-tail writes are masked out the same way stale pool tokens
    are in the single-token ref, so the accepted prefix attends exactly
    the KV a draft-free run would."""
    B, C, H, D = q.shape
    G_pool, T, KV, _ = k_pages.shape
    k = k_pages[page_table].reshape(B, -1, KV, D).astype(jnp.float32)
    v = v_pages[page_table].reshape(B, -1, KV, D).astype(jnp.float32)
    Gq = H // KV
    qg = q.reshape(B, C, KV, Gq, D).astype(jnp.float32) / math.sqrt(D)
    s = jnp.einsum("bckgd,bskd->bkgcs", qg, k)
    kpos = jnp.arange(k.shape[1])[None, None, None, None, :]
    qpos = (base[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :])
    s = jnp.where(kpos <= qpos[:, None, None, :, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgcs,bskd->bckgd", w, v)
    return out.reshape(B, C, H, D).astype(v_pages.dtype)


def _ring_decode_attend(q, ck, cv, cache_index, window):
    """Decode attention over a ring-buffer SWA cache (single-step q)."""
    B, Sq, H, D = q.shape
    W = ck.shape[1]
    # slot s holds absolute position: valid if pos > cache_index - window
    slots = jnp.arange(W)
    # absolute position stored in slot s (when cache_index tokens written):
    # last write at (cache_index) -> slot cache_index % W.
    total = cache_index + Sq
    age = (jnp.int32(total - 1) - slots) % W  # 0 = newest ... W-1 oldest
    valid = age < jnp.minimum(total, W)
    logits = _gqa_logits(q, ck) / math.sqrt(D)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    weights = jax.nn.softmax(logits, axis=-1)
    return _gqa_out(weights, cv).astype(cv.dtype)


def cross_attention(
    params: Dict[str, jax.Array],
    x: jax.Array,
    memory: jax.Array,
    *,
    cfg: ModelConfig,
    memory_kv: Optional[Dict[str, jax.Array]] = None,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Cross-attention to a fixed memory (image patches / audio frames /
    encoder output).  ``memory_kv``: precomputed K/V for decode steps."""
    cdt = _dt(cfg.compute_dtype)
    if memory_kv is None:
        q, k, v = _project_qkv(params, x, memory, cfg)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x.astype(cdt),
                       params["wq"].astype(cdt))
        if cfg.qkv_bias:
            q = q + params["bq"].astype(cdt)
        k, v = memory_kv["k"], memory_kv["v"]
    y = _mask_padded_heads(attend(q, k, v, cfg=cfg, causal=False, window=0,
                                  impl="dense"), cfg)
    out = jnp.einsum("bshk,hkd->bsd", y.astype(cdt), params["wo"].astype(cdt))
    kv = {"k": k, "v": v} if memory_kv is None else memory_kv
    return constrain(out, "batch", "seq", "embed"), kv
