"""Model substrate: parameter definitions, init, norms, rotary embeddings.

Parameters are declared as ``ParamDef`` trees (shape + logical axes + init),
which gives three views of the same model for free:

* ``init_params``      — materialized weights (smoke tests, real training),
* ``abstract_params``  — ShapeDtypeStructs (the multi-pod dry-run: no
                         allocation, exactly the shannon/kernels pattern),
* ``param_specs``      — PartitionSpec tree under the active sharding rules
                         (the knob surface ACTS tunes).
"""
from __future__ import annotations

import math
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.dist.sharding import AxisRules, spec_for_shape

__all__ = [
    "ParamDef",
    "stack_defs",
    "init_params",
    "abstract_params",
    "param_specs",
    "count_def_params",
    "rms_norm",
    "rope_freqs",
    "apply_rope",
    "cross_entropy_loss",
    "dtype_of",
]


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# ParamDef trees
# ---------------------------------------------------------------------------
InitFn = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


def normal_init(std: float) -> InitFn:
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return init


def fan_in_init(axis: int = -2) -> InitFn:
    """Lecun-normal on the fan-in dimension(s): std = 1/sqrt(fan_in)."""

    def init(key, shape, dtype):
        fan_in = shape[axis] if len(shape) >= 2 else shape[0]
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return init


def zeros_init() -> InitFn:
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init() -> InitFn:
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis names, len == len(shape)
    init: InitFn
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _map_defs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_def)


def stack_defs(tree, n: int, axis_name: str = "layer"):
    """Add a leading stacking dim (scan-over-superblocks parameter layout)."""

    def stack(d: ParamDef) -> ParamDef:
        return ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init, d.dtype)

    return _map_defs(stack, tree)


def _path_key(root: jax.Array, path) -> jax.Array:
    label = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
    return jax.random.fold_in(root, zlib.crc32(label.encode()) & 0x7FFFFFFF)


def init_params(tree, rng: jax.Array):
    """Materialize a ParamDef tree (deterministic per-leaf keys by path)."""

    def init_leaf(path, d: ParamDef):
        return d.init(_path_key(rng, path), d.shape, d.dtype)

    return jax.tree_util.tree_map_with_path(init_leaf, tree, is_leaf=_is_def)


def abstract_params(tree):
    return _map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree)


def param_specs(tree, rules: AxisRules, mesh):
    return _map_defs(
        lambda d: spec_for_shape(d.shape, d.axes, rules, mesh), tree
    )


def count_def_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=_is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for given integer positions: (..., head_dim/2)."""
    half = head_dim // 2
    freq = (theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freq  # (..., half)
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch and heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    x32_1, x32_2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = x32_1 * cos - x32_2 * sin
    r2 = x32_2 * cos + x32_1 * sin
    return jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None,
    z_loss: float = 0.0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Token-mean cross entropy in f32 with optional z-loss regularizer."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(logz)
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((logits.argmax(-1) == labels) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
