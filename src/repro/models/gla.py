"""Chunked gated linear attention (GLA) — the shared recurrence core.

Both Mamba2's SSD and xLSTM's mLSTM are instances of the same primitive:

    S_t = exp(g_t) · S_{t-1} + k_t v_tᵀ          (state: dk × dv per head)
    y_t = q_tᵀ S_t

with per-step, per-head log-decay ``g_t ≤ 0``.  We evaluate it chunkwise —
within a chunk the quadratic "attention" form with decay matrix
``exp(c_t − c_s)`` (c = inclusive cumsum of g), across chunks a scan carries
the state — which is the TPU-native way to run these models: the chunk
matmuls hit the MXU, the scan is O(S/chunk).  This file is the pure-jnp
reference; ``repro.kernels`` provides the Pallas TPU kernel for the same
computation.

All math is f32 internally; decays are computed as differences before
exponentiation so nothing overflows.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["chunked_gla", "gla_step"]


def chunked_gla(
    q: jax.Array,  # (B, S, H, dk)
    k: jax.Array,  # (B, S, H, dk)
    v: jax.Array,  # (B, S, H, dv)
    log_g: jax.Array,  # (B, S, H) per-step log decay (≤ 0)
    chunk: int = 256,
    initial_state: Optional[jax.Array] = None,  # (B, H, dk, dv)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y: (B,S,H,dv), final_state: (B,H,dk,dv))."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_g = jnp.pad(log_g, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // L

    f32 = jnp.float32
    qc = q.reshape(B, nc, L, H, dk).astype(f32)
    kc = k.reshape(B, nc, L, H, dk).astype(f32)
    vc = v.reshape(B, nc, L, H, dv).astype(f32)
    gc = log_g.reshape(B, nc, L, H).astype(f32)

    S0 = (initial_state.astype(f32) if initial_state is not None
          else jnp.zeros((B, H, dk, dv), f32))

    def one_chunk(state, inputs):
        qb, kb, vb, gb = inputs  # (B,L,H,·)
        c = jnp.cumsum(gb, axis=1)  # inclusive cumsum (B,L,H)
        # inter-chunk: y += exp(c_t) · qᵀ S_in
        y_inter = jnp.einsum("blhk,bhkv->blhv", qb * jnp.exp(c)[..., None], state)
        # intra-chunk: decay matrix exp(c_t − c_s), s ≤ t
        dmat = c[:, :, None, :] - c[:, None, :, :]  # (B, t, s, H)
        tri = jnp.tril(jnp.ones((L, L), bool))
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        att = jnp.einsum("blhk,bmhk->blmh", qb, kb) * jnp.exp(dmat)
        y_intra = jnp.einsum("blmh,bmhv->blhv", att, vb)
        # state out: S = exp(c_L) S_in + Σ_s exp(c_L − c_s) k_s v_sᵀ
        cL = c[:, -1, :]  # (B,H)
        carry_decay = jnp.exp(cL)[:, :, None, None]
        k_decay = jnp.exp(cL[:, None, :] - c)  # (B,L,H)
        state_new = carry_decay * state + jnp.einsum(
            "blhk,blhv->bhkv", kb * k_decay[..., None], vb)
        return state_new, y_inter + y_intra

    state, ys = jax.lax.scan(
        one_chunk, S0,
        (qc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1),
         gc.swapaxes(0, 1)),
    )
    y = ys.swapaxes(0, 1).reshape(B, nc * L, H, dv)[:, :S - 0 if not pad else S]
    y = y[:, :S]
    return y.astype(v.dtype), state


def gla_step(
    q: jax.Array,  # (B, H, dk)
    k: jax.Array,  # (B, H, dk)
    v: jax.Array,  # (B, H, dv)
    log_g: jax.Array,  # (B, H)
    state: jax.Array,  # (B, H, dk, dv)
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent update (decode path). O(dk·dv) per head."""
    f32 = jnp.float32
    decay = jnp.exp(log_g.astype(f32))[..., None, None]
    state_new = decay * state.astype(f32) + jnp.einsum(
        "bhk,bhv->bhkv", k.astype(f32), v.astype(f32))
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(f32), state_new)
    return y.astype(v.dtype), state_new
