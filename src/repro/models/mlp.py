"""Dense MLP variants: SwiGLU (llama-family), GeGLU (gemma), GELU."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.dist.sharding import constrain
from repro.models.common import ParamDef, dtype_of, fan_in_init

__all__ = ["mlp_defs", "mlp"]


def mlp_defs(cfg: ModelConfig, d_ff: int = 0) -> Dict[str, ParamDef]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    pdt = dtype_of(cfg.param_dtype)
    gated = cfg.activation in ("swiglu", "geglu")
    defs = {
        "wi": ParamDef((d, ff), ("embed_fsdp", "ff"), fan_in_init(0), pdt),
        "wo": ParamDef((ff, d), ("ff", "embed_fsdp"), fan_in_init(0), pdt),
    }
    if gated:
        defs["wg"] = ParamDef((d, ff), ("embed_fsdp", "ff"), fan_in_init(0), pdt)
    return defs


def _act(name: str, g: jax.Array) -> jax.Array:
    if name in ("swiglu", "silu"):
        return jax.nn.silu(g)
    if name in ("geglu", "gelu"):
        return jax.nn.gelu(g, approximate=True)
    if name == "relu":
        return jax.nn.relu(g)
    raise ValueError(f"unknown activation {name!r}")


def mlp(params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    cdt = dtype_of(cfg.compute_dtype)
    x = x.astype(cdt)
    h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(cdt))
    if "wg" in params:
        g = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(cdt))
        h = _act(cfg.activation, g) * h
    else:
        h = _act(cfg.activation, h)
    h = constrain(h, "batch", "seq", "ff")
    out = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(cdt))
    return constrain(out, "batch", "seq_res", "embed")
