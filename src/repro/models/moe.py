"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch follows the GShard/Mesh-TensorFlow einsum formulation **with token
groups**: tokens are split into groups of ``group_size``; within each group a
token is routed to at most ``experts_per_token`` experts and each expert
accepts at most ``capacity = group_size·K·cf/E`` tokens from the group.  The
(group, tokens, experts, capacity) one-hot dispatch tensors stay O(group²)
instead of O(T²), which is what makes 65k-token-per-device batches feasible —
and ``group_size`` becomes a real configuration knob (ACTS tunes it).

Compute scales with *active* parameters (top-k × capacity_factor), not with
E — the honest cost model for the roofline.  Sharding experts over the
"model" mesh axis turns the dispatch einsums into all-to-all-style
collectives, matching production expert parallelism.  Overflowed tokens are
dropped; the router carries a GShard-style load-balancing auxiliary loss.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.dist.sharding import constrain
from repro.models.common import ParamDef, dtype_of, fan_in_init, normal_init

__all__ = ["moe_defs", "moe_ffn", "router_capacity"]


def moe_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    assert cfg.moe is not None
    d, E, ff = cfg.d_model, cfg.moe.n_experts, cfg.moe.d_ff
    pdt = dtype_of(cfg.param_dtype)
    gated = cfg.activation in ("swiglu", "geglu")
    defs = {
        "router": ParamDef((d, E), ("embed", None), normal_init(0.02), jnp.float32),
        "wi": ParamDef((E, d, ff), ("experts", "embed_fsdp", "expert_ff"),
                       fan_in_init(1), pdt),
        "wo": ParamDef((E, ff, d), ("experts", "expert_ff", "embed_fsdp"),
                       fan_in_init(1), pdt),
    }
    if gated:
        defs["wg"] = ParamDef((E, d, ff), ("experts", "embed_fsdp", "expert_ff"),
                              fan_in_init(1), pdt)
    return defs


def router_capacity(group_size: int, n_experts: int, top_k: int,
                    capacity_factor: float) -> int:
    cap = int(group_size * top_k * capacity_factor / n_experts)
    return max(cap, top_k)


def moe_ffn(
    params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig,
    group_size: int = 4096,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    assert cfg.moe is not None
    spec = cfg.moe
    B, S, d = x.shape
    E, K = spec.n_experts, spec.experts_per_token
    Tg = min(group_size, S)
    if S % Tg:
        # fall back to one group per sequence remainder-free split
        Tg = S
    G = B * (S // Tg)
    C = router_capacity(Tg, E, K, spec.capacity_factor)
    cdt = dtype_of(cfg.compute_dtype)

    xg = x.reshape(G, Tg, d)  # batch-major: group dim inherits batch sharding
    xg = constrain(xg, "batch", None, "embed")
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tg, E)

    # top-k gates, renormalized over the selected experts (Mixtral-style)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) inside its expert queue, within the group
    sel = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)  # (G, Tg, K, E)
    flat = sel.reshape(G, Tg * K, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat).reshape(G, Tg, K, E)
    pos = (pos_in_expert * sel).sum(-1)  # (G, Tg, K)
    keep = pos < C
    gate_vals = gate_vals * keep

    # dispatch / combine tensors: (G, Tg, E, C)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=cdt)[..., :C]
    disp = jnp.einsum("gtke,gtkc->gtec", sel.astype(cdt), pos_oh)
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", sel.astype(jnp.float32),
                      pos_oh.astype(jnp.float32),
                      gate_vals.astype(jnp.float32)).astype(cdt)

    xe = jnp.einsum("gtec,gtd->gecd", disp, xg.astype(cdt))  # (G, E, C, d)
    xe = constrain(xe, "batch", "experts", "cap", "embed")
    h = jnp.einsum("gecd,edf->gecf", xe, params["wi"].astype(cdt))
    if "wg" in params:
        g = jnp.einsum("gecd,edf->gecf", xe, params["wg"].astype(cdt))
        h = (jax.nn.silu(g) if cfg.activation == "swiglu"
             else jax.nn.gelu(g, approximate=True)) * h
    else:
        h = jax.nn.gelu(h, approximate=True)
    h = constrain(h, "batch", "experts", "cap", "expert_ff")
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(cdt))
    out = jnp.einsum("gecd,gtec->gtd", ye, comb).reshape(B, S, d)

    # GShard/Switch load-balance auxiliary loss
    me = probs.mean((0, 1))  # mean router prob per expert
    ce = sel[:, :, 0, :].astype(jnp.float32).mean((0, 1))  # top-1 fraction
    aux = E * jnp.sum(me * ce)
    return constrain(out, "batch", "seq_res", "embed"), aux.astype(jnp.float32)
