"""Mamba2 (SSD) block — state-space duality on top of the GLA core.

Follows the minimal Mamba2 formulation:

    [z | x | B | C | dt] = in_proj(u)
    x,B,C <- causal depthwise conv (k=4) + SiLU
    dt = softplus(dt_raw + dt_bias);  g = -exp(A_log) · dt   (per head)
    h_t = exp(g_t)·h_{t-1} + dt_t·B_t x_tᵀ ;  y_t = C_tᵀ h_t + D·x_t
    out = out_proj( RMSNorm(y) * SiLU(z) )

B/C are shared across heads (single group), x is split into heads of size
``head_dim = d_inner / ssm_heads``; the recurrence is ``chunked_gla`` with
q=C, k=B, v=dt·x.  Decode keeps a (conv window, state) cache — O(1) per
token, which is why the 500k-token decode cell runs on SSM archs.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.dist.sharding import constrain
from repro.models.common import (
    ParamDef,
    dtype_of,
    fan_in_init,
    normal_init,
    ones_init,
    rms_norm,
    zeros_init,
)
from repro.models.gla import chunked_gla, gla_step


def _gla(cfg, q, k, v, log_g):
    """Chunked-GLA dispatch: pure-jnp core or the Pallas TPU kernel."""
    if cfg.gla_impl == "pallas":
        from repro.kernels.ops import gla as gla_kernel

        return gla_kernel(q, k, v, log_g, chunk=cfg.ssm_chunk)
    return chunked_gla(q, k, v, log_g, chunk=cfg.ssm_chunk)

__all__ = ["mamba2_defs", "mamba2_block", "mamba2_cache_defs", "mamba2_decode"]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    nh = cfg.ssm_heads or max(1, d_inner // 64)
    hd = d_inner // nh
    ds = cfg.ssm_state
    return d_inner, nh, hd, ds


def mamba2_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    d_inner, nh, hd, ds = _dims(cfg)
    conv_dim = d_inner + 2 * ds
    pdt = dtype_of(cfg.param_dtype)

    def neg_A_init(key, shape, dtype):
        # A in [1, 16] -> A_log = log(A)
        a = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(a).astype(dtype)

    def dt_bias_init(key, shape, dtype):
        # dt in [1e-3, 1e-1] after softplus
        dt = jnp.exp(jax.random.uniform(key, shape, jnp.float32,
                                        jnp.log(1e-3), jnp.log(1e-1)))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)  # inv softplus

    return {
        "in_proj": ParamDef((d, 2 * d_inner + 2 * ds + nh),
                            ("embed_fsdp", "conv_dim"), fan_in_init(0), pdt),
        "conv_w": ParamDef((cfg.ssm_conv, conv_dim), (None, "conv_dim"),
                           normal_init(0.1), pdt),
        "conv_b": ParamDef((conv_dim,), ("conv_dim",), zeros_init(), pdt),
        "A_log": ParamDef((nh,), ("ssm_heads",), neg_A_init, jnp.float32),
        "D": ParamDef((nh,), ("ssm_heads",), ones_init(), jnp.float32),
        "dt_bias": ParamDef((nh,), ("ssm_heads",), dt_bias_init, jnp.float32),
        "norm_scale": ParamDef((d_inner,), (None,), ones_init(), jnp.float32),
        "out_proj": ParamDef((d_inner, d), ("conv_dim", "embed_fsdp"),
                             fan_in_init(0), pdt),
    }


def mamba2_cache_defs(cfg: ModelConfig, batch: int) -> Dict[str, ParamDef]:
    d_inner, nh, hd, ds = _dims(cfg)
    conv_dim = d_inner + 2 * ds
    return {
        "conv": ParamDef((batch, cfg.ssm_conv - 1, conv_dim),
                         ("batch", None, "conv_dim"), zeros_init(), jnp.float32),
        "state": ParamDef((batch, nh, ds, hd),
                          ("batch", "ssm_heads", "ssm_state", None),
                          zeros_init(), jnp.float32),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    d_inner, nh, hd, ds = _dims(cfg)
    z, xBC, dt_raw = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * ds], axis=-1)
    return z, xBC, dt_raw


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via k shifted adds. xBC: (B, S, D); w: (k, D)."""
    kk = w.shape[0]
    xp = jnp.pad(xBC, ((0, 0), (kk - 1, 0), (0, 0)))
    S = xBC.shape[1]
    out = sum(xp[:, j:j + S, :] * w[j] for j in range(kk)) + b
    return jax.nn.silu(out)


def _ssd_inputs(cfg: ModelConfig, params, xBC, dt_raw):
    d_inner, nh, hd, ds = _dims(cfg)
    x, Bm, Cm = jnp.split(xBC, [d_inner, d_inner + ds], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))  # (..., nh)
    log_g = -jnp.exp(params["A_log"].astype(jnp.float32)) * dt
    return x, Bm, Cm, dt, log_g


def mamba2_block(
    params: Dict[str, jax.Array], u: jax.Array, cfg: ModelConfig
) -> jax.Array:
    """u: (B, S, d_model) -> (B, S, d_model). Full-sequence (train/prefill)."""
    B, S, d = u.shape
    d_inner, nh, hd, ds = _dims(cfg)
    cdt = dtype_of(cfg.compute_dtype)

    zxbcdt = jnp.einsum("bsd,dp->bsp", u.astype(cdt),
                        params["in_proj"].astype(cdt))
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv(xBC.astype(jnp.float32), params["conv_w"].astype(jnp.float32),
                       params["conv_b"].astype(jnp.float32))
    x, Bm, Cm, dt, log_g = _ssd_inputs(cfg, params, xBC, dt_raw)

    xh = x.reshape(B, S, nh, hd)
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, nh, ds))
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, nh, ds))
    v = xh * dt[..., None]
    y, _ = _gla(cfg, q, k, v, log_g)
    y = y + xh * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = constrain(y, "batch", "seq", "conv_dim")

    y = rms_norm(y, params["norm_scale"], cfg.norm_eps) * jax.nn.silu(
        z.astype(jnp.float32))
    out = jnp.einsum("bsp,pd->bsd", y.astype(cdt), params["out_proj"].astype(cdt))
    return constrain(out, "batch", "seq", "embed")


def mamba2_decode(
    params: Dict[str, jax.Array],
    u: jax.Array,  # (B, 1, d_model)
    cache: Dict[str, jax.Array],
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token recurrent step; O(1) state update."""
    B, S, d = u.shape
    assert S == 1
    d_inner, nh, hd, ds = _dims(cfg)
    cdt = dtype_of(cfg.compute_dtype)

    zxbcdt = jnp.einsum("bsd,dp->bsp", u.astype(cdt),
                        params["in_proj"].astype(cdt))
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC = xBC[:, 0].astype(jnp.float32)  # (B, conv_dim)

    # conv window update
    window = jnp.concatenate([cache["conv"], xBC[:, None, :]], axis=1)  # (B,k,D)
    w = params["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bkd,kd->bd", window, w) + params["conv_b"].astype(
        jnp.float32)
    conv_out = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    x, Bm, Cm, dt, log_g = _ssd_inputs(cfg, params, conv_out[:, None, :],
                                       dt_raw)
    xh = x[:, 0].reshape(B, nh, hd)
    q = jnp.broadcast_to(Cm[:, 0, None, :], (B, nh, ds))
    k = jnp.broadcast_to(Bm[:, 0, None, :], (B, nh, ds))
    v = xh * dt[:, 0, :, None]
    y, state = gla_step(q, k, v, log_g[:, 0], cache["state"])
    y = y + xh * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, d_inner)

    y = rms_norm(y, params["norm_scale"], cfg.norm_eps) * jax.nn.silu(
        z.astype(jnp.float32))
    out = jnp.einsum("bsp,pd->bsd", y.astype(cdt), params["out_proj"].astype(cdt))
    return out, {"conv": new_conv, "state": state}
