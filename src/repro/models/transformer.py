"""Model assembly: superblock scan, embeddings, losses, KV-cache decode.

Every architecture is a repeating *superblock* pattern (configs define it:
e.g. gemma3 = 5×swa + 1×attn; llama-vision = 4×attn + 1×cross; zamba2 =
9×mamba2 + shared + 9×mamba2).  The stack executes as ``lax.scan`` over
parameters stacked along a leading "layer" axis — O(superblock) HLO instead
of O(n_layers), which is what keeps 100-layer × 512-device compiles
tractable and is the production-correct choice on TPU.

Weight-shared blocks (Zamba2's shared attention) live *outside* the scanned
stack and are closure-captured, so every superblock invocation reuses the
same weights while keeping per-invocation KV caches (cache slots are keyed
by position, stacked under the scan).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig, ShapeSpec
from repro.dist.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import (
    ParamDef,
    abstract_params,
    count_def_params,
    cross_entropy_loss,
    dtype_of,
    init_params,
    normal_init,
    ones_init,
    param_specs,
    rms_norm,
    stack_defs,
)

__all__ = ["Model", "count_params", "model_defs"]

ATTN_KINDS = {"attn", "swa", "moe", "moe_swa", "dec", "shared"}


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------
def _norm_def(cfg: ModelConfig) -> ParamDef:
    return ParamDef((cfg.d_model,), (None,), ones_init(), jnp.float32)


def block_defs(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    if kind in ("attn", "swa"):
        return {
            "ln1": _norm_def(cfg),
            "attn": attn_mod.attention_defs(cfg),
            "ln2": _norm_def(cfg),
            "mlp": mlp_mod.mlp_defs(cfg),
        }
    if kind in ("moe", "moe_swa"):
        return {
            "ln1": _norm_def(cfg),
            "attn": attn_mod.attention_defs(cfg),
            "ln2": _norm_def(cfg),
            "moe": moe_mod.moe_defs(cfg),
        }
    if kind == "cross":
        return {
            "ln1": _norm_def(cfg),
            "xattn": attn_mod.attention_defs(cfg, cross=True),
            "ln2": _norm_def(cfg),
            "mlp": mlp_mod.mlp_defs(cfg),
            "gate": ParamDef((1,), (None,), lambda k, s, d: jnp.zeros(s, d),
                             jnp.float32),
        }
    if kind == "dec":
        return {
            "ln1": _norm_def(cfg),
            "attn": attn_mod.attention_defs(cfg),
            "ln_x": _norm_def(cfg),
            "xattn": attn_mod.attention_defs(cfg, cross=True),
            "ln2": _norm_def(cfg),
            "mlp": mlp_mod.mlp_defs(cfg),
        }
    if kind == "mamba2":
        return {"ln1": _norm_def(cfg), "mixer": ssm_mod.mamba2_defs(cfg)}
    if kind == "mlstm":
        return {"ln1": _norm_def(cfg), "mixer": xlstm_mod.mlstm_defs(cfg)}
    if kind == "slstm":
        return {"ln1": _norm_def(cfg), "mixer": xlstm_mod.slstm_defs(cfg)}
    if kind == "shared":
        return {}  # weights live at the top level (model_defs)
    raise ValueError(f"unknown block kind {kind!r}")


def superblock_defs(cfg: ModelConfig, pattern: Tuple[str, ...]) -> Dict[str, Any]:
    return {f"{i}_{kind}": block_defs(cfg, kind)
            for i, kind in enumerate(pattern)}


def model_defs(cfg: ModelConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.padded_vocab
    pdt = dtype_of(cfg.param_dtype)
    defs: Dict[str, Any] = {
        "embed": ParamDef((V, d), ("vocab", "embed"), normal_init(0.02), pdt),
        "blocks": stack_defs(superblock_defs(cfg, cfg.superblock),
                             cfg.n_superblocks),
        "final_norm": _norm_def(cfg),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((d, V), ("embed_fsdp", "vocab"),
                                   normal_init(0.02), pdt)
    if "shared" in cfg.superblock:
        defs["shared"] = {
            "ln1": _norm_def(cfg),
            "attn": attn_mod.attention_defs(cfg),
            "ln2": _norm_def(cfg),
            "mlp": mlp_mod.mlp_defs(cfg),
        }
    if cfg.encoder:
        enc_sb = superblock_defs(cfg, cfg.encoder.superblock)
        n_enc_sb = cfg.encoder.n_layers // len(cfg.encoder.superblock)
        defs["encoder"] = {
            "blocks": stack_defs(enc_sb, n_enc_sb),
            "final_norm": _norm_def(cfg),
        }
    if cfg.frontend:
        defs["frontend_proj"] = ParamDef(
            (cfg.frontend_dim, d), (None, "embed"), normal_init(0.02), pdt)
    return defs


def count_params(cfg: ModelConfig) -> int:
    return count_def_params(model_defs(cfg))


# ---------------------------------------------------------------------------
# cache definitions
# ---------------------------------------------------------------------------
def cache_block_defs(cfg: ModelConfig, kind: str, batch: int,
                     max_seq: int) -> Dict[str, Any]:
    if kind in ("attn", "moe", "dec", "shared"):
        return attn_mod.init_attn_cache_defs(cfg, batch, max_seq)
    if kind in ("swa", "moe_swa"):
        return attn_mod.init_attn_cache_defs(cfg, batch, max_seq,
                                             window=cfg.window)
    if kind == "cross":
        return {}  # cross K/V recomputed from cached memory
    if kind == "mamba2":
        return ssm_mod.mamba2_cache_defs(cfg, batch)
    if kind == "mlstm":
        return xlstm_mod.mlstm_cache_defs(cfg, batch)
    if kind == "slstm":
        return xlstm_mod.slstm_cache_defs(cfg, batch)
    raise ValueError(f"unknown block kind {kind!r}")


def paged_cache_block_defs(cfg: ModelConfig, kind: str, n_groups: int,
                           group_tokens: int) -> Dict[str, Any]:
    """KV pool shapes for one block under the paged layout: requests own
    page *groups* instead of dense per-slot buffers.  Only dense-cache
    attention kinds are pageable (``Model.supports_continuous_batching``
    gates the rest to the wave runtime)."""
    if kind in ("attn", "moe", "dec", "shared"):
        from repro.kernels.paged_attention import POOL_AXES
        from repro.models.common import zeros_init

        KV, Dh = cfg.n_kv_heads, cfg.head_dim_
        dt = dtype_of(cfg.compute_dtype)
        # POOL_AXES is the paged kernel's layout contract: only the
        # kv_heads axis may shard (model-axis TP); groups stay whole so
        # the page-table index_map addresses every shard identically.
        return {
            "k": ParamDef((n_groups, group_tokens, KV, Dh), POOL_AXES,
                          zeros_init(), dt),
            "v": ParamDef((n_groups, group_tokens, KV, Dh), POOL_AXES,
                          zeros_init(), dt),
        }
    if kind == "cross":
        return {}  # cross K/V recomputed from cached memory
    raise ValueError(f"block kind {kind!r} has no paged cache layout")


def paged_cache_defs(cfg: ModelConfig, n_groups: int,
                     group_tokens: int) -> Dict[str, Any]:
    sb = {f"{i}_{kind}": paged_cache_block_defs(cfg, kind, n_groups,
                                                group_tokens)
          for i, kind in enumerate(cfg.superblock)}
    return {"blocks": stack_defs(sb, cfg.n_superblocks)}


def cache_defs(cfg: ModelConfig, batch: int, max_seq: int) -> Dict[str, Any]:
    sb = {f"{i}_{kind}": cache_block_defs(cfg, kind, batch, max_seq)
          for i, kind in enumerate(cfg.superblock)}
    defs: Dict[str, Any] = {
        "blocks": stack_defs(sb, cfg.n_superblocks),
        "index": ParamDef((), (), lambda k, s, d: jnp.zeros(s, d), jnp.int32),
    }
    if cfg.frontend or cfg.encoder:
        n_mem = cfg.frontend_tokens if not cfg.encoder else cfg.frontend_tokens
        defs["memory"] = ParamDef(
            (batch, n_mem, cfg.d_model), ("batch", None, "embed"),
            lambda k, s, d: jnp.zeros(s, d), dtype_of(cfg.compute_dtype))
    return defs


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------
def _apply_block_full(kind: str, p: Dict[str, Any], x: jax.Array,
                      ctx: Dict[str, Any], cfg: ModelConfig
                      ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence (train / prefill-without-cache) application."""
    aux = jnp.zeros((), jnp.float32)
    positions = ctx["positions"]
    causal = ctx.get("causal", True)
    if kind == "shared":
        p = ctx["shared_params"]
        kind = "attn"

    if kind in ("attn", "swa", "moe", "moe_swa"):
        window = cfg.window if kind in ("swa", "moe_swa") else 0
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, _ = attn_mod.self_attention(
            p["attn"], h, cfg=cfg, positions=positions, window=window,
            impl=None if causal else "dense", causal=causal)
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind in ("moe", "moe_swa"):
            y, aux = moe_mod.moe_ffn(p["moe"], h, cfg,
                                     group_size=ctx.get("moe_group", 4096))
        else:
            y = mlp_mod.mlp(p["mlp"], h, cfg)
        return x + y, aux

    if kind == "cross":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, _ = attn_mod.cross_attention(p["xattn"], h, ctx["memory"], cfg=cfg)
        x = x + jnp.tanh(p["gate"]).astype(x.dtype) * y
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp_mod.mlp(p["mlp"], h, cfg), aux

    if kind == "dec":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, _ = attn_mod.self_attention(p["attn"], h, cfg=cfg,
                                       positions=positions)
        x = x + y
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        y, _ = attn_mod.cross_attention(p["xattn"], h, ctx["memory"], cfg=cfg)
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp_mod.mlp(p["mlp"], h, cfg), aux

    if kind in ("mamba2", "mlstm", "slstm"):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        fn = {"mamba2": ssm_mod.mamba2_block, "mlstm": xlstm_mod.mlstm_block,
              "slstm": xlstm_mod.slstm_block}[kind]
        return x + fn(p["mixer"], h, cfg), aux

    raise ValueError(f"unknown block kind {kind!r}")


def _apply_block_decode(kind: str, p: Dict[str, Any], x: jax.Array,
                        cache: Dict[str, Any], ctx: Dict[str, Any],
                        cfg: ModelConfig
                        ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Single-token decode with cache update."""
    positions = ctx["positions"]
    index = ctx["index"]
    if kind == "shared":
        p = ctx["shared_params"]
        kind = "attn"

    if kind in ("attn", "swa", "moe", "moe_swa"):
        window = cfg.window if kind in ("swa", "moe_swa") else 0
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, new_kv = attn_mod.self_attention(
            p["attn"], h, cfg=cfg, positions=positions, window=window,
            cache=cache, cache_index=index,
            page_table=ctx.get("page_table"))
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if kind in ("moe", "moe_swa"):
            y, _ = moe_mod.moe_ffn(p["moe"], h, cfg)
        else:
            y = mlp_mod.mlp(p["mlp"], h, cfg)
        return x + y, new_kv

    if kind == "cross":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, _ = attn_mod.cross_attention(p["xattn"], h, ctx["memory"], cfg=cfg)
        x = x + jnp.tanh(p["gate"]).astype(x.dtype) * y
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp_mod.mlp(p["mlp"], h, cfg), cache

    if kind == "dec":
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        y, new_kv = attn_mod.self_attention(
            p["attn"], h, cfg=cfg, positions=positions, cache=cache,
            cache_index=index, page_table=ctx.get("page_table"))
        x = x + y
        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
        y, _ = attn_mod.cross_attention(p["xattn"], h, ctx["memory"], cfg=cfg)
        x = x + y
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp_mod.mlp(p["mlp"], h, cfg), new_kv

    if kind in ("mamba2", "mlstm", "slstm"):
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        fn = {"mamba2": ssm_mod.mamba2_decode, "mlstm": xlstm_mod.mlstm_decode,
              "slstm": xlstm_mod.slstm_decode}[kind]
        y, new_cache = fn(p["mixer"], h, cache, cfg)
        return x + y, new_cache

    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# stack execution (scan over superblocks)
# ---------------------------------------------------------------------------
def _remat_wrap(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "full":
        return jax.checkpoint(fn)
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    raise ValueError(f"unknown remat policy {remat!r}")


def _stack_forward(blocks_params, x, ctx, cfg: ModelConfig,
                   pattern: Tuple[str, ...], remat: str = "none"):
    def superblock(x, sb_params):
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pattern):
            x, a = _apply_block_full(kind, sb_params[f"{i}_{kind}"], x, ctx, cfg)
            aux = aux + a
        return x, aux

    wrapped = _remat_wrap(superblock, remat)

    def body(carry, sb_params):
        x, aux = carry
        x, a = wrapped(x, sb_params)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               blocks_params)
    return x, aux


def _stack_decode(blocks_params, blocks_cache, x, ctx, cfg: ModelConfig):
    pattern = cfg.superblock

    def body(x, inputs):
        sb_params, sb_cache = inputs
        new_sb_cache = {}
        for i, kind in enumerate(pattern):
            key = f"{i}_{kind}"
            x, new_sb_cache[key] = _apply_block_decode(
                kind, sb_params[key], x, sb_cache[key], ctx, cfg)
        return x, new_sb_cache

    x, new_cache = jax.lax.scan(body, x, (blocks_params, blocks_cache))
    return x, new_cache


# ---------------------------------------------------------------------------
# the Model
# ---------------------------------------------------------------------------
class Model:
    """Pure-function model bound to a ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._defs = model_defs(cfg)

    # --- parameters -----------------------------------------------------
    def param_defs(self):
        return self._defs

    def init(self, rng: jax.Array):
        return init_params(self._defs, rng)

    def abstract_params(self):
        return abstract_params(self._defs)

    def param_specs(self, rules, mesh):
        return param_specs(self._defs, rules, mesh)

    # --- embedding / head -------------------------------------------------
    def _embed(self, params, tokens):
        cdt = dtype_of(self.cfg.compute_dtype)
        x = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
        x = x * jnp.asarray(math.sqrt(self.cfg.d_model), cdt)
        return constrain(x, "batch", "seq", "embed")

    def _logits(self, params, x):
        cdt = dtype_of(self.cfg.compute_dtype)
        if self.cfg.tie_embeddings:
            w = params["embed"].astype(cdt)  # (V, d)
            logits = jnp.einsum("bsd,vd->bsv", x.astype(cdt), w)
        else:
            logits = jnp.einsum("bsd,dv->bsv", x.astype(cdt),
                                params["lm_head"].astype(cdt))
        return constrain(logits, "batch", "seq", "vocab")

    def _memory(self, params, batch) -> Optional[jax.Array]:
        """Projected cross-attention memory from the modality frontend stub
        and/or the encoder."""
        cfg = self.cfg
        if not (cfg.frontend or cfg.encoder):
            return None
        embeds = batch["frontend_embeds"]  # (B, n_tok, frontend_dim) STUB input
        cdt = dtype_of(cfg.compute_dtype)
        mem = jnp.einsum("bnf,fd->bnd", embeds.astype(cdt),
                         params["frontend_proj"].astype(cdt))
        if cfg.encoder:
            enc_pos = jnp.arange(mem.shape[1])
            ctx = {"positions": enc_pos, "causal": False, "memory": None}
            n_enc_sb = cfg.encoder.n_layers // len(cfg.encoder.superblock)
            mem, _ = _stack_forward(params["encoder"]["blocks"], mem, ctx, cfg,
                                    cfg.encoder.superblock)
            mem = rms_norm(mem, params["encoder"]["final_norm"], cfg.norm_eps)
        return constrain(mem, "batch", None, "embed")

    # --- full-sequence forward (train) -----------------------------------
    def forward(self, params, batch, *, remat: str = "none",
                moe_group: int = 4096):
        """batch: tokens (B,S) [+ frontend_embeds] -> (hidden, aux_loss)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        ctx = {
            "positions": jnp.arange(S),
            "memory": self._memory(params, batch),
            "causal": True,
            "moe_group": moe_group,
            "shared_params": params.get("shared"),
        }
        x, aux = _stack_forward(params["blocks"], x, ctx, cfg, cfg.superblock,
                                remat=remat)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux

    def loss(self, params, batch, *, remat: str = "none",
             loss_chunk: int = 0, moe_group: int = 4096,
             aux_weight: float = 0.01):
        """Causal LM loss. ``loss_chunk > 0`` computes the cross-entropy in
        sequence chunks so the full (B,S,V) logits tensor never materializes."""
        cfg = self.cfg
        x, aux = self.forward(params, batch, remat=remat, moe_group=moe_group)
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = (labels >= 0).astype(jnp.float32)
        labels = jnp.maximum(labels, 0)
        V = cfg.padded_vocab
        vocab_valid = (jnp.arange(V) < cfg.vocab_size)

        def chunk_loss(x_c, labels_c, mask_c):
            logits = self._logits(params, x_c)
            logits = jnp.where(vocab_valid[None, None, :], logits, -1e30)
            lg = logits.astype(jnp.float32)
            logz = jax.scipy.special.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, labels_c[..., None], axis=-1)[..., 0]
            nll = (logz - gold) * mask_c
            acc = ((lg.argmax(-1) == labels_c) * mask_c)
            return nll.sum(), acc.sum()

        if loss_chunk and x.shape[1] > loss_chunk and x.shape[1] % loss_chunk == 0:
            nchunk = x.shape[1] // loss_chunk
            xs = (x.reshape(x.shape[0], nchunk, loss_chunk, -1).swapaxes(0, 1),
                  labels.reshape(labels.shape[0], nchunk, loss_chunk).swapaxes(0, 1),
                  mask.reshape(mask.shape[0], nchunk, loss_chunk).swapaxes(0, 1))

            def body(carry, inp):
                nll, acc = chunk_loss(*inp)
                return (carry[0] + nll, carry[1] + acc), None

            (nll_sum, acc_sum), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
                xs)
        else:
            nll_sum, acc_sum = chunk_loss(x, labels, mask)

        denom = jnp.maximum(mask.sum(), 1.0)
        loss = nll_sum / denom
        total = loss + aux_weight * aux
        metrics = {"loss": loss, "aux_loss": aux, "accuracy": acc_sum / denom,
                   "tokens": denom}
        return total, metrics

    # --- serving ----------------------------------------------------------
    def cache_defs(self, batch: int, max_seq: int):
        return cache_defs(self.cfg, batch, max_seq)

    def init_cache(self, batch: int, max_seq: int):
        return init_params(self.cache_defs(batch, max_seq),
                           jax.random.PRNGKey(0))

    def abstract_cache(self, batch: int, max_seq: int):
        return abstract_params(self.cache_defs(batch, max_seq))

    def cache_specs(self, batch: int, max_seq: int, rules, mesh):
        return param_specs(self.cache_defs(batch, max_seq), rules, mesh)

    def prefill(self, params, batch, cache):
        """Run the prompt through the model, filling the KV caches.

        Returns (last-token logits, cache).  Attention runs in full-sequence
        mode (blocked/local), and K/V are written into the cache buffers —
        ring-rolled for sliding-window blocks.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = self._embed(params, tokens)
        memory = self._memory(params, batch)
        ctx = {
            "positions": jnp.arange(S),
            "memory": memory,
            "causal": True,
            "shared_params": params.get("shared"),
        }

        pattern = cfg.superblock

        def body(x, inputs):
            sb_params, sb_cache = inputs
            new_sb = {}
            for i, kind in enumerate(pattern):
                key = f"{i}_{kind}"
                p = sb_params[key] if kind != "shared" else ctx["shared_params"]
                akind = "attn" if kind == "shared" else kind
                if akind in ("attn", "swa", "moe", "moe_swa", "dec"):
                    window = cfg.window if akind in ("swa", "moe_swa") else 0
                    h = rms_norm(x, p["ln1"], cfg.norm_eps)
                    q, k, v = attn_mod._project_qkv(p["attn"], h, None, cfg)
                    from repro.models.common import apply_rope, rope_freqs

                    cos, sin = rope_freqs(ctx["positions"], cfg.head_dim_,
                                          cfg.rope_theta)
                    q = apply_rope(q, cos, sin)
                    k = apply_rope(k, cos, sin)
                    y = attn_mod.attend(q, k, v, cfg=cfg, causal=True,
                                        window=window)
                    y = attn_mod._mask_padded_heads(y, cfg)
                    cdt = dtype_of(cfg.compute_dtype)
                    y = jnp.einsum("bshk,hkd->bsd", y.astype(cdt),
                                   p["attn"]["wo"].astype(cdt))
                    x = x + y
                    new_sb[key] = _write_prefill_kv(
                        sb_cache[key], k, v, window, S)
                    if akind == "dec":
                        h = rms_norm(x, p["ln_x"], cfg.norm_eps)
                        y, _ = attn_mod.cross_attention(p["xattn"], h, memory,
                                                        cfg=cfg)
                        x = x + y
                    h = rms_norm(x, p["ln2"], cfg.norm_eps)
                    if akind in ("moe", "moe_swa"):
                        y, _ = moe_mod.moe_ffn(p["moe"], h, cfg)
                    else:
                        y = mlp_mod.mlp(p["mlp"], h, cfg)
                    x = x + y
                elif akind == "cross":
                    x, _ = _apply_block_full("cross", p, x, ctx, cfg)
                    new_sb[key] = sb_cache[key]
                else:  # recurrent blocks: run full-seq then recompute state
                    x, new_sb[key] = _prefill_recurrent(akind, p, x, sb_cache[key],
                                                        cfg)
            return x, new_sb

        x, new_blocks = jax.lax.scan(body, x, (params["blocks"],
                                               cache["blocks"]))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x[:, -1:, :])
        new_cache = dict(cache, blocks=new_blocks,
                         index=jnp.asarray(S, jnp.int32))
        if memory is not None and "memory" in cache:
            new_cache["memory"] = memory.astype(cache["memory"].dtype)
        return logits, new_cache

    @property
    def supports_chunked_prefill(self) -> bool:
        """Whether the prompt can be prefilled in segments through the cache.

        Chunked prefill appends multi-token segments via the cached-attention
        path, which is exact for dense-cache attention blocks (attn / moe /
        dec / cross / shared).  Sliding-window blocks write a ring buffer
        whose multi-token append would wrap incorrectly, and recurrent
        mixers (mamba2 / mlstm / slstm) recompute their state from the full
        sequence — both prefill whole prompts instead.

        MoE blocks are chunk-exact only with drop-free router capacity
        (``capacity_factor * experts_per_token >= n_experts``): capacity-
        bound routing drops tokens per routing *group*, and the grouping
        differs between whole-prompt and per-chunk prefill, so a capacity-
        bound MoE would generate different tokens under chunking.
        """
        kinds = set(self.cfg.superblock)
        if not kinds <= {"attn", "moe", "dec", "cross", "shared"}:
            return False
        if "moe" in kinds:
            moe = self.cfg.moe
            if moe is None or (moe.capacity_factor * moe.experts_per_token
                               < moe.n_experts):
                return False
        return True

    def prefill_chunk(self, params, batch, cache):
        """Append one prompt segment to the KV caches (chunked prefill).

        ``batch["tokens"]``: (B, C) — the next C prompt tokens;
        ``cache["index"]`` tokens are already resident.  For
        frontend/encoder models, ``frontend_embeds`` MUST ride with the
        FIRST chunk: the projected memory is computed once, carried in the
        cache, and reused by later chunks — a first chunk without it would
        silently attend to the cache's zero-initialized memory buffer
        (``ServeEngine.generate`` validates this up front; direct callers
        own the contract, since the chunk index is traced and cannot be
        checked here).  Returns (last-token logits, cache) —
        value-equivalent to whole-prompt ``prefill`` for stacks where
        ``supports_chunked_prefill`` holds, so the serve engine's
        ``prefill_chunk`` knob changes *timing*, not tokens.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        B, C = tokens.shape
        index = cache["index"]
        if "frontend_embeds" in batch:
            memory = self._memory(params, batch)
        else:
            memory = cache.get("memory")
        x = self._embed(params, tokens)
        ctx = {
            "positions": index + jnp.arange(C),
            "index": index,
            "memory": memory,
            "shared_params": params.get("shared"),
        }
        x, new_blocks = _stack_decode(params["blocks"], cache["blocks"], x,
                                      ctx, cfg)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x[:, -1:, :])
        new_cache = dict(cache, blocks=new_blocks, index=index + C)
        if memory is not None and "memory" in cache:
            new_cache["memory"] = memory.astype(cache["memory"].dtype)
        return logits, new_cache

    def decode_step(self, params, tokens, cache):
        """One decode step: tokens (B, 1) + cache -> (logits, new cache)."""
        cfg = self.cfg
        B = tokens.shape[0]
        index = cache["index"]
        x = self._embed(params, tokens)
        positions = jnp.broadcast_to(index, (B, 1))
        ctx = {
            "positions": positions,
            "index": index,
            "memory": cache.get("memory"),
            "shared_params": params.get("shared"),
        }
        x, new_blocks = _stack_decode(params["blocks"], cache["blocks"], x,
                                      ctx, cfg)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)
        new_cache = dict(cache, blocks=new_blocks, index=index + 1)
        return logits, new_cache

    # --- continuous batching ----------------------------------------------
    @property
    def supports_continuous_batching(self) -> bool:
        """Continuous batching decodes slots at per-slot cache lengths and
        prefills admitted requests through the chunked-append path, so it
        is exact for precisely the stacks chunked prefill is exact for
        (sliding-window rings and recurrent mixers keep the wave loop)."""
        return self.supports_chunked_prefill

    def init_paged_cache(self, n_groups: int, group_tokens: int):
        """KV pools for the paged layout: ``{"blocks": ...}`` with
        (n_groups, group_tokens, KV, D) pools per attention block.  The
        page table and per-slot lengths live with the engine — group 0 is
        the allocator's scratch group (idle decode lanes write there)."""
        return init_params(paged_cache_defs(self.cfg, n_groups,
                                            group_tokens),
                           jax.random.PRNGKey(0))

    def paged_cache_specs(self, n_groups: int, group_tokens: int, rules,
                          mesh):
        """PartitionSpecs matching ``init_paged_cache``'s tree: page
        groups stay whole per device, the KV-head axis follows the rule
        table's model-axis split (``POOL_AXES``)."""
        return param_specs(paged_cache_defs(self.cfg, n_groups,
                                            group_tokens), rules, mesh)

    def decode_step_multi(self, params, tokens, cache, lengths,
                          page_table=None):
        """Continuous-batching decode: C token(s) per slot, each slot at
        its OWN cache length.

        ``tokens``: (B, C); ``lengths``: (B,) tokens already resident per
        slot.  C == 1 is the ordinary decode step; C > 1 is the
        speculative-verify dispatch — column i of slot b sits at position
        ``lengths[b] + i``, and the causal per-slot masks make each
        column's logits exactly what C successive single-token steps
        would produce, so acceptance can compare draft tokens against
        bit-stable verified ones.  Dense layout (``page_table=None``):
        ``cache["blocks"]`` are the usual per-slot buffers, appended by
        scatter.  Paged layout: the blocks are pools and ``page_table``
        (B, MAXG) maps each slot's logical groups to physical ones.
        Idle/masked slots are decoded too (their outputs are discarded by
        the engine) — slot math is row-independent, so live slots' tokens
        are identical whatever the rest of the batch is doing.
        """
        cfg = self.cfg
        lengths = jnp.asarray(lengths, jnp.int32)
        x = self._embed(params, tokens)
        C = tokens.shape[1]
        ctx = {
            "positions": lengths[:, None] + jnp.arange(C,
                                                       dtype=jnp.int32)[None],
            "index": lengths,
            "memory": cache.get("memory"),
            "shared_params": params.get("shared"),
            "page_table": page_table,
        }
        x, new_blocks = _stack_decode(params["blocks"], cache["blocks"], x,
                                      ctx, cfg)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits, dict(cache, blocks=new_blocks)

    def prefill_chunk_slot(self, params, batch, cache, slot, length):
        """Append one prompt chunk for ONE slot of a batched dense cache.

        ``batch["tokens"]``: (1, C).  Slices the slot's view out of every
        per-slot buffer, runs the exact ``prefill_chunk`` path on it, and
        writes the view back — so admission-time prefill reuses the
        chunked-prefill math byte for byte while the other slots keep
        decoding between chunks.  Returns (last-token logits, cache).
        """
        view = {"blocks": jax.tree_util.tree_map(
            lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1),
            cache["blocks"]),
            "index": jnp.asarray(length, jnp.int32)}
        if "memory" in cache:
            view["memory"] = jax.lax.dynamic_slice_in_dim(
                cache["memory"], slot, 1, axis=0)
        logits, new_view = self.prefill_chunk(params, batch, view)
        new_cache = dict(cache, blocks=jax.tree_util.tree_map(
            lambda l, nv: jax.lax.dynamic_update_slice_in_dim(
                l, nv.astype(l.dtype), slot, axis=1),
            cache["blocks"], new_view["blocks"]))
        if "memory" in cache:
            new_cache["memory"] = jax.lax.dynamic_update_slice_in_dim(
                cache["memory"],
                new_view["memory"].astype(cache["memory"].dtype),
                slot, axis=0)
        return logits, new_cache

    def prefill_chunk_slot_paged(self, params, batch, cache, page_row,
                                 length, slot=None):
        """Paged-layout slot prefill: gather, exact chunk, scatter back.

        The slot's pages are gathered (through ``page_row``, its page-
        table row) into a dense single-request view, the ordinary
        ``prefill_chunk`` runs on that view, and the C freshly-appended
        positions are scattered back into the pools.  Unallocated logical
        groups point at the scratch group; their garbage is masked by the
        chunk path's length-based attention mask, so the gathered tail is
        inert.  ``slot`` addresses the engine's dense memory buffer for
        frontend/encoder models.
        """
        length = jnp.asarray(length, jnp.int32)
        C = batch["tokens"].shape[1]

        def gather(l):
            g = l[:, page_row]  # (n_sb, MAXG, T, KV, D)
            n_sb, maxg, T = g.shape[:3]
            return g.reshape(n_sb, 1, maxg * T, *g.shape[3:])

        view = {"blocks": jax.tree_util.tree_map(gather, cache["blocks"]),
                "index": length}
        if "memory" in cache:
            view["memory"] = jax.lax.dynamic_slice_in_dim(
                cache["memory"], slot, 1, axis=0)
        logits, new_view = self.prefill_chunk(params, batch, view)

        pos = length + jnp.arange(C)

        def scatter(l, nv):
            T = l.shape[2]
            seg = jax.lax.dynamic_slice_in_dim(nv, length, C, axis=2)[:, 0]
            return l.at[:, page_row[pos // T], pos % T].set(
                seg.astype(l.dtype))

        new_cache = dict(cache, blocks=jax.tree_util.tree_map(
            scatter, cache["blocks"], new_view["blocks"]))
        if "memory" in cache:
            new_cache["memory"] = jax.lax.dynamic_update_slice_in_dim(
                cache["memory"],
                new_view["memory"].astype(cache["memory"].dtype),
                slot, axis=0)
        return logits, new_cache


def _write_prefill_kv(cache_slice, k, v, window, S):
    """Write prefill K/V into a cache buffer (ring-rolled for SWA)."""
    kb, vb = cache_slice["k"], cache_slice["v"]
    Sbuf = kb.shape[1]
    if window and Sbuf == window:
        if S >= window:
            # slot(p) = p % W for the last W positions => roll by S % W
            k_last, v_last = k[:, -window:], v[:, -window:]
            shift = S % window
        else:
            # positions 0..S-1 already sit at slots 0..S-1
            k_last = jnp.pad(k, ((0, 0), (0, window - S), (0, 0), (0, 0)))
            v_last = jnp.pad(v, ((0, 0), (0, window - S), (0, 0), (0, 0)))
            shift = 0
        kb = jnp.roll(k_last.astype(kb.dtype), shift, axis=1)
        vb = jnp.roll(v_last.astype(vb.dtype), shift, axis=1)
        return {"k": kb, "v": vb}
    S_w = min(S, Sbuf)
    kb = jax.lax.dynamic_update_slice(kb, k[:, :S_w].astype(kb.dtype),
                                      (0, 0, 0, 0))
    vb = jax.lax.dynamic_update_slice(vb, v[:, :S_w].astype(vb.dtype),
                                      (0, 0, 0, 0))
    return {"k": kb, "v": vb}


def _prefill_recurrent(kind, p, x, cache_slice, cfg):
    """Recurrent blocks (mamba2/mlstm/slstm): full-sequence forward that also
    produces the final state for decode continuation."""
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind == "mamba2":
        y, state = _mamba2_with_state(p["mixer"], h, cfg)
    elif kind == "mlstm":
        y, state = _mlstm_with_state(p["mixer"], h, cfg)
    elif kind == "slstm":
        y, state = _slstm_with_state(p["mixer"], h, cfg)
    else:
        raise ValueError(kind)
    return x + y, state


def _mamba2_with_state(params, u, cfg):
    from repro.models.ssm import _causal_conv, _dims, _split_proj, _ssd_inputs
    from repro.models.gla import chunked_gla

    B, S, d = u.shape
    d_inner, nh, hd, ds = _dims(cfg)
    cdt = dtype_of(cfg.compute_dtype)
    zxbcdt = jnp.einsum("bsd,dp->bsp", u.astype(cdt),
                        params["in_proj"].astype(cdt))
    z, xBC, dt_raw = _split_proj(cfg, zxbcdt)
    xBC_f = xBC.astype(jnp.float32)
    conv_tail = jnp.pad(xBC_f, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))[
        :, -(cfg.ssm_conv - 1):, :]
    xBC_c = _causal_conv(xBC_f, params["conv_w"].astype(jnp.float32),
                         params["conv_b"].astype(jnp.float32))
    x, Bm, Cm, dt, log_g = _ssd_inputs(cfg, params, xBC_c, dt_raw)
    xh = x.reshape(B, S, nh, hd)
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, nh, ds))
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, nh, ds))
    v = xh * dt[..., None]
    y, state = chunked_gla(q, k, v, log_g, chunk=cfg.ssm_chunk)
    y = y + xh * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y, params["norm_scale"], cfg.norm_eps) * jax.nn.silu(
        z.astype(jnp.float32))
    out = jnp.einsum("bsp,pd->bsd", y.astype(cdt), params["out_proj"].astype(cdt))
    return out, {"conv": conv_tail, "state": state}


def _mlstm_with_state(params, u, cfg):
    from repro.models.xlstm import (_causal_conv, _mdims, _mlstm_qkvg,
                                    _mlstm_readout)
    from repro.models.gla import chunked_gla

    B, S, d = u.shape
    d_in, nh, dh = _mdims(cfg)
    cdt = dtype_of(cfg.compute_dtype)
    zx = jnp.einsum("bsd,dp->bsp", u.astype(cdt), params["up_proj"].astype(cdt))
    z, x_in = jnp.split(zx, 2, axis=-1)
    x_f = x_in.astype(jnp.float32)
    conv_tail = jnp.pad(x_f, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))[
        :, -(cfg.ssm_conv - 1):, :]
    xc = _causal_conv(x_f, params["conv_w"].astype(jnp.float32),
                      params["conv_b"].astype(jnp.float32))
    q, k, v, log_f = _mlstm_qkvg(params, xc, cfg)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    y_aug, state = chunked_gla(q, k, v_aug, log_f, chunk=cfg.ssm_chunk)
    h = _mlstm_readout(y_aug).reshape(B, S, d_in)
    h = rms_norm(h, params["norm_scale"], cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsp,pd->bsd", h.astype(cdt), params["down_proj"].astype(cdt))
    return out, {"conv": conv_tail, "state": state}


def _slstm_with_state(params, u, cfg):
    from repro.models.xlstm import _slstm_cell

    B, S, d = u.shape
    nh, dh = cfg.n_heads, d // cfg.n_heads
    cdt = dtype_of(cfg.compute_dtype)
    wx = jnp.einsum("bsd,dhgk->bshgk", u.astype(cdt),
                    params["w_in"].astype(cdt)).astype(jnp.float32)
    state0 = {k: jnp.zeros((B, nh, dh), jnp.float32) for k in ("c", "n", "h")}
    state0["m"] = jnp.full((B, nh, dh), -1e30, jnp.float32)
    r = params["r"].astype(jnp.float32)
    bias = params["bias"].astype(jnp.float32)

    def step(state, wx_t):
        new = _slstm_cell(r, bias, wx_t, state)
        return new, new["h"]

    state, hs = jax.lax.scan(step, state0, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, S, d)
    h = rms_norm(h, params["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", h.astype(cdt), params["out_proj"].astype(cdt))
    return out, state
