"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, truly recurrent) — arXiv:2405.04517.

mLSTM is gated linear attention with exponential input gates and sigmoid
forget gates plus a normalizer state:

    C_t = f_t·C_{t-1} + i_t·v_t k_tᵀ      n_t = f_t·n_{t-1} + i_t·k_t
    h_t = (C_t q_t) / max(|n_tᵀ q_t|, 1)

We run it on the shared ``chunked_gla`` core by (a) folding the input gate
into k and (b) appending a ones-column to v so the same scan produces the
normalizer — one recurrence, two readouts.  TPU adaptation note (DESIGN.md):
the original CUDA kernels stabilize exponential gates with a running
max-state; on the chunked path we instead clamp the input-gate pre-activation
(|ĩ| ≤ 10), which keeps f32 chunk math finite with sigmoid forget gates.

sLSTM keeps per-head scalar states with hidden-state feedback (R·h_{t-1}),
which makes it sequential by construction; it runs as ``lax.scan`` over time
with the paper's log-space max stabilizer.  This is the honest cost of sLSTM
on any hardware — the xLSTM paper itself places few sLSTM layers for this
reason.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ModelConfig
from repro.dist.sharding import constrain
from repro.models.common import (
    ParamDef,
    dtype_of,
    fan_in_init,
    normal_init,
    ones_init,
    rms_norm,
    zeros_init,
)
from repro.models.gla import chunked_gla, gla_step

__all__ = [
    "mlstm_defs", "mlstm_block", "mlstm_cache_defs", "mlstm_decode",
    "slstm_defs", "slstm_block", "slstm_cache_defs", "slstm_decode",
]

_ICLAMP = 10.0


def _mdims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model  # projected inner width
    nh = cfg.n_heads
    dh = d_in // nh
    return d_in, nh, dh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    d_in, nh, dh = _mdims(cfg)
    pdt = dtype_of(cfg.param_dtype)
    return {
        "up_proj": ParamDef((d, 2 * d_in), ("embed_fsdp", "conv_dim"),
                            fan_in_init(0), pdt),
        "conv_w": ParamDef((cfg.ssm_conv, d_in), (None, "conv_dim"),
                           normal_init(0.1), pdt),
        "conv_b": ParamDef((d_in,), ("conv_dim",), zeros_init(), pdt),
        "wq": ParamDef((d_in, nh, dh), ("conv_dim", "ssm_heads", None),
                       fan_in_init(0), pdt),
        "wk": ParamDef((d_in, nh, dh), ("conv_dim", "ssm_heads", None),
                       fan_in_init(0), pdt),
        "wv": ParamDef((d_in, nh, dh), ("conv_dim", "ssm_heads", None),
                       fan_in_init(0), pdt),
        "w_if": ParamDef((d_in, nh, 2), ("conv_dim", "ssm_heads", None),
                         normal_init(0.02), jnp.float32),
        "b_if": ParamDef((nh, 2), ("ssm_heads", None), zeros_init(), jnp.float32),
        "norm_scale": ParamDef((d_in,), (None,), ones_init(), jnp.float32),
        "down_proj": ParamDef((d_in, d), ("conv_dim", "embed_fsdp"),
                              fan_in_init(0), pdt),
    }


def mlstm_cache_defs(cfg: ModelConfig, batch: int) -> Dict[str, ParamDef]:
    d_in, nh, dh = _mdims(cfg)
    return {
        "conv": ParamDef((batch, cfg.ssm_conv - 1, d_in),
                         ("batch", None, "conv_dim"), zeros_init(), jnp.float32),
        "state": ParamDef((batch, nh, dh, dh + 1),
                          ("batch", "ssm_heads", None, None),
                          zeros_init(), jnp.float32),
    }


def _causal_conv(x, w, b):
    kk = w.shape[0]
    S = x.shape[1]
    xp = jnp.pad(x, ((0, 0), (kk - 1, 0), (0, 0)))
    return jax.nn.silu(sum(xp[:, j:j + S, :] * w[j] for j in range(kk)) + b)


def _mlstm_qkvg(params, xc, cfg):
    """Projections + gates from the conv output. xc: (B,S,d_in) f32."""
    d_in, nh, dh = _mdims(cfg)
    q = jnp.einsum("bsp,phk->bshk", xc, params["wq"].astype(jnp.float32))
    k = jnp.einsum("bsp,phk->bshk", xc, params["wk"].astype(jnp.float32))
    v = jnp.einsum("bsp,phk->bshk", xc, params["wv"].astype(jnp.float32))
    q = q / jnp.sqrt(jnp.float32(dh))
    gates = jnp.einsum("bsp,phg->bshg", xc, params["w_if"].astype(jnp.float32))
    gates = gates + params["b_if"].astype(jnp.float32)
    i_pre, f_pre = gates[..., 0], gates[..., 1]
    log_f = jax.nn.log_sigmoid(f_pre)  # ≤ 0: safe decay
    i_gate = jnp.exp(jnp.clip(i_pre, -_ICLAMP, _ICLAMP))  # clamped exp gate
    return q, k * i_gate[..., None], v, log_f


def _mlstm_readout(y_aug):
    """Split [values | normalizer] and normalize (denominator floor 1.0)."""
    y, den = y_aug[..., :-1], y_aug[..., -1:]
    return y / jnp.maximum(jnp.abs(den), 1.0)


def mlstm_block(params, u: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, d = u.shape
    d_in, nh, dh = _mdims(cfg)
    cdt = dtype_of(cfg.compute_dtype)
    zx = jnp.einsum("bsd,dp->bsp", u.astype(cdt), params["up_proj"].astype(cdt))
    z, x_in = jnp.split(zx, 2, axis=-1)
    xc = _causal_conv(x_in.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32),
                      params["conv_b"].astype(jnp.float32))
    q, k, v, log_f = _mlstm_qkvg(params, xc, cfg)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    from repro.models.ssm import _gla

    y_aug, _ = _gla(cfg, q, k, v_aug, log_f)
    h = _mlstm_readout(y_aug).reshape(B, S, d_in)
    h = constrain(h, "batch", "seq", "conv_dim")
    h = rms_norm(h, params["norm_scale"], cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsp,pd->bsd", h.astype(cdt), params["down_proj"].astype(cdt))
    return constrain(out, "batch", "seq", "embed")


def mlstm_decode(params, u, cache, cfg: ModelConfig):
    B = u.shape[0]
    d_in, nh, dh = _mdims(cfg)
    cdt = dtype_of(cfg.compute_dtype)
    zx = jnp.einsum("bsd,dp->bsp", u.astype(cdt), params["up_proj"].astype(cdt))
    z, x_in = jnp.split(zx, 2, axis=-1)
    window = jnp.concatenate([cache["conv"], x_in.astype(jnp.float32)], axis=1)
    w = params["conv_w"].astype(jnp.float32)
    xc = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, w) +
                     params["conv_b"].astype(jnp.float32))[:, None, :]
    q, k, v, log_f = _mlstm_qkvg(params, xc, cfg)
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    y_aug, state = gla_step(q[:, 0], k[:, 0], v_aug[:, 0], log_f[:, 0],
                            cache["state"])
    h = _mlstm_readout(y_aug).reshape(B, 1, d_in)
    h = rms_norm(h, params["norm_scale"], cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("bsp,pd->bsd", h.astype(cdt), params["down_proj"].astype(cdt))
    return out, {"conv": window[:, 1:], "state": state}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    pdt = dtype_of(cfg.param_dtype)
    return {
        # the hidden dim dh is sharded over the model axis ("slstm_dh") on
        # the OUTPUT side of the recurrent weights: the per-step rec result
        # and all gate/cell states stay local shards, the only per-step
        # collective is the tiny all-gather of h for the next contraction,
        # and the dR weight-gradient psum XLA otherwise emits every timestep
        # becomes a local sharded accumulation
        "w_in": ParamDef((d, nh, 4, dh),
                         ("embed_fsdp", "ssm_heads", None, "slstm_dh"),
                         fan_in_init(0), pdt),
        # block-diagonal recurrent weights: per-head (dh, 4, dh)
        "r": ParamDef((nh, dh, 4, dh), ("ssm_heads", None, None, "slstm_dh"),
                      fan_in_init(1), jnp.float32),
        "bias": ParamDef((nh, 4, dh), ("ssm_heads", None, "slstm_dh"),
                         zeros_init(), jnp.float32),
        "norm_scale": ParamDef((d,), (None,), ones_init(), jnp.float32),
        "out_proj": ParamDef((d, d), ("embed_fsdp", "embed"), fan_in_init(0), pdt),
    }


def slstm_cache_defs(cfg: ModelConfig, batch: int) -> Dict[str, ParamDef]:
    nh = cfg.n_heads
    dh = cfg.d_model // nh
    shape = (batch, nh, dh)
    axes = ("batch", "ssm_heads", "slstm_dh")
    return {name: ParamDef(shape, axes, zeros_init(), jnp.float32)
            for name in ("c", "n", "h", "m")}


def _slstm_cell(r, bias, wx_t, state):
    """One sLSTM time step with log-space stabilizer.

    wx_t: (B, nh, 4, dh) input contribution; state: dict of (B, nh, dh).
    """
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    rec = jnp.einsum("bhk,hkgd->bhgd", h, r)  # (B, nh, 4, dh)
    pre = wx_t + rec + bias
    i_pre, f_pre, z_pre, o_pre = (pre[:, :, 0], pre[:, :, 1], pre[:, :, 2],
                                  pre[:, :, 3])
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(log_f + m, i_pre)  # stabilizer state
    i = jnp.exp(i_pre - m_new)
    f = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_block(params, u: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, d = u.shape
    nh, dh = cfg.n_heads, d // cfg.n_heads
    cdt = dtype_of(cfg.compute_dtype)
    wx = jnp.einsum("bsd,dhgk->bshgk", u.astype(cdt),
                    params["w_in"].astype(cdt)).astype(jnp.float32)
    state0 = {k: jnp.zeros((B, nh, dh), jnp.float32) for k in ("c", "n", "h")}
    state0["m"] = jnp.full((B, nh, dh), -1e30, jnp.float32)
    r = params["r"].astype(jnp.float32)
    bias = params["bias"].astype(jnp.float32)

    def step(state, wx_t):
        new = _slstm_cell(r, bias, wx_t, state)
        return new, new["h"]

    _, hs = jax.lax.scan(step, state0, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).reshape(B, S, d)  # (B,S,nh*dh)
    h = rms_norm(h, params["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", h.astype(cdt), params["out_proj"].astype(cdt))
    return constrain(out, "batch", "seq", "embed")


def slstm_decode(params, u, cache, cfg: ModelConfig):
    B = u.shape[0]
    d = cfg.d_model
    nh, dh = cfg.n_heads, d // cfg.n_heads
    cdt = dtype_of(cfg.compute_dtype)
    wx = jnp.einsum("bsd,dhgk->bshgk", u.astype(cdt),
                    params["w_in"].astype(cdt)).astype(jnp.float32)[:, 0]
    new = _slstm_cell(params["r"].astype(jnp.float32),
                      params["bias"].astype(jnp.float32), wx, cache)
    h = new["h"].reshape(B, 1, d)
    h = rms_norm(h, params["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bsd,de->bse", h.astype(cdt), params["out_proj"].astype(cdt))
    return out, new
