"""Optimizer substrate: AdamW, schedules, clipping, gradient compression."""
from .adamw import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    global_norm,
    lr_at,
    opt_state_defs,
)
from .compression import COMPRESSIONS, compress_grads, compression_init

__all__ = [n for n in dir() if not n.startswith("_")]
