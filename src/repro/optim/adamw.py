"""AdamW with f32 moments, global-norm clipping and LR schedules — pure
pytree functions (no optax dependency)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptimizerConfig", "adamw_init", "adamw_update", "lr_at",
           "global_norm", "clip_by_global_norm", "opt_state_defs"]


@dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | constant
    min_lr_ratio: float = 0.1


def lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    else:
        decay = jnp.float32(1.0)
    return cfg.learning_rate * warm * decay


def adamw_init(params) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree_util.tree_map(zeros32, params),
        "nu": jax.tree_util.tree_map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_defs(param_defs):
    """ParamDef tree for the optimizer state (moments shard like params)."""
    from repro.models.common import ParamDef, zeros_init

    def moment(d):
        return ParamDef(d.shape, d.axes, zeros_init(), jnp.float32)

    is_def = lambda x: isinstance(x, ParamDef)
    return {
        "mu": jax.tree_util.tree_map(moment, param_defs, is_leaf=is_def),
        "nu": jax.tree_util.tree_map(moment, param_defs, is_leaf=is_def),
        "step": ParamDef((), (), zeros_init(), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype),
                                  tree), norm


def adamw_update(grads, opt_state, params, cfg: OptimizerConfig):
    """One AdamW step. grads may be any float dtype; math runs in f32."""
    step = opt_state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, p):
        g32 = g.astype(jnp.float32)
        mu_n = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu_n = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
        mhat = mu_n / b1c
        vhat = nu_n / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_n = p32 - lr * (delta + cfg.weight_decay * p32)
        return p_n.astype(p.dtype), mu_n, nu_n

    out = jax.tree_util.tree_map(upd, grads, opt_state["mu"], opt_state["nu"],
                                 params)
    # unzip the 3-tuples
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, lr
