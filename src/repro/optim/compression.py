"""Gradient compression with error feedback (distributed-optimization trick).

Two schemes, both with per-leaf error-feedback accumulators so the bias is
corrected over steps (Karimireddy et al., "EF-SGD"):

* ``int8``  — per-tensor symmetric linear quantization (32x -> 8x bytes on
  the wire when paired with int8 reduce-scatter on real fabric),
* ``topk``  — keep the largest-|g| fraction, zero the rest (sparse push).

In this SPMD codebase the gradients are reduced implicitly by the XLA
partitioner, so compression is applied *around* the reduction point: the
train step quantizes (grad + error), dequantizes for the update, and carries
the residual.  On a real pod the same hooks pair with int8 collectives; the
numerics — which is what tests can verify — are identical.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compression_init", "compress_grads", "COMPRESSIONS"]

COMPRESSIONS = ("none", "int8", "topk")


def compression_init(params, scheme: str):
    if scheme == "none":
        return None
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _int8_roundtrip(g32: jax.Array) -> jax.Array:
    scale = jnp.max(jnp.abs(g32)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_roundtrip(g32: jax.Array, frac: float) -> jax.Array:
    flat = g32.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g32) >= thresh, g32, 0.0)


def compress_grads(
    grads, error: Optional[Any], scheme: str, topk_frac: float = 0.05
) -> Tuple[Any, Optional[Any]]:
    """Returns (decompressed grads to apply, new error-feedback state)."""
    if scheme == "none":
        return grads, error

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        if scheme == "int8":
            out = _int8_roundtrip(g32)
        elif scheme == "topk":
            out = _topk_roundtrip(g32, topk_frac)
        else:
            raise ValueError(f"unknown compression {scheme!r}")
        return out.astype(g.dtype), g32 - out

    pairs = jax.tree_util.tree_map(one, grads, error)
    new_grads = jax.tree_util.tree_map(
        lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_error = jax.tree_util.tree_map(
        lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_error
