"""Serving substrate: continuous-batching engine over a paged KV cache.

``repro.serve.space`` (knob space + co-deployment surrogate) and the
runtime bookkeeping modules (``paging``: page-group allocator;
``scheduler``: fifo/sjf/interleave admission) are numpy-only; the engine
pulls in jax and the model stack.  Attribute access is lazy so the tuning
path (``--joint``, benchmarks, tests of the knob space) never pays the
jax import for touching the package.
"""
from typing import Any

_ENGINE_NAMES = ("GenerationResult", "OversubscriptionError", "ServeConfig",
                 "ServeEngine")
_PAGING_NAMES = ("PAGE_TOKENS", "PageAllocator")
_SCHED_NAMES = ("PAGE_POLICIES", "Request", "SCHEDULES", "SlotScheduler")
_SPACE_NAMES = (
    "CotuneParams",
    "LiveCotuneScalarizer",
    "LiveServeSUT",
    "ServeKernelCoupling",
    "ServeSurrogate",
    "apply_serve_knobs",
    "coupled_serve_metrics",
    "kv_floor_raise_count",
    "make_cotune_sut",
    "make_live_cotune_sut",
    "serve_knob_space",
)

__all__ = list(_ENGINE_NAMES + _PAGING_NAMES + _SCHED_NAMES + _SPACE_NAMES)


def __getattr__(name: str) -> Any:
    if name in _ENGINE_NAMES:
        from . import engine

        return getattr(engine, name)
    if name in _PAGING_NAMES:
        from . import paging

        return getattr(paging, name)
    if name in _SCHED_NAMES:
        from . import scheduler

        return getattr(scheduler, name)
    if name in _SPACE_NAMES:
        from . import space

        return getattr(space, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
