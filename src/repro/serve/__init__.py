"""Serving substrate: batched prefill + KV-cache decode engine."""
from .engine import GenerationResult, ServeConfig, ServeEngine

__all__ = ["GenerationResult", "ServeConfig", "ServeEngine"]
