"""Serving substrate: batched prefill + KV-cache decode engine.

``repro.serve.space`` (knob space + co-deployment surrogate) is numpy-only;
the engine pulls in jax and the model stack.  Attribute access is lazy so
the tuning path (``--joint``, benchmarks, tests of the knob space) never
pays the jax import for touching the package.
"""
from typing import Any

_ENGINE_NAMES = ("GenerationResult", "ServeConfig", "ServeEngine")
_SPACE_NAMES = (
    "PAGE_TOKENS",
    "SCHEDULES",
    "CotuneParams",
    "LiveCotuneScalarizer",
    "LiveServeSUT",
    "ServeKernelCoupling",
    "ServeSurrogate",
    "apply_serve_knobs",
    "coupled_serve_metrics",
    "make_cotune_sut",
    "make_live_cotune_sut",
    "serve_knob_space",
)

__all__ = list(_ENGINE_NAMES + _SPACE_NAMES)


def __getattr__(name: str) -> Any:
    if name in _ENGINE_NAMES:
        from . import engine

        return getattr(engine, name)
    if name in _SPACE_NAMES:
        from . import space

        return getattr(space, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
