"""Batched serving engine: prefill + KV-cache decode with slot admission.

Scope: fixed-capacity batch slots, greedy or temperature sampling, EOS
early-exit, equal-length prompt batching (the paged-attention/continuous-
batching generalization is out of scope for this repro; the restriction is
documented in DESIGN.md).  The decode step is the same ``serve_step`` the
dry-run lowers for the decode_32k / long_500k cells.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig
from repro.models import Model

from .space import PAGE_TOKENS, SCHEDULES

__all__ = ["ServeConfig", "ServeEngine", "GenerationResult"]


@dataclass
class ServeConfig:
    max_seq: int = 2048
    batch_slots: int = 8
    temperature: float = 0.0  # 0 = greedy
    eos_token: Optional[int] = None
    seed: int = 0
    # Tunable serving knobs (see repro.serve.space.serve_knob_space; the
    # joint co-tuning mode persists winners for them).  prefill_chunk is
    # the prefill split size: prompts longer than this are prefilled in
    # chunk-sized segments threaded through the KV cache (scheduler
    # granularity vs per-chunk dispatch overhead — the knob moves measured
    # prefill latency).  Models whose blocks cannot append multi-token
    # segments exactly (sliding-window rings, recurrent mixers; see
    # Model.supports_chunked_prefill) prefill whole prompts regardless.
    prefill_chunk: int = 512
    # KV capacity in PAGE_TOKENS-token pages; batch_slots*max_seq must fit
    # (enforced at construction — the admission constraint).  None
    # auto-sizes to exactly that footprint, so configs that never touch
    # the knob keep working at any max_seq/batch_slots combination.
    kv_cache_pages: Optional[int] = None
    # Wave admission order: fifo | sjf | interleave.  Validated and
    # modelled by the co-tuning surrogate; the engine's equal-length-wave
    # scheduler runs fifo today — runtime sjf/interleave land with
    # continuous batching.
    schedule: str = "fifo"
    # Tune/load Pallas block configs for this engine's decode shapes before
    # serving (persisted in the repro.autotune cache, so the compile-time
    # cost is paid once per (shape, dtype, backend)).
    autotune_kernels: bool = False
    autotune_budget: int = 12

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}; "
                             f"have {SCHEDULES}")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        needed = self.batch_slots * self.max_seq
        if self.kv_cache_pages is None:
            self.kv_cache_pages = -(-needed // PAGE_TOKENS)
        capacity = self.kv_cache_pages * PAGE_TOKENS
        if needed > capacity:
            raise ValueError(
                f"KV cache too small: {self.batch_slots} slots x "
                f"{self.max_seq} tokens needs {needed} tokens but "
                f"kv_cache_pages={self.kv_cache_pages} holds only "
                f"{capacity}")


@dataclass
class GenerationResult:
    tokens: List[List[int]]  # generated continuations (per request)
    prefill_seconds: float
    decode_seconds: float
    steps: int
    # prefill dispatches actually issued (> waves when chunked prefill
    # split prompts) — the observable evidence the prefill_chunk knob acts
    prefill_chunks: int = 0

    @property
    def decode_tokens_per_sec(self) -> float:
        n = sum(len(t) for t in self.tokens)
        return n / max(self.decode_seconds, 1e-9)


class ServeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        # tuned block configs for this engine's kernel shapes (filled when
        # cfg.autotune_kernels; consulted implicitly by repro.kernels.ops)
        self.kernel_blocks: Dict[str, Dict[str, int]] = {}
        if cfg.autotune_kernels:
            # the decode cache buffer is always max_seq long; prompt-length
            # dependent shapes are warmed lazily per wave in generate()
            mcfg = model.cfg
            self.kernel_blocks["decode_attention"] = self._ensure(
                "decode_attention",
                {"B": cfg.batch_slots, "S": cfg.max_seq,
                 "H": mcfg.padded_heads, "KV": mcfg.n_kv_heads,
                 "D": mcfg.head_dim_})
        self._prefill = jax.jit(model.prefill)
        self._prefill_chunk = jax.jit(model.prefill_chunk)
        self._decode = jax.jit(model.decode_step)

    def _ensure(self, kernel: str, dims: Dict[str, int]) -> Dict[str, int]:
        from repro import autotune

        return autotune.ensure_tuned(kernel, dims,
                                     dtype=self.model.cfg.compute_dtype,
                                     budget=self.cfg.autotune_budget)

    def _warm_prefill_blocks(self, prompt_len: int) -> None:
        """Tune/load block configs for the shapes this wave actually runs:
        prefill attention at S=prompt_len, rmsnorm at the prefill and
        decode row counts.  Idempotent per shape (cache hits are free)."""
        mcfg = self.model.cfg
        B = self.cfg.batch_slots
        self.kernel_blocks["flash_attention"] = self._ensure(
            "flash_attention",
            {"B": B, "S": prompt_len, "SK": prompt_len,
             "H": mcfg.padded_heads, "KV": mcfg.n_kv_heads,
             "D": mcfg.head_dim_})
        self.kernel_blocks["rmsnorm_prefill"] = self._ensure(
            "rmsnorm", {"ROWS": B * prompt_len, "D": mcfg.d_model})
        self.kernel_blocks["rmsnorm_decode"] = self._ensure(
            "rmsnorm", {"ROWS": B, "D": mcfg.d_model})

    def generate(
        self,
        prompts: Sequence[Sequence[int]],
        max_new_tokens: int,
        frontend_embeds: Optional[np.ndarray] = None,
    ) -> GenerationResult:
        """Generate continuations for a batch of equal-length prompts.

        Requests are packed into ``batch_slots``-sized waves; a short final
        wave is padded with dummy prompts (their outputs are discarded).
        """
        mcfg = self.model.cfg
        if (mcfg.frontend or mcfg.encoder) and frontend_embeds is None:
            # Fail loudly on BOTH prefill paths: the whole-prompt path
            # would KeyError deep in _memory, and the chunked path would
            # silently attend to the cache's zero-initialized memory.
            raise ValueError(
                f"{mcfg.name} has a modality frontend/encoder; generate() "
                "requires frontend_embeds")
        lens = {len(p) for p in prompts}
        if len(lens) != 1:
            raise ValueError("engine batches equal-length prompts; "
                             f"got lengths {sorted(lens)}")
        (plen,) = lens
        if plen + max_new_tokens > self.cfg.max_seq:
            raise ValueError("prompt + generation exceeds max_seq")
        if self.cfg.autotune_kernels:
            self._warm_prefill_blocks(plen)

        slots = self.cfg.batch_slots
        outputs: List[List[int]] = []
        prefill_s = decode_s = 0.0
        steps = chunks = 0
        for wave_start in range(0, len(prompts), slots):
            wave = list(prompts[wave_start:wave_start + slots])
            n_real = len(wave)
            while len(wave) < slots:
                wave.append(wave[0])  # pad with a copy; discarded later
            fe = None
            if frontend_embeds is not None:
                fe = frontend_embeds[wave_start:wave_start + slots]
                if fe.shape[0] < slots:
                    reps = np.repeat(fe[:1], slots - fe.shape[0], axis=0)
                    fe = np.concatenate([fe, reps], axis=0)
            toks, pf, dc, st, nc = self._generate_wave(
                np.asarray(wave, np.int32), max_new_tokens, fe)
            outputs.extend(toks[:n_real])
            prefill_s += pf
            decode_s += dc
            steps += st
            chunks += nc
        return GenerationResult(outputs, prefill_s, decode_s, steps, chunks)

    def _generate_wave(self, prompt_arr: np.ndarray, max_new: int,
                       frontend_embeds) -> Any:
        B, P = prompt_arr.shape
        cache = self.model.init_cache(B, max_seq=self.cfg.max_seq)

        chunk = self.cfg.prefill_chunk
        chunked = chunk < P and self.model.supports_chunked_prefill
        # host->device conversion stays OUTSIDE the timed window, so
        # prefill_seconds keeps measuring model time like it always has
        tokens = jnp.asarray(prompt_arr)
        fe = jnp.asarray(frontend_embeds) \
            if frontend_embeds is not None else None
        t0 = time.time()
        if chunked:
            # Chunked prefill: run the prompt through the model in
            # chunk-sized segments, threading the KV cache between calls.
            # Exact (same tokens, same cache) as whole-prompt prefill for
            # the block kinds that support it; the knob trades scheduler
            # granularity against per-chunk dispatch overhead.
            n_chunks = 0
            for start in range(0, P, chunk):
                piece = {"tokens": tokens[:, start:start + chunk]}
                if start == 0 and fe is not None:
                    piece["frontend_embeds"] = fe
                logits, cache = self._prefill_chunk(self.params, piece,
                                                    cache)
                n_chunks += 1
        else:
            batch = {"tokens": tokens}
            if fe is not None:
                batch["frontend_embeds"] = fe
            logits, cache = self._prefill(self.params, batch, cache)
            n_chunks = 1
        logits.block_until_ready()
        prefill_s = time.time() - t0

        rng = jax.random.PRNGKey(self.cfg.seed)
        out = np.zeros((B, max_new), np.int64)
        done = np.zeros(B, bool)
        t0 = time.time()
        produced = 0
        for step in range(max_new):
            tok = self._sample(logits, rng, step)
            out[:, step] = np.asarray(tok[:, 0])
            produced = step + 1
            if self.cfg.eos_token is not None:
                done |= out[:, step] == self.cfg.eos_token
                if done.all():
                    break
            if produced < max_new:
                logits, cache = self._decode(self.params, tok, cache)
        decode_s = time.time() - t0

        results = []
        for b in range(B):
            toks = out[b, :produced].tolist()
            if self.cfg.eos_token is not None and self.cfg.eos_token in toks:
                toks = toks[:toks.index(self.cfg.eos_token) + 1]
            results.append(toks)
        return results, prefill_s, decode_s, produced, n_chunks

    def _sample(self, logits, rng, step):
        lg = logits[:, -1, :self.model.cfg.vocab_size].astype(jnp.float32)
        if self.cfg.temperature <= 0:
            return jnp.argmax(lg, axis=-1, keepdims=True).astype(jnp.int32)
        key = jax.random.fold_in(rng, step)
        return jax.random.categorical(
            key, lg / self.cfg.temperature, axis=-1)[:, None].astype(jnp.int32)
