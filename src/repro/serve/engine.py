"""Serving engine: continuous-batching runtime over a paged KV cache.

Two runtimes share one engine:

* ``continuous`` (default) — a slot-based scheduler admits requests into
  decode slots *as they free up mid-generation* (``repro.serve.scheduler``:
  fifo | sjf | interleave — the tuned ``schedule`` knob acts here), backed
  by either dense per-slot KV buffers or a real paged allocator
  (``repro.serve.paging``; ``kv_cache_pages`` bounds how many requests can
  be resident, which is the memory/throughput trade-off the tuner
  explores).  Decode is one batched dispatch per step at per-slot cache
  lengths; admission-time prefill reuses the exact chunked-prefill path,
  so generated tokens are identical to the wave runtime's and identical
  across schedules (slot math is row-independent).  Two further tuned
  mechanisms ride this runtime without touching tokens: *prefix sharing*
  (``share_prefix``; admission maps registry-matched prompt-prefix page
  groups copy-on-write instead of re-prefilling them) and
  *self-speculative decoding* (``draft_len``; n-gram drafts from the
  request's own history verified as extra columns of the same batched
  dispatch, accepted only where they match what single-token decode
  would have sampled).
* ``wave`` — the legacy static loop (equal-length prompts packed into
  ``batch_slots``-sized waves), kept as the exact-parity fallback and the
  only runtime for stacks without ``supports_continuous_batching``
  (sliding-window rings, recurrent mixers).

The decode step is the same ``serve_step`` the dry-run lowers for the
decode_32k / long_500k cells.
"""
from __future__ import annotations

import functools
import time
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.dist.sharding import RULE_PRESETS, axis_rules
from repro.models import Model

from .paging import (PAGE_TOKENS, OversubscriptionError, PageAllocator,
                     PrefixIndex, min_pages_for)
from .scheduler import (PAGE_POLICIES, SCHEDULES, TP_MODES, Request,
                        SlotScheduler)

__all__ = ["ServeConfig", "ServeEngine", "GenerationResult",
           "OversubscriptionError", "TP_MODES"]

RUNTIMES = ("continuous", "wave")
KV_LAYOUTS = ("dense", "paged")


def _tail_history(prompt: Sequence[int], out: List[int],
                  window: int) -> List[int]:
    """The trailing ``window`` tokens of prompt+generated WITHOUT
    materialising the full concatenation — the list build itself was the
    other O(T) term in the per-step drafting cost (``list(prompt) +
    out`` every decode step).  ``window <= 0`` keeps the historical
    unbounded behaviour."""
    if window <= 0:
        return list(prompt) + out
    if window <= len(out):
        return out[-window:]
    head = list(prompt[-(window - len(out)):]) if len(prompt) else []
    return head + out


@dataclass
class ServeConfig:
    max_seq: int = 2048
    batch_slots: int = 8
    temperature: float = 0.0  # 0 = greedy
    eos_token: Optional[int] = None
    seed: int = 0
    # Tunable serving knobs (see repro.serve.space.serve_knob_space; the
    # joint co-tuning mode persists winners for them).  prefill_chunk is
    # the prefill split size: prompts longer than this are prefilled in
    # chunk-sized segments threaded through the KV cache (scheduler
    # granularity vs per-chunk dispatch overhead — under the continuous
    # runtime it is also the interleave quantum).  Models whose blocks
    # cannot append multi-token segments exactly (sliding-window rings,
    # recurrent mixers; see Model.supports_chunked_prefill) prefill whole
    # prompts regardless.
    prefill_chunk: int = 512
    # KV capacity in PAGE_TOKENS-token pages.  Under the paged layout this
    # is a REAL pool: requests reserve page groups at admission and release
    # them at completion, so fewer pages = fewer resident requests.  Under
    # the dense layout (and the wave runtime) batch_slots*max_seq must fit
    # (the buffers really are that big).  None auto-sizes to that footprint
    # (+ the scratch group under paging), so configs that never touch the
    # knob keep working at any max_seq/batch_slots combination.
    kv_cache_pages: Optional[int] = None
    # Admission order under the continuous runtime: fifo | sjf (shortest
    # prompt first) | interleave (fifo admission, prefill chunks issued
    # between decode steps).  The wave runtime runs fifo regardless.
    schedule: str = "fifo"
    # KV reservation policy under the paged layout (repro.serve.scheduler
    # PAGE_POLICIES; a tuned knob — serve_knob_space exposes it):
    #   reserve   — admission reserves the worst-case prompt+max_new
    #               footprint; no preemption, but short generations strand
    #               the unused reservation tail.
    #   on_demand — admission reserves the prompt only; decode grows the
    #               reservation group-by-group and, when the pool runs
    #               dry, preempts the youngest request (recompute: it is
    #               re-queued at the head and re-prefilled with its
    #               generated tokens folded into the prompt — tokens stay
    #               bit-identical because sampling keys on
    #               (rid, token-index)).
    # Dense layouts have no allocator, so the knob is inert there.
    page_policy: str = "reserve"
    # Runtime: continuous batching (slot-level admission) or the legacy
    # equal-length wave loop.  Stacks without supports_continuous_batching
    # fall back to wave automatically.
    runtime: str = "continuous"
    # KV layout under the continuous runtime: dense per-slot buffers or
    # the paged pool + allocator.  The wave runtime is always dense.
    kv_layout: str = "dense"
    # Pages per allocation group == the paged kernel's pages_per_block
    # tile.  With autotune_kernels the tuned paged_attention entry
    # overrides this (clamped so one max_seq request still fits).
    kv_page_block: int = 1
    # Prefix sharing across concurrent requests (paged layout only; a
    # tuned knob): admission content-matches the prompt against a
    # registry of resident fully-prefilled prompt chunks and maps the
    # matched page groups copy-on-write instead of re-prefilling them —
    # TTFT drops by exactly the prefill no longer issued, and the pool
    # hosts more requests because shared groups are stored once.  Tokens
    # are untouched: matching compares token content exactly and chunked
    # prefill is chunk-split-invariant, so shared KV is bitwise the KV
    # the sharer would have computed.  Inert under dense/wave layouts
    # and for requests carrying frontend embeddings (their KV depends on
    # the embeds, not just the token ids).
    share_prefix: bool = False
    # Self-speculative decoding draft length (0 = off; a tuned knob):
    # each decode dispatch carries up to draft_len extra tokens drafted
    # by n-gram lookup in the request's own history, verified as extra
    # columns of the same batched step.  The longest draft prefix that
    # matches what single-token decode would have sampled is accepted —
    # same (rid, token-index) sampling keys, so generated tokens stay
    # bit-identical at any draft_len; only the dispatch count drops.
    draft_len: int = 0
    # n-gram draft lookback bound: only the trailing draft_window tokens
    # of prompt+generated are scanned per draft, so host-side drafting
    # cost stays flat in generation length (it used to rescan the whole
    # history — O(T^2) over a generation).  Tokens never depend on it:
    # a truncated match only changes WHAT gets drafted, and verification
    # accepts exactly what single-token decode would have sampled.
    draft_window: int = 256
    # Effective admission cap <= batch_slots (None = all slots).  The
    # online retuner's max_batch knob acts here: physical slot/dispatch
    # shapes are compiled once, so capping ADMISSION is how max_batch
    # swaps mid-run without draining or recompiling the engine.
    slot_cap: Optional[int] = None
    # Online workload-aware retuning (continuous runtime): fingerprint
    # the live request window (repro.serve.workload), detect drift from
    # the signature the deployed knobs were tuned under, and warm-start
    # a retune whose winner swaps into the running loop at the next step
    # boundary.  All trigger arithmetic counts decode steps (never
    # wall-clock), so the retune step is deterministic per trace.
    retune: bool = False
    retune_budget: int = 16       # SUT tests per retune
    retune_threshold: float = 0.25  # fingerprint distance that triggers
    retune_window: int = 16       # admissions the fingerprint averages
    retune_cooldown: int = 32     # min decode steps between retunes
    retune_check_every: int = 4   # shift-check cadence in decode steps
    retune_min_requests: int = 6  # admissions before fingerprints count
    # the workload signature (fingerprint_sig string) the deployed knobs
    # were tuned under; None anchors on the first full window instead
    tuned_signature: Optional[str] = None
    # Tune/load Pallas block configs for this engine's decode shapes before
    # serving (persisted in the repro.autotune cache, so the compile-time
    # cost is paid once per (shape, dtype, backend)).
    autotune_kernels: bool = False
    autotune_budget: int = 12
    # Multi-device serving: a (data, model) mesh shape (None = single
    # device).  The ``model`` axis is the tensor-parallel split — heads /
    # ff / vocab columns (and the paged pool's KV-head axis) shard across
    # it and every decode step all-reduces partial sums.  The ``data``
    # axis carries engine REPLICAS: batch slots spread over it and the
    # engine widens slot/page capacity ×data, so the config's
    # batch_slots / kv_cache_pages stay per-replica quantities.  Both
    # layouts (and the meshes between) are tuned knobs —
    # ``serve_knob_space(max_devices=...)`` exposes ``mesh_devices`` /
    # ``tp_vs_replicas`` and the joint mode co-tunes them with schedule,
    # page policy and kernel blocks.
    mesh_shape: Optional[Tuple[int, int]] = None
    # AxisRules preset (repro.dist.sharding.RULE_PRESETS) activated for
    # sharded generation.  "serve_tp" is safe for every mesh shape: on a
    # (K, 1) replicas mesh its model-axis mappings drop (size-1 axis) and
    # it degenerates to "serve_replicas" exactly.
    rules_preset: str = "serve_tp"
    # Which mesh orientation a flat tuned device count maps to (TP_MODES;
    # ``apply_serve_knobs`` writes the resolved mesh_shape from it) —
    # recorded here so deployed configs carry the tuner's choice.
    tp_vs_replicas: str = "tp"

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise ValueError(f"unknown schedule {self.schedule!r}; "
                             f"have {SCHEDULES}")
        if self.runtime not in RUNTIMES:
            raise ValueError(f"unknown runtime {self.runtime!r}; "
                             f"have {RUNTIMES}")
        if self.kv_layout not in KV_LAYOUTS:
            raise ValueError(f"unknown kv_layout {self.kv_layout!r}; "
                             f"have {KV_LAYOUTS}")
        if self.page_policy not in PAGE_POLICIES:
            raise ValueError(f"unknown page_policy {self.page_policy!r}; "
                             f"have {PAGE_POLICIES}")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.kv_page_block < 1:
            raise ValueError("kv_page_block must be >= 1")
        if self.draft_len < 0:
            raise ValueError("draft_len must be >= 0")
        if self.draft_window < 2:
            raise ValueError("draft_window must be >= 2 (an n-gram draft "
                             "needs at least a 1-token suffix + 1 earlier "
                             "token to match against)")
        if self.slot_cap is not None and not (
                1 <= self.slot_cap <= self.batch_slots):
            raise ValueError(f"slot_cap must be in [1, batch_slots="
                             f"{self.batch_slots}]; got {self.slot_cap}")
        for knob in ("retune_budget", "retune_window", "retune_cooldown",
                     "retune_check_every", "retune_min_requests"):
            if getattr(self, knob) < 1:
                raise ValueError(f"{knob} must be >= 1")
        if self.retune_threshold < 0:
            raise ValueError("retune_threshold must be >= 0")
        if self.rules_preset not in RULE_PRESETS:
            raise ValueError(f"unknown rules_preset {self.rules_preset!r}; "
                             f"have {sorted(RULE_PRESETS)}")
        if self.tp_vs_replicas not in TP_MODES:
            raise ValueError(f"unknown tp_vs_replicas "
                             f"{self.tp_vs_replicas!r}; have {TP_MODES}")
        if self.mesh_shape is not None:
            ms = tuple(int(x) for x in self.mesh_shape)
            if len(ms) != 2 or any(x < 1 for x in ms):
                raise ValueError(f"mesh_shape must be a (data, model) pair "
                                 f"of positive ints; got {self.mesh_shape!r}")
            self.mesh_shape = ms
        paged = self.runtime == "continuous" and self.kv_layout == "paged"
        needed = self.batch_slots * self.max_seq
        # remember auto-sizing: the engine re-derives a full-residency pool
        # if autotuning later changes the group size (pages_per_block)
        self._kv_pages_auto = self.kv_cache_pages is None
        if self.kv_cache_pages is None:
            pages = -(-needed // PAGE_TOKENS)
            if paged:  # round to group granularity + the scratch group
                ppb = self.kv_page_block
                pages = (-(-pages // ppb) + 1) * ppb
            self.kv_cache_pages = pages
        if paged:
            # Pages bound residency, not the dense footprint — but one
            # max_seq request (plus the scratch group) must always fit.
            floor = min_pages_for(self.max_seq, self.kv_page_block)
            if self.kv_cache_pages < floor:
                raise ValueError(
                    f"KV cache too small: a single {self.max_seq}-token "
                    f"request (+ the scratch group) needs {floor} pages at "
                    f"{self.kv_page_block} pages/group but "
                    f"kv_cache_pages={self.kv_cache_pages}")
        else:
            capacity = self.kv_cache_pages * PAGE_TOKENS
            if needed > capacity:
                raise ValueError(
                    f"KV cache too small: {self.batch_slots} slots x "
                    f"{self.max_seq} tokens needs {needed} tokens but "
                    f"kv_cache_pages={self.kv_cache_pages} holds only "
                    f"{capacity}")


@dataclass
class GenerationResult:
    tokens: List[List[int]]  # generated continuations (per request)
    prefill_seconds: float
    decode_seconds: float
    steps: int  # batched decode dispatches
    # prefill dispatches actually issued (> waves when chunked prefill
    # split prompts; per-slot under the continuous runtime) — the
    # observable evidence the prefill_chunk knob acts
    prefill_chunks: int = 0
    # per-request runtime provenance (rid order == input order):
    # {"rid", "prompt_len", "new_tokens", "latency_s", "ttft_s",
    #  "preemptions"}
    per_request: List[Dict[str, Any]] = field(default_factory=list)
    # recompute preemptions issued (on_demand page policy only): each one
    # re-queued a request whose re-prefill cost is the price of admitting
    # on prompt-size reservations instead of worst-case ones
    preemptions: int = 0
    # prefix-sharing + speculative-decoding provenance: prompt tokens
    # admitted straight from shared resident groups (their prefill was
    # skipped), copy-on-write group splits performed, draft tokens
    # proposed to verification, and draft tokens accepted (beyond the
    # guaranteed first token of every dispatch)
    shared_prefix_tokens: int = 0
    cow_splits: int = 0
    drafted: int = 0
    accepted: int = 0
    # online retune events (cfg.retune): one dict per swap — {"step",
    # "distance", "signature", "fingerprint", "config", "value",
    # "n_tests", "warm_source", "spec_accept", "measured_accept",
    # "applied": {knob: (old, new)}}
    retunes: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens that verification accepted.

        ``nan`` when nothing was drafted: "no speculation ran" and "every
        draft was rejected" are different facts, and the old 0.0-for-both
        answer poisoned any feedback loop that treated it as a measured
        rate (the online retuner would have pinned ``spec_accept`` to 0
        on runs that simply had ``draft_len=0``).  Consumers must guard
        with ``math.isnan`` before feeding it anywhere numeric."""
        if self.drafted == 0:
            return float("nan")
        return self.accepted / self.drafted

    @property
    def decode_tokens_per_sec(self) -> float:
        n = sum(len(t) for t in self.tokens)
        return n / max(self.decode_seconds, 1e-9)

    def latency_percentile(self, q: float) -> float:
        """q-th percentile (0..100) of per-request latency seconds."""
        lats = [r["latency_s"] for r in self.per_request]
        if not lats:
            return 0.0
        return float(np.percentile(np.asarray(lats), q))

    @property
    def p50_latency_s(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p95_latency_s(self) -> float:
        return self.latency_percentile(95.0)


class ServeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        import dataclasses

        self.model = model
        self.params = params
        # private copy: pool sizing below rewrites kv_cache_pages (group
        # rounding, wave-fallback footprint, autotuned group size) and
        # must not leak into a caller-owned config reused across engines
        orig = cfg
        self.cfg = cfg = dataclasses.replace(cfg)
        cfg._kv_pages_auto = getattr(orig, "_kv_pages_auto", False)
        # --- mesh resolution: the (data, model) device grid ------------
        self.mesh = None
        self.rules = RULE_PRESETS[cfg.rules_preset]
        data, tp = cfg.mesh_shape or (1, 1)
        self.mesh_shape = (data, tp)
        if data * tp > 1:
            n_dev = len(jax.devices())
            if n_dev % (data * tp):
                raise ValueError(
                    f"mesh_shape {self.mesh_shape} needs {data * tp} "
                    f"devices evenly out of {n_dev} available")
            from repro.launch.mesh import make_mesh

            self.mesh = make_mesh(data, tp)
            if data > 1:
                # replicas widening: the config's capacity knobs are
                # per-data-slice, so the flat engine runs data× of them
                # (each replica's slots/pool shard onto its own slice)
                cfg.batch_slots *= data
                if cfg.slot_cap is not None:
                    cfg.slot_cap *= data
                if not cfg._kv_pages_auto:
                    cfg.kv_cache_pages *= data
                elif cfg.kv_layout != "paged":
                    # auto dense footprint: re-derive at the widened slots
                    cfg.kv_cache_pages = -(-cfg.batch_slots * cfg.max_seq
                                           // PAGE_TOKENS)
        self._continuous = (cfg.runtime == "continuous"
                            and model.supports_continuous_batching)
        self._paged = self._continuous and cfg.kv_layout == "paged"
        if cfg.kv_layout == "paged" and not self._paged:
            # A paged config passed the lenient one-request validation,
            # but this stack runs dense buffers (wave fallback): restore
            # the dense footprint accounting the paged branch waived, so
            # the config honestly reports the memory actually allocated.
            needed = cfg.batch_slots * cfg.max_seq
            if cfg.kv_cache_pages * PAGE_TOKENS < needed:
                cfg.kv_cache_pages = -(-needed // PAGE_TOKENS)
        # tuned block configs for this engine's kernel shapes (filled when
        # cfg.autotune_kernels; consulted implicitly by repro.kernels.ops)
        self.kernel_blocks: Dict[str, Dict[str, Any]] = {}
        mcfg = model.cfg
        if cfg.autotune_kernels:
            # the decode cache buffer is always max_seq long; prompt-length
            # dependent shapes are warmed lazily per wave in generate()
            self.kernel_blocks["decode_attention"] = self._ensure(
                "decode_attention",
                {"B": cfg.batch_slots, "S": cfg.max_seq,
                 "H": mcfg.padded_heads, "KV": mcfg.n_kv_heads,
                 "D": mcfg.head_dim_})
        if self._paged:
            self._size_paged_pool()
        if self.mesh is not None:
            # lay the weights out per the rule table up front: heads/ff
            # columns land on their model-axis shard once, and every jit
            # below traces against committed sharded inputs
            self.params = self._shard_tree(
                self.params, model.param_specs(self.rules, self.mesh))
        jit = jax.jit if self.mesh is None else self._jit_mesh_keyed
        self._prefill = jit(model.prefill)
        self._prefill_chunk = jit(model.prefill_chunk)
        self._decode = jit(model.decode_step)
        if self._continuous:
            self._decode_multi = jit(model.decode_step_multi)
            self._slot_chunk = jit(model.prefill_chunk_slot)
            self._slot_chunk_paged = jit(model.prefill_chunk_slot_paged)
            self._argmax_multi = jax.jit(self._greedy_rows)
            self._categorical_multi = jax.jit(self._categorical_rows)
            self._argmax_grid = jax.jit(self._greedy_grid)
            self._categorical_grid_j = jax.jit(self._categorical_grid)
            self._copy_group = jax.jit(self._copy_group_blocks)

    # ------------------------------------------------------------------
    def _ensure(self, kernel: str, dims: Dict[str, int]) -> Dict[str, Any]:
        from repro import autotune

        return autotune.ensure_tuned(kernel, dims,
                                     dtype=self.model.cfg.compute_dtype,
                                     budget=self.cfg.autotune_budget)

    def _shard_tree(self, tree, specs):
        """device_put a pytree onto the mesh with per-leaf NamedShardings.

        ``specs`` mirrors ``tree`` with a PartitionSpec at every array
        position; since PartitionSpec is itself a tuple the spec tree is
        flattened only UP TO the data tree's structure (never into the
        specs themselves)."""
        flat, treedef = jax.tree_util.tree_flatten(tree)
        sflat = treedef.flatten_up_to(specs)
        put = [jax.device_put(x, NamedSharding(self.mesh,
                                               PartitionSpec(*s)))
               for x, s in zip(flat, sflat)]
        return jax.tree_util.tree_unflatten(treedef, put)

    def _jit_mesh_keyed(self, fn):
        """``jax.jit`` with the trace cache keyed to THIS engine.

        Bound methods of a shared ``Model`` hash equal across engines, and
        jax's jaxpr-tracing cache does not see the ambient mesh that
        ``constrain`` captures at trace time — so two sharded engines over
        the same model with coinciding avals (e.g. a (2,1) and a (2,2)
        mesh both widen slots x2) would hand each other jaxprs whose
        sharding constraints pin the OTHER engine's devices.  A per-engine
        closure (identity-hashed) makes the reuse impossible."""
        @functools.wraps(fn)
        def keyed(*args, **kwargs):
            return fn(*args, **kwargs)
        return jax.jit(keyed)

    def _sharding_ctx(self):
        """The ``axis_rules`` context generation runs under: tracing the
        jitted steps inside it attaches ``constrain`` activation
        constraints for this engine's rule table + mesh.  Single-device
        engines get a no-op context — same code path, unsharded."""
        if self.mesh is None:
            return nullcontext()
        return axis_rules(self.rules, self.mesh)

    def _size_paged_pool(self) -> None:
        """Fix the pool geometry: group size (pages), groups per request,
        total groups.  With autotune the paged kernel's tuned
        ``pages_per_block`` becomes the group size — clamped so one
        max_seq request still fits the configured page budget — and the
        winner is re-keyed under the runtime pool signature so the
        ``ops.paged_flash_decode`` consult point hits it."""
        cfg, mcfg = self.cfg, self.model.cfg
        ppb = cfg.kv_page_block
        if cfg.autotune_kernels:
            tuned = self._ensure(
                "paged_attention",
                {"B": cfg.batch_slots, "S": cfg.max_seq,
                 "H": mcfg.padded_heads, "KV": mcfg.n_kv_heads,
                 "D": mcfg.head_dim_})
            self.kernel_blocks["paged_attention"] = tuned
            ppb = int(tuned.get("pages_per_block", ppb))
        if not getattr(cfg, "_kv_pages_auto", False):
            while ppb > 1:  # tuned tile too coarse for this page budget
                if cfg.kv_cache_pages >= min_pages_for(cfg.max_seq, ppb):
                    break
                ppb //= 2
        self.group_pages = ppb
        self.group_tokens = ppb * PAGE_TOKENS
        self.max_groups = -(-cfg.max_seq // self.group_tokens)
        if getattr(cfg, "_kv_pages_auto", False):
            # auto-sized budget: full residency at the adopted group size
            self.pool_groups = cfg.batch_slots * self.max_groups + 1
        else:
            self.pool_groups = max(cfg.kv_cache_pages // ppb,
                                   self.max_groups + 1)
        # the config reports the pool actually allocated (group rounding,
        # one-request minimum and auto-resizing can all move it)
        cfg.kv_cache_pages = self.pool_groups * ppb
        if cfg.autotune_kernels:
            self._rekey_paged_entry()

    def _rekey_paged_entry(self) -> None:
        """Persist the paged winner under the dims the pool actually runs
        (S = max_groups * group_tokens), so the runtime consult point in
        ``ops.paged_flash_decode`` resolves the tuned launch knobs."""
        from repro import autotune

        mcfg = self.model.cfg
        logical = {"B": self.cfg.batch_slots, "S": self.cfg.max_seq,
                   "H": mcfg.padded_heads, "KV": mcfg.n_kv_heads,
                   "D": mcfg.head_dim_}
        runtime = dict(logical, S=self.max_groups * self.group_tokens)
        if runtime == logical:
            return
        cache = autotune.default_cache()
        entry = cache.get("paged_attention", autotune.shape_sig(logical),
                          mcfg.compute_dtype, autotune.backend_name())
        if not entry:
            return
        # rebuild-per-trial loops (LiveServeSUT) construct many engines:
        # skip the full-file cache rewrite when the entry already landed
        existing = cache.get_config("paged_attention",
                                    autotune.shape_sig(runtime),
                                    mcfg.compute_dtype,
                                    autotune.backend_name())
        if existing == entry["config"]:
            return
        cache.put("paged_attention", autotune.shape_sig(runtime),
                  mcfg.compute_dtype, autotune.backend_name(),
                  entry["config"], entry["value"],
                  meta=dict(entry.get("meta", {}), rekeyed_from="logical"))

    def _warm_prefill_blocks(self, prompt_len: int) -> None:
        """Tune/load block configs for the shapes this wave actually runs:
        prefill attention at S=prompt_len, rmsnorm at the prefill and
        decode row counts.  Idempotent per shape (cache hits are free)."""
        mcfg = self.model.cfg
        B = self.cfg.batch_slots
        self.kernel_blocks["flash_attention"] = self._ensure(
            "flash_attention",
            {"B": B, "S": prompt_len, "SK": prompt_len,
             "H": mcfg.padded_heads, "KV": mcfg.n_kv_heads,
             "D": mcfg.head_dim_})
        self.kernel_blocks["rmsnorm_prefill"] = self._ensure(
            "rmsnorm", {"ROWS": B * prompt_len, "D": mcfg.d_model})
        self.kernel_blocks["rmsnorm_decode"] = self._ensure(
            "rmsnorm", {"ROWS": B, "D": mcfg.d_model})

    def _make_retuner(self):
        """The online workload-aware retuner for this engine (cfg.retune).

        The retuner optimises over the same ``serve_knob_space`` the
        offline joint mode tunes — with ``kv_cache_pages`` frozen to the
        pool actually allocated (the device pool is compiled; resizing it
        mid-run would recompile) — and keys its cache entries by the
        SAME shape signature ``launch/tune.py`` uses, so online winners
        and offline joint-tune winners transfer both ways through
        nearest-signature lookup."""
        from repro.autotune import mesh_sig

        from .space import CotuneParams, serve_knob_space
        from .workload import OnlineRetuner

        cfg, mcfg = self.cfg, self.model.cfg
        B = cfg.batch_slots
        base_params = CotuneParams.from_model(mcfg, max_seq=cfg.max_seq)
        # clamp the allocated pool into the knob's range (the space uses
        # the same page_per_seq arithmetic as serve_knob_space) so the
        # frozen value always validates
        lo = max(1, cfg.max_seq // PAGE_TOKENS)
        pages = min(max(cfg.kv_cache_pages, lo), B * lo)
        space = serve_knob_space(cfg.max_seq, max_slots=B).freeze(
            {"kv_cache_pages": pages})
        active = {
            "max_batch": min(cfg.slot_cap or B, B),
            "prefill_chunk": cfg.prefill_chunk,
            "kv_cache_pages": pages,
            "schedule": cfg.schedule,
            "page_policy": cfg.page_policy,
            "share_prefix": int(bool(cfg.share_prefix)),
            "draft_len": cfg.draft_len,
        }
        # the exact dims launch/tune.py keys serve winners under
        sig_dims = {"S": cfg.max_seq, "H": mcfg.padded_heads,
                    "KV": mcfg.n_kv_heads, "D": mcfg.head_dim_}
        return OnlineRetuner(
            space, base_params, baseline=cfg.tuned_signature,
            budget=cfg.retune_budget, threshold=cfg.retune_threshold,
            min_requests=cfg.retune_min_requests,
            cooldown=cfg.retune_cooldown,
            check_every=cfg.retune_check_every, seed=cfg.seed,
            active_config=active, sig_dims=sig_dims,
            dtype=mcfg.compute_dtype,
            # winners persist/resolve at THIS engine's topology only
            # (schema v4 keys by mesh signature)
            mesh=mesh_sig(self.mesh_shape))

    # ------------------------------------------------------------------
    def generate(
        self,
        prompts: Sequence[Sequence[int]],
        max_new_tokens: Union[int, Sequence[int]],
        frontend_embeds: Optional[np.ndarray] = None,
    ) -> GenerationResult:
        """Generate continuations for a batch of requests.

        Under the continuous runtime prompts may have MIXED lengths and
        ``max_new_tokens`` may be per-request; completed requests free
        their slot (and KV pages) for pending ones mid-generation.  The
        wave runtime keeps the historical contract: equal-length prompts
        packed into ``batch_slots``-sized waves, short final wave padded
        with dummies.
        """
        mcfg = self.model.cfg
        if (mcfg.frontend or mcfg.encoder) and frontend_embeds is None:
            # Fail loudly on BOTH prefill paths: the whole-prompt path
            # would KeyError deep in _memory, and the chunked path would
            # silently attend to the cache's zero-initialized memory.
            raise ValueError(
                f"{mcfg.name} has a modality frontend/encoder; generate() "
                "requires frontend_embeds")
        n = len(prompts)
        if isinstance(max_new_tokens, (int, np.integer)):
            max_new = [int(max_new_tokens)] * n
        else:
            max_new = [int(m) for m in max_new_tokens]
            if len(max_new) != n:
                raise ValueError("per-request max_new_tokens length must "
                                 "match the number of prompts")
        if any(m < 1 for m in max_new):
            raise ValueError("max_new_tokens must be >= 1")
        for p, m in zip(prompts, max_new):
            if len(p) + m > self.cfg.max_seq:
                raise ValueError("prompt + generation exceeds max_seq")
        with self._sharding_ctx():
            if self._continuous:
                return self._generate_continuous(prompts, max_new,
                                                 frontend_embeds)
            return self._generate_waves(prompts, max_new, frontend_embeds)

    # ------------------------------------------------------------------
    # wave runtime (legacy exact-parity path)
    # ------------------------------------------------------------------
    def _generate_waves(self, prompts, max_new: List[int],
                        frontend_embeds) -> GenerationResult:
        lens = {len(p) for p in prompts}
        if len(lens) != 1:
            raise ValueError("the wave runtime batches equal-length "
                             f"prompts; got lengths {sorted(lens)} "
                             "(use runtime='continuous' for mixed)")
        (plen,) = lens
        if self.cfg.autotune_kernels:
            self._warm_prefill_blocks(plen)

        slots = self.cfg.batch_slots
        outputs: List[List[int]] = []
        per_request: List[Dict[str, Any]] = []
        prefill_s = decode_s = 0.0
        steps = chunks = 0
        t0 = time.time()
        for wave_start in range(0, len(prompts), slots):
            wave = list(prompts[wave_start:wave_start + slots])
            wave_new = max_new[wave_start:wave_start + slots]
            n_real = len(wave)
            while len(wave) < slots:
                wave.append(wave[0])  # pad with a copy; discarded later
            fe = None
            if frontend_embeds is not None:
                fe = frontend_embeds[wave_start:wave_start + slots]
                if fe.shape[0] < slots:
                    reps = np.repeat(fe[:1], slots - fe.shape[0], axis=0)
                    fe = np.concatenate([fe, reps], axis=0)
            toks, pf, dc, st, nc = self._generate_wave(
                np.asarray(wave, np.int32), max(wave_new), fe)
            wave_done = time.time() - t0
            for i in range(n_real):
                t = toks[i][:wave_new[i]]
                outputs.append(t)
                per_request.append({
                    "rid": wave_start + i, "prompt_len": plen,
                    "new_tokens": len(t),
                    "latency_s": wave_done,
                    "ttft_s": wave_done - dc,
                    "preemptions": 0,  # waves hold slots to completion
                })
            prefill_s += pf
            decode_s += dc
            steps += st
            chunks += nc
        return GenerationResult(outputs, prefill_s, decode_s, steps, chunks,
                                per_request)

    def _generate_wave(self, prompt_arr: np.ndarray, max_new: int,
                       frontend_embeds) -> Any:
        B, P = prompt_arr.shape
        cache = self.model.init_cache(B, max_seq=self.cfg.max_seq)

        chunk = self.cfg.prefill_chunk
        chunked = chunk < P and self.model.supports_chunked_prefill
        # host->device conversion stays OUTSIDE the timed window, so
        # prefill_seconds keeps measuring model time like it always has
        tokens = jnp.asarray(prompt_arr)
        fe = jnp.asarray(frontend_embeds) \
            if frontend_embeds is not None else None
        t0 = time.time()
        if chunked:
            # Chunked prefill: run the prompt through the model in
            # chunk-sized segments, threading the KV cache between calls.
            # Exact (same tokens, same cache) as whole-prompt prefill for
            # the block kinds that support it; the knob trades scheduler
            # granularity against per-chunk dispatch overhead.
            n_chunks = 0
            for start in range(0, P, chunk):
                piece = {"tokens": tokens[:, start:start + chunk]}
                if start == 0 and fe is not None:
                    piece["frontend_embeds"] = fe
                logits, cache = self._prefill_chunk(self.params, piece,
                                                    cache)
                n_chunks += 1
        else:
            batch = {"tokens": tokens}
            if fe is not None:
                batch["frontend_embeds"] = fe
            logits, cache = self._prefill(self.params, batch, cache)
            n_chunks = 1
        logits.block_until_ready()
        prefill_s = time.time() - t0

        rng = jax.random.PRNGKey(self.cfg.seed)
        out = np.zeros((B, max_new), np.int64)
        done = np.zeros(B, bool)
        t0 = time.time()
        produced = 0
        for step in range(max_new):
            tok = self._sample(logits, rng, step)
            out[:, step] = np.asarray(tok[:, 0])
            produced = step + 1
            if self.cfg.eos_token is not None:
                done |= out[:, step] == self.cfg.eos_token
                if done.all():
                    break
            if produced < max_new:
                logits, cache = self._decode(self.params, tok, cache)
        decode_s = time.time() - t0

        results = []
        for b in range(B):
            toks = out[b, :produced].tolist()
            if self.cfg.eos_token is not None and self.cfg.eos_token in toks:
                toks = toks[:toks.index(self.cfg.eos_token) + 1]
            results.append(toks)
        return results, prefill_s, decode_s, produced, n_chunks

    def _sample(self, logits, rng, step):
        lg = logits[:, -1, :self.model.cfg.vocab_size].astype(jnp.float32)
        if self.cfg.temperature <= 0:
            return jnp.argmax(lg, axis=-1, keepdims=True).astype(jnp.int32)
        key = jax.random.fold_in(rng, step)
        return jax.random.categorical(
            key, lg / self.cfg.temperature, axis=-1)[:, None].astype(jnp.int32)

    # ------------------------------------------------------------------
    # continuous-batching runtime
    # ------------------------------------------------------------------
    def _greedy_rows(self, logits):
        lg = logits[:, -1, :self.model.cfg.vocab_size].astype(jnp.float32)
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)

    def _base_key(self, rid: int):
        """Per-request PRNG root.  Token ``i`` of request ``rid`` is always
        sampled with ``fold_in(_base_key(rid), i)`` — BOTH the prefill-tail
        path (``_sample_slot``) and the batched decode path
        (``_categorical_rows``) compose keys this way, which is what makes
        temperature sampling schedule- and slot-placement-invariant."""
        return jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed), rid)

    def _categorical_rows(self, logits, base_keys, produced):
        """Per-slot keys derive from (request id, token index) only, so
        sampled tokens are schedule- and slot-placement-invariant."""
        lg = logits[:, -1, :self.model.cfg.vocab_size].astype(jnp.float32)
        keys = jax.vmap(jax.random.fold_in)(base_keys, produced)
        return jax.vmap(
            lambda k, row: jax.random.categorical(
                k, row / self.cfg.temperature))(keys, lg).astype(jnp.int32)

    def _greedy_grid(self, logits):
        """Greedy over a (B, C, V) speculative-verify grid -> (B, C)
        tokens; column 0 is exactly ``_greedy_rows`` of the single-token
        dispatch."""
        lg = logits[..., :self.model.cfg.vocab_size].astype(jnp.float32)
        return jnp.argmax(lg, axis=-1).astype(jnp.int32)

    def _categorical_grid(self, logits, base_keys, produced):
        """Temperature sampling over a (B, C, V) verify grid: column i of
        slot b keys on ``fold_in(base_key[b], produced[b] + i)`` — the
        key single-token decode would use i steps later — which is what
        makes speculative acceptance token-parity-exact."""
        lg = logits[..., :self.model.cfg.vocab_size].astype(jnp.float32)
        offs = jnp.arange(lg.shape[1], dtype=jnp.int32)

        def row(base, p0, rows):
            return jax.vmap(lambda i, r: jax.random.categorical(
                jax.random.fold_in(base, p0 + i),
                r / self.cfg.temperature))(offs, rows)

        return jax.vmap(row)(base_keys, produced, lg).astype(jnp.int32)

    def _copy_group_blocks(self, blocks, src, dst):
        """Device copy of one physical pool group (the CoW split): every
        paged cache leaf is (n_sub, G, T, KV, D) — copy pool row
        ``src`` into ``dst`` across all blocks."""
        return jax.tree_util.tree_map(
            lambda l: l.at[:, dst].set(l[:, src]), blocks)

    @staticmethod
    def _ngram_draft(history: List[int], k: int, max_n: int = 3,
                     window: int = 0) -> List[int]:
        """Self-drafted continuation: find the most recent earlier
        occurrence of the longest (<= max_n) suffix of ``history`` and
        propose the <= k tokens that followed it.  Pure host-side
        heuristic — a wrong draft costs wasted verify columns, never
        correctness (verification accepts exactly what single-token
        decode would have produced).

        ``window`` bounds the lookback to the trailing ``window`` tokens
        (0 = unbounded).  The unbounded scan was O(len(history)) per
        decode step — O(T^2) over a generation, a real host-side drag on
        long generations.  Generated tokens can NOT depend on the bound:
        drafts only ever change which verify columns are issued, and
        acceptance compares against what single-token decode samples."""
        if window and len(history) > window:
            history = history[-window:]
        L = len(history)
        if k <= 0 or L < 2:
            return []
        for n in range(min(max_n, L - 1), 0, -1):
            suffix = history[L - n:]
            for s in range(L - n - 1, -1, -1):
                if history[s:s + n] == suffix:
                    return history[s + n:s + n + k]
        return []

    def _init_continuous_cache(self):
        """Slot KV state: dense per-slot buffers or the paged pools, plus
        the per-slot frontend memory buffer (never paged — fixed width)."""
        mcfg = self.model.cfg
        B = self.cfg.batch_slots
        if self._paged:
            cache = self.model.init_paged_cache(self.pool_groups,
                                                self.group_tokens)
            if self.mesh is not None:
                # POOL_AXES: page groups stay whole per device, only the
                # KV-head axis follows the model-axis split
                cache = self._shard_tree(
                    cache, self.model.paged_cache_specs(
                        self.pool_groups, self.group_tokens,
                        self.rules, self.mesh))
            if mcfg.frontend or mcfg.encoder:
                from repro.models.common import dtype_of

                cache["memory"] = jnp.zeros(
                    (B, mcfg.frontend_tokens, mcfg.d_model),
                    dtype_of(mcfg.compute_dtype))
        else:
            cache = self.model.init_cache(B, max_seq=self.cfg.max_seq)
            cache.pop("index", None)  # lengths are per-slot host state
            if self.mesh is not None:
                specs = self.model.cache_specs(B, self.cfg.max_seq,
                                               self.rules, self.mesh)
                specs.pop("index", None)
                cache = self._shard_tree(cache, specs)
        return cache

    def _generate_continuous(self, prompts, max_new: List[int],
                             frontend_embeds) -> GenerationResult:
        cfg = self.cfg
        B = cfg.batch_slots
        reqs = []
        for i, p in enumerate(prompts):
            fe = None
            if frontend_embeds is not None:
                fe = np.asarray(frontend_embeds[i:i + 1])
            reqs.append(Request(i, list(p), max_new[i], fe))
        sched = SlotScheduler(cfg.schedule, B, page_policy=cfg.page_policy)
        sched.submit(reqs)
        alloc = None
        prefix = None
        if self._paged:
            # the allocator mirrors the device pool exactly (pool_groups
            # already folds in the one-request minimum / auto-sizing)
            alloc = PageAllocator(self.pool_groups * self.group_pages,
                                  PAGE_TOKENS, self.group_pages)
            page_tables = np.zeros((B, self.max_groups), np.int32)
            # with retuning the registry is kept warm even while sharing
            # is off, so a mid-run swap to share_prefix=1 has resident
            # prompts to match against (matching itself is gated on the
            # live cfg.share_prefix in shared_match)
            if cfg.share_prefix or cfg.retune:
                prefix = PrefixIndex(alloc)
        # on_demand reservations persist after a mid-run swap back to
        # "reserve": live prompt-only reservations still need the decode
        # extend path until they drain, so the latch only ever sets
        ever_on_demand = alloc is not None and sched.on_demand
        cache = self._init_continuous_cache()
        # admission cap (the retuner's max_batch knob): only slots below
        # the cap admit, so physical dispatch shapes never change
        slot_cap = min(cfg.slot_cap or B, B)
        window = retuner = None
        retunes: List[Dict[str, Any]] = []
        seen_rids: set = set()
        if cfg.retune:
            from .workload import WorkloadWindow

            window = WorkloadWindow(capacity=cfg.retune_window)
            retuner = self._make_retuner()
        self.last_retuner = retuner

        # host-side slot state
        slot_req: List[Optional[Request]] = [None] * B
        slot_chunks: List[List[np.ndarray]] = [[] for _ in range(B)]
        slot_first_chunk = [False] * B  # frontend embeds ride chunk 0
        slot_out: List[List[int]] = [[] for _ in range(B)]
        lengths = np.zeros(B, np.int64)
        next_tok = np.zeros(B, np.int32)
        base_keys = jnp.zeros((B,) + jax.random.PRNGKey(0).shape,
                              jax.random.PRNGKey(0).dtype)

        results: List[Optional[List[int]]] = [None] * len(prompts)
        per_request: List[Optional[Dict[str, Any]]] = [None] * len(prompts)
        first_tok_t: Dict[int, float] = {}  # rid -> first-ever-token time
        shared_by_rid: Dict[int, int] = {}  # rid -> shared-admitted tokens
        prefill_s = decode_s = 0.0
        steps = chunks_issued = preemptions = 0
        shared_total = cow_splits = drafted = accepted = 0
        t0 = time.time()

        def run_chunk(b: int) -> None:
            nonlocal cache, prefill_s, chunks_issued
            piece_tokens = slot_chunks[b].pop(0)
            piece = {"tokens": jnp.asarray(piece_tokens)}
            r = slot_req[b]
            if slot_first_chunk[b]:
                slot_first_chunk[b] = False
                if r.frontend_embeds is not None:
                    piece["frontend_embeds"] = jnp.asarray(r.frontend_embeds)
            t = time.time()
            if self._paged:
                logits, new_cache = self._slot_chunk_paged(
                    self.params, piece, cache,
                    jnp.asarray(page_tables[b]),
                    jnp.asarray(lengths[b], jnp.int32),
                    jnp.asarray(b, jnp.int32))
            else:
                logits, new_cache = self._slot_chunk(
                    self.params, piece, cache, jnp.asarray(b, jnp.int32),
                    jnp.asarray(lengths[b], jnp.int32))
            cache = new_cache
            lengths[b] += piece_tokens.shape[1]
            chunks_issued += 1
            if not slot_chunks[b]:  # prefill done: sample the next token
                # publish this prompt's full-chunk groups for sharers;
                # frontend requests never register — their KV depends on
                # the embeds, not just the token ids, so content-matched
                # sharing would alias different activations
                if prefix is not None and r.frontend_embeds is None:
                    prefix.register(list(r.prompt),
                                    [int(g) for g in page_tables[b]])
                # token index = tokens already carried from before a
                # preemption (0 for fresh requests) — the (rid, index)
                # sampling key continues exactly where it left off
                tok = int(np.asarray(self._sample_slot(
                    logits, r.rid, len(slot_out[b]))))
                prefill_s += time.time() - t
                first_tok_t.setdefault(r.rid, time.time())
                accept_token(b, tok)
            else:
                logits.block_until_ready()
                prefill_s += time.time() - t

        def accept_token(b: int, tok: int) -> None:
            r = slot_req[b]
            slot_out[b].append(tok)
            next_tok[b] = tok
            done = len(slot_out[b]) >= r.max_new or (
                cfg.eos_token is not None and tok == cfg.eos_token)
            if done:
                finish_slot(b)

        def clear_slot(b: int) -> None:
            slot_req[b] = None
            slot_out[b] = []
            slot_chunks[b] = []
            lengths[b] = 0
            next_tok[b] = 0
            if alloc is not None:
                page_tables[b, :] = PageAllocator.SCRATCH_GROUP

        def finish_slot(b: int) -> None:
            r = slot_req[b]
            now = time.time()
            results[r.rid] = list(slot_out[b])
            per_request[r.rid] = {
                "rid": r.rid, "prompt_len": r.prompt_len,
                "new_tokens": len(slot_out[b]),
                "latency_s": now - t0,
                "ttft_s": first_tok_t.get(r.rid, now) - t0,
                "preemptions": r.preemptions,
                "shared_tokens": shared_by_rid.get(r.rid, 0),
            }
            if alloc is not None:
                alloc.release(r.rid)
            clear_slot(b)

        def preempt_slot(b: int) -> None:
            """Recompute preemption: capture the victim's generated tokens
            into its request, release its page groups and re-queue it at
            the head — readmission re-prefills prompt+generated and
            continues at the same (rid, token-index) sampling keys."""
            nonlocal preemptions
            r = slot_req[b]
            r.generated = list(slot_out[b])
            r.preemptions += 1
            preemptions += 1
            alloc.release(r.rid)
            clear_slot(b)
            sched.resubmit(r)

        def admit_tokens(r: Request) -> int:
            """The admission reservation: worst-case prompt+max_new under
            ``reserve``, the actual prefill footprint under ``on_demand``
            (decode extends group-by-group from there).  Reads the LIVE
            policy — a retune swap changes what new admissions reserve."""
            if alloc is not None and sched.on_demand:
                return r.resident_tokens
            return r.total_tokens

        def shared_match(r: Request):
            """``(gids, covered, cow)`` the registry offers ``r``: live
            groups whose registered chunks cover a prefix of its
            prompt(+carried tokens), capped one token short of the full
            footprint so at least one suffix token always runs through
            prefill (its logits seed sampling).  ``cow`` is set when the
            suffix's first write lands *inside* the last shared group —
            that group must be split before admission completes.  Gated
            on the LIVE ``cfg.share_prefix`` (a retune knob): with
            sharing off the registry still registers (cheap, keeps it
            warm for a swap) but never matches."""
            if (prefix is None or not cfg.share_prefix
                    or r.frontend_embeds is not None):
                return [], 0, False
            toks = list(r.prompt) + list(r.generated)
            gids, covered = prefix.match(toks)
            covered = min(covered, len(toks) - 1)
            keep = -(-covered // self.group_tokens)
            return gids[:keep], covered, bool(covered % self.group_tokens)

        def try_admit(r: Request):
            """Secure ``r``'s page reservation: take refs on matched
            shared groups, extend with private groups for the rest, and
            CoW-split (allocator swap + device group copy) the boundary
            group the suffix will write into.  Returns ``(groups,
            covered)`` — the logical page-table row and the shared token
            count — or ``None`` when the pool cannot host ``r`` yet."""
            nonlocal cache, cow_splits
            gids, covered, cow = shared_match(r)
            if not gids:
                groups = alloc.try_alloc(r.rid, admit_tokens(r))
                return None if groups is None else (groups, 0)
            alloc.share(r.rid, gids)
            if alloc.extend(r.rid, admit_tokens(r)) is None:
                alloc.release(r.rid)  # undo: the shared refs must not leak
                return None
            if cow:
                old = gids[-1]
                new = alloc.cow_split(r.rid, len(gids) - 1)
                if new is None:
                    alloc.release(r.rid)
                    return None
                # the split group's resident tokens must read identically
                # through the new mapping: copy the physical bytes
                cache = dict(cache, blocks=self._copy_group(
                    cache["blocks"], jnp.asarray(old, jnp.int32),
                    jnp.asarray(new, jnp.int32)))
                cow_splits += 1
            return alloc.owned_groups(r.rid), covered

        def fits_shared(r: Request) -> bool:
            """Free-space test matching ``try_admit``'s arithmetic exactly
            (the sjf bypass scan must never disagree with admission):
            fresh groups needed = full reservation minus shared groups,
            plus one when a CoW split will claim a free group."""
            gids, covered, cow = shared_match(r)
            need = (alloc.groups_for(admit_tokens(r)) - len(gids)
                    + (1 if cow else 0))
            return need <= alloc.free_groups

        def next_admission():
            """(request, groups, covered) for the next admissible request,
            else None.  Head-first in policy order; under ``sjf`` a bounded
            bypass admits the first *fitting* pending request when the
            head's reservation doesn't fit (no head-of-line starvation);
            ``fifo``/``interleave`` stay strictly in order."""
            head = sched.peek()
            if alloc is None:
                return sched.pop(), None, 0
            got = try_admit(head)
            if got is not None:
                sched.pop()
                return head, got[0], got[1]
            if cfg.schedule != "sjf":
                return None
            cand = sched.pop_first_fit(fits_shared)
            if cand is None:
                return None
            got = try_admit(cand)
            # fits_shared IS try_admit's free-space arithmetic, so this
            # cannot be None — admitting with a stale page table would
            # corrupt KV
            assert got is not None, "pop_first_fit/try_admit disagree"
            return cand, got[0], got[1]

        def extend_slot(b: int, want: Optional[int] = None) -> None:
            """Grow slot ``b``'s reservation to cover the next decode
            write (``want`` tokens under speculation: every column of the
            verify chain that could be *accepted* must land in reserved
            groups, not scratch); on pool exhaustion preempt the
            cheapest-recompute victim — resident tokens minus the
            shared-prefix tokens other owners keep alive, ties youngest —
            and retry.  ``b`` itself may be the cheapest and get
            preempted — the caller re-filters ``active`` on ``slot_req``
            afterwards, which drops self-preempted slots from the
            dispatch."""
            r = slot_req[b]
            target = int(lengths[b]) + 1 if want is None else want
            while True:
                new = alloc.extend(r.rid, target)
                if new is not None:
                    if new:
                        grown = alloc.owned_groups(r.rid)
                        page_tables[b, :len(grown)] = grown
                    return
                occupied = [bb for bb in range(B)
                            if slot_req[bb] is not None]
                by_rid = {slot_req[bb].rid: bb for bb in occupied}

                def recompute_cost(rr: Request) -> int:
                    # tokens a preemption would force back through
                    # prefill: resident minus what shared groups keep
                    # alive for its readmission re-match
                    return max(0, int(lengths[by_rid[rr.rid]])
                               - alloc.shared_prefix_tokens(rr.rid))

                victim = SlotScheduler.select_victim(
                    [slot_req[bb] for bb in occupied],
                    cost=recompute_cost)
                vb = by_rid[victim.rid]
                preempt_slot(vb)
                if vb == b:
                    return

        def sample_key_for(b: int) -> None:
            nonlocal base_keys
            if cfg.temperature > 0:
                base_keys = base_keys.at[b].set(
                    self._base_key(slot_req[b].rid))

        def apply_knobs(knob_cfg: Dict[str, Any]) -> Dict[str, Any]:
            """Swap a retuned winner into the running loop at this step
            boundary — no drain, no recompile of live dispatch shapes
            (``max_batch`` caps ADMISSION; the physical slot count is
            compiled; a new ``draft_len`` only keys a different verify
            grid width, which jit caches per shape).  Tokens cannot
            change: sampling keys on (rid, token-index) only, and every
            knob here is token-parity-invariant by construction.
            Returns {knob: (old, new)} for the knobs that moved."""
            nonlocal slot_cap, ever_on_demand
            applied: Dict[str, Any] = {}
            new_cap = min(int(knob_cfg["max_batch"]), B)
            if new_cap != slot_cap:
                applied["max_batch"] = (slot_cap, new_cap)
                slot_cap = new_cap
            new_sched = str(knob_cfg["schedule"])
            if new_sched != cfg.schedule:
                applied["schedule"] = (cfg.schedule, new_sched)
                sched.set_policy(new_sched)  # re-sorts pending
                cfg.schedule = new_sched
            new_pp = str(knob_cfg.get("page_policy", cfg.page_policy))
            if alloc is not None and new_pp != cfg.page_policy:
                applied["page_policy"] = (cfg.page_policy, new_pp)
                sched.set_page_policy(new_pp)
                cfg.page_policy = new_pp
                if new_pp == "on_demand":
                    ever_on_demand = True
            new_chunk = int(knob_cfg["prefill_chunk"])
            if new_chunk != cfg.prefill_chunk:
                applied["prefill_chunk"] = (cfg.prefill_chunk, new_chunk)
                cfg.prefill_chunk = new_chunk
            new_draft = int(knob_cfg.get("draft_len", cfg.draft_len))
            if new_draft != cfg.draft_len:
                applied["draft_len"] = (cfg.draft_len, new_draft)
                cfg.draft_len = new_draft
            new_share = bool(int(knob_cfg.get(
                "share_prefix", int(cfg.share_prefix))))
            if alloc is not None and new_share != cfg.share_prefix:
                applied["share_prefix"] = (cfg.share_prefix, new_share)
                cfg.share_prefix = new_share
            return applied

        def loop() -> None:
            nonlocal cache, decode_s, steps, shared_total, drafted, accepted
            while sched.has_pending or any(r is not None for r in slot_req):
                progressed = False
                # 1. admission into freed slots, in policy order; only
                # slots below slot_cap admit (the max_batch knob — slots
                # at/above a lowered cap simply drain and stay empty)
                for b in range(B):
                    if b >= slot_cap:
                        continue
                    if slot_req[b] is not None or not sched.has_pending:
                        continue
                    admitted = next_admission()
                    if admitted is None:
                        break  # pool full: wait for a release
                    head, groups, covered = admitted
                    if window is not None and head.rid not in seen_rids:
                        seen_rids.add(head.rid)  # re-admissions don't
                        window.record_request(steps, head.prompt,
                                              head.max_new)
                    if groups is not None:
                        page_tables[b, :] = PageAllocator.SCRATCH_GROUP
                        page_tables[b, :len(groups)] = groups
                    if covered:
                        shared_total += covered
                        shared_by_rid[head.rid] = (
                            shared_by_rid.get(head.rid, 0) + covered)
                    slot_req[b] = head
                    lengths[b] = covered
                    chunk = cfg.prefill_chunk
                    # a preempted request re-prefills its prompt plus the
                    # tokens it had generated (exact chunked prefill ⇒
                    # identical cache state to the uninterrupted run);
                    # with prefix sharing the covered leading tokens are
                    # already resident in shared groups, so only the
                    # private suffix is prefilled at all — the TTFT win
                    toks = np.asarray(
                        [(list(head.prompt)
                          + list(head.generated))[covered:]],
                        np.int32)
                    slot_out[b] = list(head.generated)
                    slot_chunks[b] = [toks[:, s:s + chunk]
                                      for s in range(0, toks.shape[1],
                                                     chunk)]
                    slot_first_chunk[b] = True
                    sample_key_for(b)
                    progressed = True
                    if not sched.interleave_prefill:
                        while slot_chunks[b] and slot_req[b] is not None:
                            run_chunk(b)
                # 2. pending prefill chunks: one per slot per step under
                # interleave, drained back-to-back otherwise (the drain
                # arm is only reachable after a retune swaps the policy
                # AWAY from interleave mid-prefill — admission drains
                # non-interleave slots inline above)
                for b in range(B):
                    if slot_req[b] is None or not slot_chunks[b]:
                        continue
                    if sched.interleave_prefill:
                        run_chunk(b)
                    else:
                        while slot_chunks[b] and slot_req[b] is not None:
                            run_chunk(b)
                    progressed = True
                # 3. one batched decode step over every decoding slot —
                # with speculation, draft_len extra n-gram columns ride
                # the same dispatch and the longest sample-matching draft
                # prefix is accepted; under on_demand, first grow
                # reservations to cover the step's KV writes (the whole
                # chain that could be accepted), preempting victims on
                # pool exhaustion
                active = [b for b in range(B)
                          if slot_req[b] is not None and not slot_chunks[b]]
                drafts: Dict[int, List[int]] = {}
                if cfg.draft_len > 0:
                    for b in active:
                        r = slot_req[b]
                        # never draft past the generation budget: tokens
                        # beyond max_new could not be accepted anyway
                        room = r.max_new - len(slot_out[b]) - 1
                        d = self._ngram_draft(
                            _tail_history(r.prompt, slot_out[b],
                                          cfg.draft_window),
                            min(cfg.draft_len, room))
                        if d:
                            drafts[b] = d
                if ever_on_demand:
                    for b in active:
                        if slot_req[b] is None:
                            continue  # preempted as a victim this pass
                        want = None
                        if b in drafts:
                            want = min(
                                int(lengths[b]) + 1 + len(drafts[b]),
                                slot_req[b].total_tokens)
                        extend_slot(b, want)
                    active = [b for b in active
                              if slot_req[b] is not None
                              and not slot_chunks[b]]
                if active and cfg.draft_len > 0:
                    t = time.time()
                    C = cfg.draft_len + 1
                    feed = np.zeros((B, C), np.int32)
                    feed[:, 0] = next_tok
                    for b, d in drafts.items():
                        if slot_req[b] is not None:
                            feed[b, 1:1 + len(d)] = d
                    logits, new_cache = self._decode_multi(
                        self.params, jnp.asarray(feed), cache,
                        jnp.asarray(lengths, jnp.int32),
                        jnp.asarray(page_tables) if self._paged else None)
                    if cfg.temperature <= 0:
                        toks = np.asarray(self._argmax_grid(logits))
                    else:
                        produced = jnp.asarray(
                            [len(slot_out[b]) for b in range(B)], jnp.int32)
                        toks = np.asarray(self._categorical_grid_j(
                            logits, base_keys, produced))
                    cache = new_cache
                    decode_s += time.time() - t
                    steps += 1
                    progressed = True
                    for b in active:
                        d = drafts.get(b, [])
                        drafted += len(d)
                        acc_b = 0
                        # column 0 is the ordinary sampled token (always
                        # accepted); column i+1's logits are valid only
                        # if fed draft token d[i] matched the token
                        # sampled at column i
                        for i in range(C):
                            lengths[b] += 1  # the fed token is resident
                            first_tok_t.setdefault(slot_req[b].rid,
                                                   time.time())
                            tok = int(toks[b, i])
                            accept_token(b, tok)
                            if i > 0:
                                accepted += 1
                                acc_b += 1
                            if slot_req[b] is None:
                                break  # finished mid-chain
                            if i >= len(d) or tok != d[i]:
                                break
                        if window is not None and d:
                            window.record_draft(len(d), acc_b)
                elif active:
                    t = time.time()
                    logits, new_cache = self._decode_multi(
                        self.params, jnp.asarray(next_tok[:, None]), cache,
                        jnp.asarray(lengths, jnp.int32),
                        jnp.asarray(page_tables) if self._paged else None)
                    if cfg.temperature <= 0:
                        toks = np.asarray(self._argmax_multi(logits))
                    else:
                        produced = jnp.asarray(
                            [len(slot_out[b]) for b in range(B)], jnp.int32)
                        toks = np.asarray(self._categorical_multi(
                            logits, base_keys, produced))
                    cache = new_cache
                    decode_s += time.time() - t
                    steps += 1
                    progressed = True
                    for b in active:
                        lengths[b] += 1  # the fed token is now resident
                        first_tok_t.setdefault(slot_req[b].rid, time.time())
                        tok = int(toks[b])
                        if window is not None:
                            # shadow probe: what WOULD 1-token n-gram
                            # drafting have proposed, and would it have
                            # been accepted?  Feeds a measured
                            # acceptance rate even while draft_len=0,
                            # so the retuner can justify switching
                            # speculation ON — without it the loop
                            # could only ever turn it off.
                            pred = self._ngram_draft(
                                _tail_history(slot_req[b].prompt,
                                              slot_out[b],
                                              cfg.draft_window), 1)
                            if pred:
                                window.record_draft(
                                    1, 1 if pred[0] == tok else 0)
                        accept_token(b, tok)
                if window is not None:
                    window.record_depth(
                        sched.queue_depth
                        + sum(1 for r in slot_req if r is not None))
                    hit = retuner.maybe_retune(window, steps)
                    if hit is not None:
                        hit["applied"] = apply_knobs(hit["config"])
                        retunes.append(hit)
                if not progressed:  # defensive: cannot happen (paging.py)
                    raise RuntimeError(
                        "continuous scheduler stalled: pending requests "
                        "but no admissible slot, chunk or decode step")

        try:
            loop()
        except BaseException:
            # error-path unwind: no page group may outlive the generation
            # (a stranded reservation would silently shrink every later
            # run's pool); tests assert check_balanced() after this
            if alloc is not None:
                alloc.release_all()
            raise
        finally:
            # post-run pool introspection (tests/bench), even on unwind
            self.last_alloc = alloc
            self.last_prefix = prefix

        return GenerationResult(
            [list(t) for t in results], prefill_s, decode_s, steps,
            chunks_issued, [dict(r) for r in per_request],
            preemptions=preemptions, shared_prefix_tokens=shared_total,
            cow_splits=cow_splits, drafted=drafted, accepted=accepted,
            retunes=retunes)

    def _sample_slot(self, logits, rid: int, produced: int):
        """Sample ONE request's next token from (1, S, V) logits, keyed by
        the shared (request id, token index) scheme (``_base_key``)."""
        lg = logits[:, -1, :self.model.cfg.vocab_size].astype(jnp.float32)[0]
        if self.cfg.temperature <= 0:
            return jnp.argmax(lg).astype(jnp.int32)
        key = jax.random.fold_in(self._base_key(rid), produced)
        return jax.random.categorical(
            key, lg / self.cfg.temperature).astype(jnp.int32)
