"""Paged KV-cache allocator: free-list of fixed-size page groups.

The serving engine's KV memory is a pool of ``PAGE_TOKENS``-token pages.
Requests own *groups* of ``pages_per_group`` physically-contiguous pages
(the paged decode-attention kernel fetches one group per grid step, so the
group size is simultaneously the allocator granularity and the kernel's
``pages_per_block`` tiling knob — the scheduler×pager×kernel coupling the
co-tuner exercises).  Group 0 is a reserved scratch group: idle engine
slots park their page tables on it, so masked-out decode lanes can never
write into live requests' memory.

Groups are **refcounted**: ``share`` maps an additional owner onto groups
another request already holds (prefix sharing — several page tables point
at one physical group), ``cow_split`` breaks one logical position of an
owner's mapping out into a private copy before a divergent write
(copy-on-write), and ``release`` only returns a group to the free list
when its last owner lets go.  Each group carries a *generation* counter,
bumped every time it is freed, so stale references (the ``PrefixIndex``
registry) can be detected instead of silently aliasing recycled memory.

This module is pure Python/numpy — the device-side pool lives with the
model cache; the allocator only does the bookkeeping (which is exactly
what makes ``kv_cache_pages`` a *real* memory/throughput trade-off: fewer
pages bound how many requests can be resident at once).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["PAGE_TOKENS", "OversubscriptionError", "PageAllocator",
           "PrefixIndex", "min_pages_for"]

PAGE_TOKENS = 16  # KV-cache page granularity (tokens per page)


def min_pages_for(max_tokens: int, pages_per_group: int = 1) -> int:
    """Smallest page budget at which ONE ``max_tokens`` request fits a
    pool of ``pages_per_group``-page groups alongside the reserved
    scratch group — the constructibility floor every paged ``ServeConfig``
    must clear (validation, knob application and the engine's group-size
    clamp all share this one formula)."""
    groups = -(-max(int(max_tokens), 1) // (pages_per_group * PAGE_TOKENS))
    return (groups + 1) * pages_per_group


class OversubscriptionError(ValueError):
    """A single request needs more KV pages than the whole pool holds."""


class PageAllocator:
    """Free-list allocator over groups of ``pages_per_group`` pages.

    ``try_alloc`` is the admission check: it returns the group ids for a
    reservation of ``n_tokens`` tokens, or ``None`` when the pool is
    *temporarily* full (the scheduler defers admission until a running
    request completes and releases its groups).  A request that could
    never fit — even with the pool empty — raises
    ``OversubscriptionError`` instead, so impossible workloads fail
    loudly rather than deadlocking admission.

    ``extend`` is the on-demand growth path (``page_policy="on_demand"``):
    admission reserves only the prompt footprint and decode grows the
    reservation group-by-group; a ``None`` from ``extend`` is the signal
    to preempt a victim (release its groups, re-queue it for recompute)
    and retry.
    """

    SCRATCH_GROUP = 0

    def __init__(self, n_pages: int, page_tokens: int = PAGE_TOKENS,
                 pages_per_group: int = 1):
        if n_pages < 1 or page_tokens < 1 or pages_per_group < 1:
            raise ValueError("n_pages, page_tokens and pages_per_group "
                             "must be >= 1")
        self.n_pages = int(n_pages)
        self.page_tokens = int(page_tokens)
        self.pages_per_group = int(pages_per_group)
        self.group_tokens = self.page_tokens * self.pages_per_group
        # group 0 is scratch; partial trailing pages are unusable (the
        # pool's group layout is what the kernel tiles over)
        self.n_groups = self.n_pages // self.pages_per_group
        if self.n_groups < 2:
            raise ValueError(
                f"pool of {n_pages} pages at {pages_per_group} pages/group "
                "yields no usable groups beyond the reserved scratch group")
        self._free: List[int] = list(range(self.n_groups - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}  # owner id -> group ids
        self._refs: Dict[int, int] = {}  # group id -> owner count (live only)
        self._gen: Dict[int, int] = {}   # group id -> free cycles (staleness)
        self.high_water = 0

    # ------------------------------------------------------------------
    @property
    def usable_groups(self) -> int:
        return self.n_groups - 1

    @property
    def usable_tokens(self) -> int:
        return self.usable_groups * self.group_tokens

    @property
    def free_groups(self) -> int:
        return len(self._free)

    @property
    def groups_in_use(self) -> int:
        return self.usable_groups - len(self._free)

    def groups_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 1) // self.group_tokens)

    def fits(self, n_tokens: int) -> bool:
        """Would ``try_alloc(_, n_tokens)`` succeed right now?  The ONE
        free-space test (admission bypass scans use it, so they can never
        drift from the allocation path's arithmetic)."""
        return self.groups_for(n_tokens) <= len(self._free)

    # ------------------------------------------------------------------
    def try_alloc(self, owner: int, n_tokens: int) -> Optional[List[int]]:
        """Reserve groups covering ``n_tokens`` for ``owner``.

        Returns the group ids (logical order), ``None`` if the pool is
        temporarily full, and raises ``OversubscriptionError`` when the
        request exceeds the pool's total usable capacity.
        """
        if owner in self._owned:
            raise ValueError(f"owner {owner} already holds pages")
        need = self.groups_for(n_tokens)
        if need > self.usable_groups:
            raise OversubscriptionError(
                f"request needs {n_tokens} KV tokens ({need} groups of "
                f"{self.group_tokens}) but the pool holds only "
                f"{self.usable_tokens} usable tokens "
                f"({self.usable_groups} groups) — raise kv_cache_pages")
        if not self.fits(n_tokens):
            return None
        groups = [self._take_free() for _ in range(need)]
        self._owned[owner] = groups
        self.high_water = max(self.high_water, self.groups_in_use)
        return list(groups)

    def _take_free(self) -> int:
        gid = self._free.pop()
        self._refs[gid] = 1
        return gid

    def _drop_ref(self, gid: int) -> bool:
        """Decrement ``gid``'s refcount; free (and age) it at zero.
        Returns True when the group actually went back to the free list."""
        left = self._refs[gid] - 1
        if left > 0:
            self._refs[gid] = left
            return False
        del self._refs[gid]
        self._gen[gid] = self._gen.get(gid, 0) + 1
        self._free.append(gid)
        return True

    def extend(self, owner: int, n_tokens: int) -> Optional[List[int]]:
        """Grow ``owner``'s reservation to cover ``n_tokens`` total tokens.

        The on-demand growth path: a request admitted on a prompt-sized
        reservation calls this as decode crosses group boundaries.  Returns
        the *newly added* group ids (``[]`` when the current reservation
        already covers ``n_tokens``), ``None`` when the pool is temporarily
        full (the caller preempts a victim and retries), and raises
        ``OversubscriptionError`` when ``n_tokens`` exceeds the pool's
        total usable capacity — which, like ``try_alloc``'s, can only
        happen on pools below the one-``max_seq``-request floor the engine
        config already enforces.
        """
        groups = self._owned.get(owner)
        if groups is None:
            raise KeyError(f"owner {owner} holds no pages")
        need = self.groups_for(n_tokens)
        if need > self.usable_groups:
            raise OversubscriptionError(
                f"request grew to {n_tokens} KV tokens ({need} groups of "
                f"{self.group_tokens}) but the pool holds only "
                f"{self.usable_tokens} usable tokens "
                f"({self.usable_groups} groups) — raise kv_cache_pages")
        grow = need - len(groups)
        if grow <= 0:
            return []
        if grow > len(self._free):
            return None
        new = [self._take_free() for _ in range(grow)]
        groups.extend(new)
        self.high_water = max(self.high_water, self.groups_in_use)
        return list(new)

    # ------------------------------------------------------------------
    # prefix sharing: refcounts, copy-on-write, staleness
    # ------------------------------------------------------------------
    def ref(self, gid: int) -> int:
        """Current owner count of ``gid`` (0 = free or never allocated)."""
        return self._refs.get(gid, 0)

    def generation(self, gid: int) -> int:
        """How many times ``gid`` has been freed.  A reference captured at
        generation ``g`` is stale once ``generation(gid) != g`` — the group
        has been recycled and holds someone else's KV."""
        return self._gen.get(gid, 0)

    def share(self, owner: int, gids: Sequence[int]) -> List[int]:
        """Map ``owner`` onto groups other requests already hold (prefix
        sharing): each group's refcount is incremented and the list becomes
        the leading segment of ``owner``'s reservation (grow the private
        tail with ``extend``).  Every group must be live — sharing a free
        group would alias recycled memory."""
        if owner in self._owned:
            raise ValueError(f"owner {owner} already holds pages")
        gids = list(gids)
        for g in gids:
            if g == self.SCRATCH_GROUP:
                raise ValueError("cannot share the scratch group")
            if self._refs.get(g, 0) < 1:
                raise ValueError(f"group {g} is not live; cannot share it")
        for g in gids:
            self._refs[g] += 1
        self._owned[owner] = gids
        return list(gids)

    def cow_split(self, owner: int, logical: int) -> Optional[int]:
        """Copy-on-write: give ``owner`` a private copy slot for logical
        group ``logical`` of its reservation (which must currently be
        shared, refcount >= 2).  Returns the fresh physical group id —
        the caller copies the device bytes and repoints its page table —
        or ``None`` when the pool is temporarily full (preempt + retry)."""
        groups = self._owned.get(owner)
        if groups is None:
            raise KeyError(f"owner {owner} holds no pages")
        old = groups[logical]
        if self._refs.get(old, 0) < 2:
            raise ValueError(
                f"group {old} has a single owner; nothing to split")
        if not self._free:
            return None
        new = self._take_free()
        self._refs[old] -= 1
        groups[logical] = new
        self.high_water = max(self.high_water, self.groups_in_use)
        return new

    def shared_prefix_tokens(self, owner: int) -> int:
        """Token capacity of ``owner``'s leading still-shared groups
        (refcount >= 2).  This is KV that survives the owner's preemption
        — other owners keep the groups live, so readmission re-prefills
        only the private tail; the cost-aware victim selector subtracts it
        from the recompute bill."""
        groups = self._owned.get(owner)
        if groups is None:
            raise KeyError(f"owner {owner} holds no pages")
        n = 0
        for g in groups:
            if self._refs.get(g, 0) < 2:
                break
            n += 1
        return n * self.group_tokens

    def owned_groups(self, owner: int) -> List[int]:
        """The groups ``owner`` currently holds, in logical order."""
        groups = self._owned.get(owner)
        if groups is None:
            raise KeyError(f"owner {owner} holds no pages")
        return list(groups)

    def release(self, owner: int) -> None:
        """Drop ``owner``'s claim on every group it holds.  Groups whose
        refcount hits zero return to the free list (and age a generation);
        groups still shared by other owners stay resident."""
        groups = self._owned.pop(owner, None)
        if groups is None:
            raise KeyError(f"owner {owner} holds no pages")
        for g in reversed(groups):
            self._drop_ref(g)

    def release_all(self) -> int:
        """Release every live reservation (engine unwind path: an exception
        mid-generation must not strand page groups).  Returns the number of
        owners released."""
        owners = list(self._owned)
        for owner in owners:
            self.release(owner)
        return len(owners)

    def check_balanced(self) -> None:
        """Invariant: free + *distinct* owned == usable (no id lost or
        duplicated between the lists), no scratch leakage, and every
        group's refcount equals the number of owners mapping it (never
        zero while owned, absent once free)."""
        counts: Dict[int, int] = {}
        for gs in self._owned.values():
            for g in gs:
                counts[g] = counts.get(g, 0) + 1
        all_ids = self._free + list(counts)
        if len(all_ids) != self.usable_groups or \
                len(set(all_ids)) != len(all_ids) or \
                self.SCRATCH_GROUP in all_ids:
            raise AssertionError(
                f"page-pool imbalance: {len(self._free)} free + "
                f"{len(counts)} distinct owned != {self.usable_groups} "
                "usable (dups or scratch leakage)")
        if counts != self._refs:
            raise AssertionError(
                f"refcount drift: recorded {self._refs} vs actual owner "
                f"counts {counts}")


class PrefixIndex:
    """Registry of fully-prefilled prompt chunks for prefix sharing.

    Keys are *running prefixes*: a chunk registered under prefix ``P``
    means "some live request's prompt starts with ``P + chunk`` and the
    chunk's KV sits, complete, in physical group ``gid``".  ``match``
    walks a new prompt chunk by chunk through the registry and returns
    the groups a sharer can map instead of re-prefilling; the final
    *partial* chunk may boundary-share a registered full chunk whose
    stored tokens extend it (the engine CoW-splits that group before the
    first divergent write).

    Entries are validated lazily against the allocator: a hit requires
    the group to still be live (``ref > 0``) at the generation captured
    when it was registered — a freed-and-recycled group can never be
    handed to a sharer.  Dead entries are pruned as they are seen.

    Sharing is only ever *content-checked* (token tuples compared
    exactly, not hashed), so a registry hit is a guarantee, and only
    full groups of ORIGINAL prompts are registered — generated tokens
    and partial chunks never enter the index.
    """

    def __init__(self, alloc: PageAllocator):
        self.alloc = alloc
        self.group_tokens = alloc.group_tokens
        # running-prefix tuple -> [[chunk tuple, gid, generation], ...]
        self._children: Dict[Tuple[int, ...], List[List[Any]]] = {}

    def _live(self, gid: int, gen: int) -> bool:
        return self.alloc.ref(gid) > 0 and self.alloc.generation(gid) == gen

    def _prune(self, prefix: Tuple[int, ...]) -> List[List[Any]]:
        kids = [e for e in self._children.get(prefix, [])
                if self._live(e[1], e[2])]
        if kids:
            self._children[prefix] = kids
        else:
            self._children.pop(prefix, None)
        return kids

    def match(self, tokens: Sequence[int]) -> Tuple[List[int], int]:
        """-> ``(gids, covered)``: live groups whose registered chunks
        chain-match ``tokens`` from position 0, and the matched token
        count.  A trailing partial chunk counts as covered when a
        registered full chunk extends it (boundary share: its group is
        the last of ``gids``; the caller must CoW before writing into
        it).  Group-granular by construction — a divergence mid-chunk
        shares nothing of that chunk."""
        T = self.group_tokens
        toks = list(tokens)
        gids: List[int] = []
        covered = 0
        prefix: Tuple[int, ...] = ()
        while covered + T <= len(toks):
            chunk = tuple(toks[covered:covered + T])
            hit = next((e for e in self._prune(prefix) if e[0] == chunk),
                       None)
            if hit is None:
                break
            gids.append(hit[1])
            covered += T
            prefix += chunk
        rest = tuple(toks[covered:])
        if rest and covered + len(rest) == len(toks):
            hit = next((e for e in self._prune(prefix)
                        if e[0][:len(rest)] == rest), None)
            if hit is not None:
                gids.append(hit[1])
                covered += len(rest)
        return gids, covered

    def register(self, tokens: Sequence[int], gids: Sequence[int]) -> int:
        """Publish the full-chunk groups of a freshly prefilled prompt:
        group ``k`` of ``gids`` holds chunk ``k`` of ``tokens``.  Chunks
        already covered by a live entry are skipped (first registration
        wins — its group is the one sharers already map).  Returns the
        number of new entries."""
        T = self.group_tokens
        toks = list(tokens)
        added = 0
        prefix: Tuple[int, ...] = ()
        for k in range(len(toks) // T):
            chunk = tuple(toks[k * T:(k + 1) * T])
            kids = self._prune(prefix)
            if not any(e[0] == chunk for e in kids):
                gid = int(gids[k])
                kids.append([chunk, gid, self.alloc.generation(gid)])
                self._children[prefix] = kids
                added += 1
            prefix += chunk
        return added
