"""Paged KV-cache allocator: free-list of fixed-size page groups.

The serving engine's KV memory is a pool of ``PAGE_TOKENS``-token pages.
Requests own *groups* of ``pages_per_group`` physically-contiguous pages
(the paged decode-attention kernel fetches one group per grid step, so the
group size is simultaneously the allocator granularity and the kernel's
``pages_per_block`` tiling knob — the scheduler×pager×kernel coupling the
co-tuner exercises).  Group 0 is a reserved scratch group: idle engine
slots park their page tables on it, so masked-out decode lanes can never
write into live requests' memory.

This module is pure Python/numpy — the device-side pool lives with the
model cache; the allocator only does the bookkeeping (which is exactly
what makes ``kv_cache_pages`` a *real* memory/throughput trade-off: fewer
pages bound how many requests can be resident at once).
"""
from __future__ import annotations

from typing import Dict, List, Optional

__all__ = ["PAGE_TOKENS", "OversubscriptionError", "PageAllocator",
           "min_pages_for"]

PAGE_TOKENS = 16  # KV-cache page granularity (tokens per page)


def min_pages_for(max_tokens: int, pages_per_group: int = 1) -> int:
    """Smallest page budget at which ONE ``max_tokens`` request fits a
    pool of ``pages_per_group``-page groups alongside the reserved
    scratch group — the constructibility floor every paged ``ServeConfig``
    must clear (validation, knob application and the engine's group-size
    clamp all share this one formula)."""
    groups = -(-max(int(max_tokens), 1) // (pages_per_group * PAGE_TOKENS))
    return (groups + 1) * pages_per_group


class OversubscriptionError(ValueError):
    """A single request needs more KV pages than the whole pool holds."""


class PageAllocator:
    """Free-list allocator over groups of ``pages_per_group`` pages.

    ``try_alloc`` is the admission check: it returns the group ids for a
    reservation of ``n_tokens`` tokens, or ``None`` when the pool is
    *temporarily* full (the scheduler defers admission until a running
    request completes and releases its groups).  A request that could
    never fit — even with the pool empty — raises
    ``OversubscriptionError`` instead, so impossible workloads fail
    loudly rather than deadlocking admission.

    ``extend`` is the on-demand growth path (``page_policy="on_demand"``):
    admission reserves only the prompt footprint and decode grows the
    reservation group-by-group; a ``None`` from ``extend`` is the signal
    to preempt a victim (release its groups, re-queue it for recompute)
    and retry.
    """

    SCRATCH_GROUP = 0

    def __init__(self, n_pages: int, page_tokens: int = PAGE_TOKENS,
                 pages_per_group: int = 1):
        if n_pages < 1 or page_tokens < 1 or pages_per_group < 1:
            raise ValueError("n_pages, page_tokens and pages_per_group "
                             "must be >= 1")
        self.n_pages = int(n_pages)
        self.page_tokens = int(page_tokens)
        self.pages_per_group = int(pages_per_group)
        self.group_tokens = self.page_tokens * self.pages_per_group
        # group 0 is scratch; partial trailing pages are unusable (the
        # pool's group layout is what the kernel tiles over)
        self.n_groups = self.n_pages // self.pages_per_group
        if self.n_groups < 2:
            raise ValueError(
                f"pool of {n_pages} pages at {pages_per_group} pages/group "
                "yields no usable groups beyond the reserved scratch group")
        self._free: List[int] = list(range(self.n_groups - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}  # owner id -> group ids
        self.high_water = 0

    # ------------------------------------------------------------------
    @property
    def usable_groups(self) -> int:
        return self.n_groups - 1

    @property
    def usable_tokens(self) -> int:
        return self.usable_groups * self.group_tokens

    @property
    def free_groups(self) -> int:
        return len(self._free)

    @property
    def groups_in_use(self) -> int:
        return self.usable_groups - len(self._free)

    def groups_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 1) // self.group_tokens)

    def fits(self, n_tokens: int) -> bool:
        """Would ``try_alloc(_, n_tokens)`` succeed right now?  The ONE
        free-space test (admission bypass scans use it, so they can never
        drift from the allocation path's arithmetic)."""
        return self.groups_for(n_tokens) <= len(self._free)

    # ------------------------------------------------------------------
    def try_alloc(self, owner: int, n_tokens: int) -> Optional[List[int]]:
        """Reserve groups covering ``n_tokens`` for ``owner``.

        Returns the group ids (logical order), ``None`` if the pool is
        temporarily full, and raises ``OversubscriptionError`` when the
        request exceeds the pool's total usable capacity.
        """
        if owner in self._owned:
            raise ValueError(f"owner {owner} already holds pages")
        need = self.groups_for(n_tokens)
        if need > self.usable_groups:
            raise OversubscriptionError(
                f"request needs {n_tokens} KV tokens ({need} groups of "
                f"{self.group_tokens}) but the pool holds only "
                f"{self.usable_tokens} usable tokens "
                f"({self.usable_groups} groups) — raise kv_cache_pages")
        if not self.fits(n_tokens):
            return None
        groups = [self._free.pop() for _ in range(need)]
        self._owned[owner] = groups
        self.high_water = max(self.high_water, self.groups_in_use)
        return list(groups)

    def extend(self, owner: int, n_tokens: int) -> Optional[List[int]]:
        """Grow ``owner``'s reservation to cover ``n_tokens`` total tokens.

        The on-demand growth path: a request admitted on a prompt-sized
        reservation calls this as decode crosses group boundaries.  Returns
        the *newly added* group ids (``[]`` when the current reservation
        already covers ``n_tokens``), ``None`` when the pool is temporarily
        full (the caller preempts a victim and retries), and raises
        ``OversubscriptionError`` when ``n_tokens`` exceeds the pool's
        total usable capacity — which, like ``try_alloc``'s, can only
        happen on pools below the one-``max_seq``-request floor the engine
        config already enforces.
        """
        groups = self._owned.get(owner)
        if groups is None:
            raise KeyError(f"owner {owner} holds no pages")
        need = self.groups_for(n_tokens)
        if need > self.usable_groups:
            raise OversubscriptionError(
                f"request grew to {n_tokens} KV tokens ({need} groups of "
                f"{self.group_tokens}) but the pool holds only "
                f"{self.usable_tokens} usable tokens "
                f"({self.usable_groups} groups) — raise kv_cache_pages")
        grow = need - len(groups)
        if grow <= 0:
            return []
        if grow > len(self._free):
            return None
        new = [self._free.pop() for _ in range(grow)]
        groups.extend(new)
        self.high_water = max(self.high_water, self.groups_in_use)
        return list(new)

    def owned_groups(self, owner: int) -> List[int]:
        """The groups ``owner`` currently holds, in logical order."""
        groups = self._owned.get(owner)
        if groups is None:
            raise KeyError(f"owner {owner} holds no pages")
        return list(groups)

    def release(self, owner: int) -> None:
        """Return every group owned by ``owner`` to the free list."""
        groups = self._owned.pop(owner, None)
        if groups is None:
            raise KeyError(f"owner {owner} holds no pages")
        self._free.extend(reversed(groups))

    def release_all(self) -> int:
        """Release every live reservation (engine unwind path: an exception
        mid-generation must not strand page groups).  Returns the number of
        owners released."""
        owners = list(self._owned)
        for owner in owners:
            self.release(owner)
        return len(owners)

    def check_balanced(self) -> None:
        """Invariant: free + owned == usable, with no duplicate ids."""
        owned = [g for gs in self._owned.values() for g in gs]
        all_ids = self._free + owned
        if len(all_ids) != self.usable_groups or \
                len(set(all_ids)) != len(all_ids) or \
                self.SCRATCH_GROUP in all_ids:
            raise AssertionError(
                f"page-pool imbalance: {len(self._free)} free + "
                f"{len(owned)} owned != {self.usable_groups} usable "
                f"(dups or scratch leakage)")
