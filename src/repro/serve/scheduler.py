"""Runtime request scheduling for the continuous-batching serve engine.

The tuned ``schedule`` knob acts here — at admission time, not as a
surrogate fiction:

* ``fifo``       — requests enter freed decode slots in arrival order.
* ``sjf``        — shortest-job-first by prompt length (tie: arrival
                   order), trimming mean latency under mixed lengths.
* ``interleave`` — fifo admission, but prefill is issued one
                   ``prefill_chunk`` at a time *between* decode steps, so
                   a long prompt never stalls slots that are decoding.

The scheduler is deliberately engine-agnostic pure Python: it owns the
pending queue and the admission policy; slot/page state stays in the
engine.  ``admission_order`` exposes the policy as a plain function the
calibration tests use to pin the ordering the analytic surrogate's
schedule terms model (``repro.serve.space`` derives those terms in closed
form; the rank-agreement tests are what keep the two honest).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence

__all__ = ["SCHEDULES", "Request", "SlotScheduler", "admission_order"]

SCHEDULES = ("fifo", "sjf", "interleave")


@dataclass
class Request:
    """One generation request as the scheduler sees it."""

    rid: int                  # caller-side index (results keep this order)
    prompt: Sequence[int]
    max_new: int
    frontend_embeds: Optional[Any] = None  # (1, n_tok, dim) or None
    arrival: int = 0          # submission order (fifo/tie-break key)

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def total_tokens(self) -> int:
        """Worst-case KV footprint: the admission reservation size."""
        return self.prompt_len + self.max_new


def admission_order(policy: str, requests: Sequence[Request]) -> List[Request]:
    """The order the policy would admit ``requests`` given free slots.

    ``interleave`` admits fifo — its difference is prefill *timing*, not
    order.  The policy as a plain function, for tests pinning the
    ordering the surrogate's schedule terms assume.
    """
    if policy not in SCHEDULES:
        raise ValueError(f"unknown schedule {policy!r}; have {SCHEDULES}")
    reqs = sorted(requests, key=lambda r: r.arrival)
    if policy == "sjf":
        reqs.sort(key=lambda r: (r.prompt_len, r.arrival))
    return reqs


@dataclass
class SlotScheduler:
    """Pending-queue + admission policy for a fixed set of decode slots."""

    policy: str
    slots: int
    _pending: List[Request] = field(default_factory=list)
    _arrivals: int = 0

    def __post_init__(self):
        if self.policy not in SCHEDULES:
            raise ValueError(f"unknown schedule {self.policy!r}; "
                             f"have {SCHEDULES}")
        if self.slots < 1:
            raise ValueError("need at least one decode slot")

    @property
    def interleave_prefill(self) -> bool:
        """Whether prefill chunks are spread across decode steps."""
        return self.policy == "interleave"

    def submit(self, requests: Sequence[Request]) -> None:
        for r in requests:
            r.arrival = self._arrivals
            self._arrivals += 1
            self._pending.append(r)
        self._pending = admission_order(self.policy, self._pending)

    @property
    def has_pending(self) -> bool:
        return bool(self._pending)

    def peek(self) -> Optional[Request]:
        """The request the policy would admit next (None when drained)."""
        return self._pending[0] if self._pending else None

    def pop(self) -> Request:
        """Admit the head request (call after its resources are secured)."""
        return self._pending.pop(0)
