"""Runtime request scheduling for the continuous-batching serve engine.

The tuned ``schedule`` knob acts here — at admission time, not as a
surrogate fiction:

* ``fifo``       — requests enter freed decode slots in arrival order.
* ``sjf``        — shortest-job-first by prompt length (tie: arrival
                   order), trimming mean latency under mixed lengths.
* ``interleave`` — fifo admission, but prefill is issued one
                   ``prefill_chunk`` at a time *between* decode steps, so
                   a long prompt never stalls slots that are decoding.

The tuned ``page_policy`` knob also lives here — it decides what a KV
reservation *means* at admission:

* ``reserve``    — admission reserves the worst-case ``prompt + max_new``
                   footprint up front; a request can never run out of
                   pages mid-flight, but short actual generations strand
                   the unused tail of every reservation.
* ``on_demand``  — admission reserves only the prompt footprint and the
                   engine grows the reservation group-by-group as decode
                   crosses group boundaries; when the pool runs dry the
                   engine preempts a victim (``select_victim``: the
                   cheapest recompute — least non-shared resident tokens,
                   youngest on ties), releases its claim on its groups
                   and re-queues it at the *head* via ``resubmit``
                   with its generated tokens folded into the prompt, so
                   readmission re-prefills and continues.  Tokens stay
                   bit-identical because sampling is keyed
                   ``(rid, token-index)`` and therefore schedule- and
                   preemption-invariant.

The scheduler is deliberately engine-agnostic pure Python: it owns the
pending queue and the admission policy; slot/page state stays in the
engine.  ``admission_order`` exposes the policy as a plain function the
calibration tests use to pin the ordering the analytic surrogate's
schedule terms model (``repro.serve.space`` derives those terms in closed
form; the rank-agreement tests are what keep the two honest).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Sequence

__all__ = ["SCHEDULES", "PAGE_POLICIES", "TP_MODES", "Request",
           "SlotScheduler", "admission_order", "replica_slices"]

SCHEDULES = ("fifo", "sjf", "interleave")
PAGE_POLICIES = ("reserve", "on_demand")
# How a flat tuned device count maps onto the serve engine's
# (data, model) mesh: "tp" puts every device on the model axis (one
# tensor-parallel engine — heads/ff shard, steps all-reduce), "replicas"
# on the data axis (replicated engines — batch slots spread, capacity
# widens ×K).  A scheduling vocabulary, not a jax concern: the tuning
# space and the feasibility predicates read it without importing jax.
TP_MODES = ("tp", "replicas")


def replica_slices(n_slots: int, data: int) -> List[range]:
    """Slot index ranges per data-axis replica for a widened engine.

    The engine widens ``batch_slots`` ×``data`` and shards the slot axis,
    so replica ``i`` owns the contiguous block
    ``[i * n_slots/data, (i+1) * n_slots/data)`` — the occupancy view the
    surrogate's replica terms model and the bench's per-replica dispatch
    accounting reads.  ``n_slots`` must divide evenly (the engine
    guarantees it by construction: widened = per-replica × data).
    """
    data = max(1, int(data))
    if n_slots % data:
        raise ValueError(f"{n_slots} slots do not split over {data} "
                         f"replicas evenly")
    per = n_slots // data
    return [range(i * per, (i + 1) * per) for i in range(data)]

# bounded sjf admission-bypass window: how many pending requests past a
# non-fitting head the engine may scan for one that fits the page pool
# (bounded so a full pool cannot turn admission into a queue-length scan)
ADMIT_SCAN = 4


@dataclass
class Request:
    """One generation request as the scheduler sees it."""

    rid: int                  # caller-side index (results keep this order)
    prompt: Sequence[int]
    max_new: int
    frontend_embeds: Optional[Any] = None  # (1, n_tok, dim) or None
    arrival: int = -1         # submission order; assigned on FIRST submit
    # tokens produced before a preemption (folded into the re-prefill and
    # carried so readmission continues at the right (rid, token-index))
    generated: List[int] = field(default_factory=list)
    preemptions: int = 0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def resident_tokens(self) -> int:
        """The prefill footprint at (re)admission: the original prompt
        plus any tokens generated before a preemption — what the
        ``on_demand`` policy reserves up front."""
        return self.prompt_len + len(self.generated)

    @property
    def total_tokens(self) -> int:
        """Worst-case KV footprint: the ``reserve`` admission size."""
        return self.prompt_len + self.max_new


def admission_order(policy: str, requests: Sequence[Request]) -> List[Request]:
    """The order the policy would admit ``requests`` given free slots.

    ``interleave`` admits fifo — its difference is prefill *timing*, not
    order.  The policy as a plain function, for tests pinning the
    ordering the surrogate's schedule terms assume.
    """
    if policy not in SCHEDULES:
        raise ValueError(f"unknown schedule {policy!r}; have {SCHEDULES}")
    reqs = sorted(requests, key=lambda r: r.arrival)
    if policy == "sjf":
        reqs.sort(key=lambda r: (r.prompt_len, r.arrival))
    return reqs


@dataclass
class SlotScheduler:
    """Pending-queue + admission policy for a fixed set of decode slots."""

    policy: str
    slots: int
    page_policy: str = "reserve"
    _pending: List[Request] = field(default_factory=list)
    # preempted requests, re-queued ahead of everything pending: they
    # already spent prefill (and decode) work, so they re-enter first
    # regardless of the admission policy
    _resubmitted: List[Request] = field(default_factory=list)
    _arrivals: int = 0

    def __post_init__(self):
        if self.policy not in SCHEDULES:
            raise ValueError(f"unknown schedule {self.policy!r}; "
                             f"have {SCHEDULES}")
        if self.page_policy not in PAGE_POLICIES:
            raise ValueError(f"unknown page_policy {self.page_policy!r}; "
                             f"have {PAGE_POLICIES}")
        if self.slots < 1:
            raise ValueError("need at least one decode slot")

    @property
    def interleave_prefill(self) -> bool:
        """Whether prefill chunks are spread across decode steps."""
        return self.policy == "interleave"

    @property
    def on_demand(self) -> bool:
        """Whether admission reserves prompt-only footprints that the
        engine grows (and, under pressure, preempts) at decode time."""
        return self.page_policy == "on_demand"

    def set_policy(self, policy: str) -> None:
        """Swap the admission policy mid-run (the online retuner's
        ``schedule`` knob): the pending queue re-sorts to the new order;
        resubmitted requests keep their head-of-line priority and
        ``arrival`` stamps are untouched, so fifo fairness and sjf
        tie-breaks stay stable across the swap."""
        if policy not in SCHEDULES:
            raise ValueError(f"unknown schedule {policy!r}; "
                             f"have {SCHEDULES}")
        self.policy = policy
        self._pending = admission_order(policy, self._pending)

    def set_page_policy(self, policy: str) -> None:
        """Swap the reservation policy mid-run: only NEW admissions
        change meaning; live reservations keep their size (the engine's
        extend path grows any prompt-only ones as decode crosses group
        boundaries, a no-op for fully-reserved requests)."""
        if policy not in PAGE_POLICIES:
            raise ValueError(f"unknown page_policy {policy!r}; "
                             f"have {PAGE_POLICIES}")
        self.page_policy = policy

    @property
    def queue_depth(self) -> int:
        """Requests waiting for a slot (pending + preempted re-queued) —
        the demand signal the workload fingerprint's depth averages."""
        return len(self._resubmitted) + len(self._pending)

    def submit(self, requests: Sequence[Request]) -> None:
        for r in requests:
            if r.arrival < 0:  # first submission only: a re-submitted
                r.arrival = self._arrivals  # request keeps its place in
                self._arrivals += 1         # the fifo/tie-break order
            self._pending.append(r)
        self._pending = admission_order(self.policy, self._pending)

    def resubmit(self, request: Request) -> None:
        """Re-queue a preempted request at the head of the line.

        Preempted requests bypass the admission policy: they already hold
        a place in the completed-work order (their prefill and part of
        their decode ran), so they re-enter before anything still pending.
        ``arrival`` is preserved (see ``submit``), keeping fifo fairness
        and sjf tie-breaks stable across preemptions.
        """
        self._resubmitted.append(request)

    @property
    def has_pending(self) -> bool:
        return bool(self._resubmitted) or bool(self._pending)

    def peek(self) -> Optional[Request]:
        """The request the policy would admit next (None when drained)."""
        if self._resubmitted:
            return self._resubmitted[0]
        return self._pending[0] if self._pending else None

    def pop(self) -> Request:
        """Admit the head request (call after its resources are secured)."""
        if self._resubmitted:
            return self._resubmitted.pop(0)
        return self._pending.pop(0)

    def pop_first_fit(self, fits: Callable[[Request], bool],
                      limit: int = ADMIT_SCAN) -> Optional[Request]:
        """Admit the first request within the next ``limit`` queue entries
        for which ``fits`` holds, removing it from the queue.

        The bounded head-of-line bypass: under ``sjf`` a head whose
        reservation does not fit the page pool must not starve smaller
        pending requests that would.  ``fifo`` stays strict (the engine
        only calls this for sjf), and the window is bounded so a full
        pool never costs a whole-queue scan per admission attempt.
        """
        window = max(limit, 1)
        queue = (self._resubmitted[:window]
                 + self._pending[:max(0, window - len(self._resubmitted))])
        for i, r in enumerate(queue):
            if fits(r):
                if i < len(self._resubmitted):
                    return self._resubmitted.pop(i)
                return self._pending.pop(i - len(self._resubmitted))
        return None

    @staticmethod
    def select_victim(running: Sequence[Request],
                      cost: Optional[Callable[[Request], int]] = None
                      ) -> Request:
        """The preemption victim.

        With a ``cost`` function (the engine passes the recompute bill:
        resident tokens minus the shared-prefix tokens that survive the
        preemption), pick the *cheapest-recompute* request — ties broken
        youngest-first (largest arrival, then largest rid) so the oldest
        request can never starve.  Without one, the historical
        youngest-first policy: the least completed work lost."""
        if not running:
            raise ValueError("no running requests to preempt")
        if cost is None:
            return max(running, key=lambda r: (r.arrival, r.rid))
        return min(running, key=lambda r: (cost(r), -r.arrival, -r.rid))
