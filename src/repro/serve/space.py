"""Serve-engine knobs as an ACTS ``ParameterSpace`` + the co-tuning surface.

``serve_knob_space`` exposes the engine's config surface — batch slots,
prefill chunk, KV-cache pages, scheduling policy — to the ordinary tuner
stack, and ``apply_serve_knobs`` maps a tuned config back onto a
``ServeConfig``.  Every knob acts in the engine at runtime: ``max_batch``
sizes the decode slots, ``prefill_chunk`` is the chunked-prefill split
(and the interleave quantum), ``kv_cache_pages`` is the paged allocator's
pool (residency bound), and ``schedule`` is the continuous runtime's
admission policy (``repro.serve.scheduler``).

The rest of the module is the CPU-side **co-deployment surrogate** behind
``python -m repro.launch.tune --joint``, ``benchmarks/cotune_bench.py`` and
the composite tests: an analytic serve-throughput model whose optimum
depends on the decode kernel's block configuration.  The coupling is the
paper's §2.1 phenomenon made concrete, twice over:

* the latency SLA ties them — a slower attention kernel inflates the decode
  step, so the SLA binds at a smaller batch; tuning the serve engine
  against stock kernel blocks therefore lands on a batch size that wastes
  the tuned kernel's headroom;
* co-residency ties them — engine slot state and kernel KV tiles share
  VMEM, so large ``block_kv`` choices that win a kernel-only microbenchmark
  start thrashing at the batch sizes joint tuning wants.

Numbers (weight-stream time, per-token costs, slot bytes) are calibrated to
be *plausible*, not measured.  The **live** path is ``LiveServeSUT`` /
``make_live_cotune_sut`` at the bottom of this module: the same
``CompositeSUT`` wiring wall-clocking the real ``ServeEngine.generate``
(plus the real train step and the decode kernel) — what
``python -m repro.launch.tune --joint --real`` runs.  This module stays
jax-free at import time (numpy only); the live classes import the engine
lazily inside their methods.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.autotune.space import KERNELS, VMEM_BYTES, _dtype_bytes
from repro.core.composite import CompositeSUT
from repro.core.params import Config, EnumParam, IntParam, ParameterSpace
from repro.core.surrogates import Surrogate
from repro.core.tuner import PerfMetric

from .paging import PAGE_TOKENS
from .scheduler import PAGE_POLICIES, SCHEDULES, TP_MODES

__all__ = [
    "PAGE_TOKENS",
    "SCHEDULES",
    "PAGE_POLICIES",
    "TP_MODES",
    "serve_knob_space",
    "apply_serve_knobs",
    "kv_floor_raise_count",
    "CotuneParams",
    "params_for_fingerprint",
    "coupled_serve_metrics",
    "ServeSurrogate",
    "ServeKernelCoupling",
    "make_cotune_sut",
    "LiveServeSUT",
    "LiveCotuneScalarizer",
    "make_live_cotune_sut",
]

# PAGE_TOKENS / SCHEDULES are defined by the runtime modules (paging /
# scheduler, both numpy-only) and re-exported here for the tuning stack.


def serve_knob_space(max_seq: int = 2048, max_slots: int = 64,
                     max_devices: int = 1) -> ParameterSpace:
    """The serve engine's tunable knobs (``ServeConfig`` fields).

    The KV-page range scales with ``max_seq`` so the knob always spans
    "one resident sequence" .. "all ``max_slots`` slots resident" — at the
    default 2048-token serving window it matches ``ServeConfig``'s
    defaults.  The prefill-chunk choices scale DOWN with small windows
    (powers of two, floor max(8, min(128, max_seq/16)), ceiling
    min(max_seq, 2048)) so the knob stays live on the small serving
    windows the wall-clock (``--real``) mode tunes; at ``max_seq`` ≥ 2048
    they are the historical (128, ..., 2048) set.  ``max_slots`` bounds
    the batch-slot knob — live tuning on small hosts caps it so candidate
    engines stay buildable.

    ``max_devices > 1`` widens the space with the SHARDING subspace:
    ``mesh_devices`` (powers of two up to the host's device count) and
    ``tp_vs_replicas`` (which mesh axis those devices land on).  The
    default keeps the historical single-device space — existing cached
    winners and tests see the exact same knob set.
    """
    page_per_seq = max(1, max_seq // PAGE_TOKENS)
    chunk_lo = max(8, min(128, max_seq // 16))
    chunk_choices = tuple(
        c for c in (8, 16, 32, 64, 128, 256, 512, 1024, 2048)
        if chunk_lo <= c <= max_seq) or (max_seq,)
    default_slots = min(8, max_slots)
    mesh_knobs = []
    if max_devices > 1:
        dev_choices = tuple(d for d in (1, 2, 4, 8, 16, 32, 64)
                            if d <= max_devices)
        mesh_knobs = [
            # how many devices the deployed engine spans (1 = unsharded);
            # powers of two so every choice tiles a (data, model) mesh
            EnumParam("mesh_devices", dev_choices, 1),
            # which mesh axis they land on: one K-way tensor-parallel
            # engine (smaller steps, all-reduce per step) vs K replicated
            # engines (K× slot/pool capacity, no collectives) — the
            # optimum flips with queue pressure, which is exactly why the
            # layout is tuned with the schedule instead of hard-coded
            EnumParam("tp_vs_replicas", TP_MODES, "tp"),
        ]
    return ParameterSpace(mesh_knobs + [
        # engine batch slots (ServeConfig.batch_slots)
        IntParam("max_batch", 1, max_slots, default=default_slots, log=True),
        # prefill split size: scheduler granularity vs per-chunk overhead
        EnumParam("prefill_chunk", chunk_choices,
                  chunk_choices[len(chunk_choices) // 2]),
        # KV pool in PAGE_TOKENS-token pages (paged layout: residency
        # bound; dense layout: must cover batch x seq)
        IntParam("kv_cache_pages", page_per_seq, max_slots * page_per_seq,
                 default=default_slots * page_per_seq, log=True),
        # continuous-runtime admission order (scheduler.py)
        EnumParam("schedule", SCHEDULES, "fifo"),
        # paged-layout KV reservation policy: worst-case up-front
        # (reserve) vs prompt-only + on-demand growth with recompute
        # preemption (on_demand) — the optimum genuinely shifts with
        # kv_cache_pages (small pools want on_demand's packing, large
        # pools avoid its bookkeeping), which is what makes it worth
        # co-tuning rather than hard-coding
        EnumParam("page_policy", PAGE_POLICIES, "reserve"),
        # prefix sharing (paged layout): matched prompt-prefix page groups
        # are mapped copy-on-write instead of re-prefilled — the win
        # scales with how much of the workload's prompts actually repeat
        # (CotuneParams.prefix_share_frac), so it is tuned, not assumed
        EnumParam("share_prefix", (0, 1), 0),
        # self-speculative draft length (0 = off): more columns amortize
        # the per-step fixed cost over more accepted tokens, but each
        # column costs verify compute whether accepted or not — the
        # optimum is interior and acceptance-rate-dependent
        EnumParam("draft_len", (0, 2, 4, 8), 0),
    ])


# apply_serve_knobs floor-raise accounting: raising a tuned kv_cache_pages
# to the deployable floor means the deployed config is NOT the config the
# tuner scored.  Fresh tuning runs can no longer produce one (the serve
# feasibility predicate prunes below-floor candidates), but pre-existing
# cached winners still pass through here — so the mutation warns once per
# process and stays countable instead of silent.
_floor_raise_count = 0
_floor_raise_warned = False


def kv_floor_raise_count() -> int:
    """How many times ``apply_serve_knobs`` raised tuned pages this
    process (0 for any winner produced by a feasibility-pruned run)."""
    return _floor_raise_count


def apply_serve_knobs(config: Config, base: Optional[Any] = None):
    """Tuned serve knobs -> a ``ServeConfig`` (lazy engine import: the
    tuning path itself never needs jax).

    The tuned page count was chosen for the *tuning* serving window; the
    deployment's ``max_seq`` may differ.  Pages are raised to the floor a
    constructible config requires — which is layout-aware: the paged
    continuous runtime only needs ONE max_seq request (+ scratch group)
    resident, so the tuner legitimately explores small pools (scored as
    low occupancy by the real engine); the dense layouts allocate the
    full ``slots × max_seq`` footprint, so the floor covers it.

    A raise means tuned != deployed, so it is observable: counted in
    ``kv_floor_raise_count`` and warned once per process.  Runs tuned
    under ``serve_feasibility`` never trigger it — the predicate encodes
    this exact floor — but pre-PR7 cached winners may.
    """
    from .engine import ServeConfig

    base = base or ServeConfig()
    slots = int(config["max_batch"])
    if base.runtime == "continuous" and base.kv_layout == "paged":
        from .paging import min_pages_for

        min_pages = min_pages_for(base.max_seq, base.kv_page_block)
    else:
        min_pages = -(-slots * base.max_seq // PAGE_TOKENS)
    tuned_pages = int(config["kv_cache_pages"])
    if tuned_pages < min_pages:
        global _floor_raise_count, _floor_raise_warned
        _floor_raise_count += 1
        if not _floor_raise_warned:
            _floor_raise_warned = True
            import warnings

            warnings.warn(
                f"apply_serve_knobs raised tuned kv_cache_pages "
                f"{tuned_pages} to the deployable floor {min_pages} "
                f"(max_seq={base.max_seq}, {base.runtime}/"
                f"{base.kv_layout}): the deployed config is not the "
                f"config the tuner scored — re-tune under "
                f"serve_feasibility to make the winner deployable as-is",
                RuntimeWarning, stacklevel=2)
    # sharding subspace -> a concrete (data, model) mesh.  Absent in
    # single-device spaces and pre-PR9 cached winners: keep the base's
    # mesh then.  A tuned mesh_devices=1 explicitly CLEARS the base mesh —
    # "unsharded" was the winner, not an unexpressed opinion.
    tp_mode = str(config.get("tp_vs_replicas", base.tp_vs_replicas))
    mesh_shape = base.mesh_shape
    if "mesh_devices" in config:
        n_dev = int(config["mesh_devices"])
        if n_dev <= 1:
            mesh_shape = None
        elif tp_mode == "replicas":
            mesh_shape = (n_dev, 1)
        else:
            mesh_shape = (1, n_dev)
    return replace(
        base,
        batch_slots=slots,
        prefill_chunk=int(config["prefill_chunk"]),
        kv_cache_pages=max(tuned_pages, min_pages),
        schedule=str(config["schedule"]),
        # absent in pre-PR5 cached winners: keep the base's policy then
        page_policy=str(config.get("page_policy", base.page_policy)),
        # absent in pre-PR6 cached winners: keep the base's settings then
        share_prefix=bool(int(config.get(
            "share_prefix", 1 if base.share_prefix else 0))),
        draft_len=int(config.get("draft_len", base.draft_len)),
        mesh_shape=mesh_shape,
        tp_vs_replicas=tp_mode,
    )


# ---------------------------------------------------------------------------
# the co-deployment surrogate
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CotuneParams:
    """Model shape + serving workload behind the co-deployment surrogate.

    The schedule/paging terms are calibrated against the CONTINUOUS
    runtime (slot-level admission, reservation-based paged allocator) —
    see ``coupled_serve_metrics`` for the derivation and
    ``tests/test_continuous_batching.py`` for the rank-agreement pin
    against the real engine.
    """

    heads: int = 16
    kv_heads: int = 4
    head_dim: int = 64
    n_layers: int = 8
    max_seq: int = 2048
    prompt_len: int = 512
    gen_len: int = 64
    n_requests: int = 64         # queued workload depth behind the SLA
    prompt_spread: float = 0.35  # relative prompt-length variation (sjf win)
    dtype: str = "float32"
    sla_s: float = 0.55          # mean per-request latency SLA
    sla_penalty: float = 2.0     # soft-penalty exponent past the SLA
    weight_stream_s: float = 2e-3   # weights read once per decode step
    per_token_s: float = 5e-5       # non-attention compute per token
    slot_dispatch_s: float = 2e-5   # per-slot decode dispatch state, even idle
    prefill_tok_s: float = 2e-6
    prefill_chunk_overhead_s: float = 1e-3
    interleave_step_factor: float = 1.03  # mixed chunk+decode dispatch cost
    sjf_latency_gain: float = 0.3   # mean-latency win per unit of spread
    page_table_s: float = 2e-8      # per page per step (table walk)
    slot_vmem_bytes: int = 460 * 1024  # engine dispatch state per slot
    kv_buffer_factor: int = 4          # double-buffered k and v tiles
    # on_demand page-policy terms: per-resident-slot allocator bookkeeping
    # each step (reservation growth checks), and the recompute tax — the
    # fraction of an extra prefill paid per over-admitted request when the
    # expected-footprint packing outruns the worst-case-safe one
    extend_check_s: float = 1e-6
    preempt_recompute: float = 0.5
    # prefix-sharing + speculation terms: the fraction of each prompt the
    # workload's requests share (and the pool therefore stores once /
    # prefill skips), the per-draft-token acceptance probability of the
    # n-gram drafter on this workload, and the verify-column cost each
    # draft token adds to a decode dispatch whether accepted or not
    prefix_share_frac: float = 0.25
    spec_accept: float = 0.6
    draft_token_s: float = 1e-5
    # tensor-parallel communication terms: every decode step all-reduces
    # the attention and MLP outputs once per layer (2 collectives/layer),
    # each paying a fixed latency floor plus a ring term proportional to
    # the activation bytes that cross devices ((m-1)/m of them on an
    # m-way model axis).  Without this term TP would dominate replicas
    # unconditionally — the comm floor is what makes the layout a real
    # batch-pressure-dependent trade (the rank-pin test holds the
    # surrogate to the fake-device engine's step counts on both sides).
    allreduce_base_s: float = 3e-5
    allreduce_byte_s: float = 5e-9

    @classmethod
    def from_model(cls, cfg, max_seq: int = 2048, **kw) -> "CotuneParams":
        """Derive the shape fields from a ``ModelConfig``.

        The SLA scales with the serving window (longer contexts mean
        proportionally slower decode steps) unless given explicitly.
        """
        kw.setdefault("sla_s", 0.55 * max_seq / 2048.0)
        return cls(heads=cfg.padded_heads, kv_heads=cfg.n_kv_heads,
                   head_dim=cfg.head_dim_, n_layers=cfg.n_layers,
                   max_seq=max_seq, dtype=cfg.compute_dtype, **kw)

    def decode_dims(self, batch: int) -> Dict[str, int]:
        return {"B": int(batch), "S": self.max_seq, "H": self.heads,
                "KV": self.kv_heads, "D": self.head_dim}

    def default_kernel_config(self) -> Config:
        return KERNELS["decode_attention"].make_space().default_config()

    def kernel_space(self) -> ParameterSpace:
        return KERNELS["decode_attention"].make_space()


def params_for_fingerprint(fp: Any, base: CotuneParams) -> CotuneParams:
    """Measured workload feedback -> surrogate params.

    ``fp`` is a ``repro.serve.workload.WorkloadFingerprint`` (duck-typed
    so this module stays importable without it): the live window's
    MEASURED acceptance rate replaces the ``spec_accept`` constant and
    the measured prefix-share fraction replaces ``prefix_share_frac`` —
    the two terms that were previously assumptions the engine never
    corrected.  ``nan`` acceptance (no draft or probe data yet) keeps the
    prior: absence of evidence must not collapse speculation's term to
    zero.  The length/demand fields re-center the workload shape the
    schedule and paging terms are derived from.
    """
    kw: Dict[str, Any] = {
        "prompt_len": max(1, int(round(fp.prompt_mean))),
        "gen_len": max(1, int(round(fp.gen_mean))),
        "prompt_spread": float(min(max(fp.prompt_spread, 0.0), 1.0)),
        "n_requests": max(1, int(round(fp.depth))),
    }
    if math.isfinite(fp.share_frac):
        kw["prefix_share_frac"] = float(min(max(fp.share_frac, 0.0), 0.95))
    if math.isfinite(fp.accept_rate):
        kw["spec_accept"] = float(min(max(fp.accept_rate, 0.0), 0.99))
    return replace(base, **kw)


def _attn_step_seconds(kernel_cfg: Config, batch: int,
                       p: CotuneParams) -> float:
    """Per-decode-step attention time at this batch, with co-residency.

    The roofline cost model gives the kernel-alone time; on top of it the
    serve engine's per-slot dispatch state competes for VMEM with the
    kernel's (buffered) KV tiles, so oversized ``block_kv`` tilings start
    spilling to HBM exactly at the batch sizes joint tuning cares about.
    """
    base = float(KERNELS["decode_attention"].model_cost(
        kernel_cfg, p.decode_dims(batch), p.dtype))
    ib = _dtype_bytes(p.dtype)
    bk = min(int(kernel_cfg["block_kv"]), p.max_seq)
    tile = p.kv_buffer_factor * bk * p.head_dim * ib
    overflow = (tile + batch * p.slot_vmem_bytes - VMEM_BYTES) / VMEM_BYTES
    if overflow > 0:  # spill: steeper than linear, still smooth
        base *= 1.0 + 16.0 * overflow + 64.0 * overflow * overflow
    return base


def coupled_serve_metrics(serve_cfg: Config, kernel_cfg: Config,
                          p: CotuneParams) -> PerfMetric:
    """End-to-end serve throughput (tokens/s) for one co-deployment config,
    derived from the CONTINUOUS runtime's actual semantics:

    * **Paging is a residency bound, not a thrash factor** — and the
      bound depends on the ``page_policy``.  Under ``reserve`` admission
      holds the worst case: ``ceil((prompt+gen)/PAGE_TOKENS)`` groups per
      request, released at completion, one group held back as scratch —
      resident concurrency ``C = min(max_batch,
      (pages-1) // ceil((prompt+gen)/PAGE_TOKENS))``, the same
      group-granular arithmetic ``PageAllocator.try_alloc`` enforces.
      Under ``on_demand`` admission reserves the prompt only and decode
      grows the reservation, so the pool packs requests by their
      *expected* footprint (a request's residency grows linearly from
      ``prompt`` to ``prompt+gen``, mean ``prompt + gen/2``):
      ``C = min(max_batch, (pages-1) // ceil((prompt+gen/2)/PAGE_TOKENS))``
      — strictly more resident requests on small pools.  The price is a
      per-resident-slot reservation-growth check each step
      (``extend_check_s``) and, past the preemption-free concurrency, a
      recompute tax: over-admitted requests get preempted and re-prefill
      (``preempt_recompute`` of an extra prefill per over-admission) —
      which is why the knob's optimum shifts with pool size instead of
      one policy dominating.  Slots beyond the page bound still cost
      dispatch (masked decode lanes ride every step).
    * **fifo/sjf** stall the decode loop for each admission's prefill
      (chunks run back-to-back at admission), so prefill is paid ``C``
      times per decode cycle: ``T = C·g / (g·step + C·prefill)``.
    * **interleave** issues one prefill chunk per loop iteration between
      decode steps — prefill amortizes once per request, each mixed
      iteration slightly dearer: ``T = C·g / (g·step·factor + prefill)``.
    * **sjf** keeps fifo's throughput but trims MEAN latency in
      proportion to the workload's prompt-length spread (short jobs exit
      first); latency counts queue wait: ``(R+C)/(2C)`` service times for
      an ``R``-deep queue.

    value = throughput under the mean-latency SLA (soft penalty past it);
    metrics carry the raw throughput and the step breakdown.
    Deterministic, so batched/sequential tuner parity is exact.
    """
    B = int(serve_cfg["max_batch"])
    chunk = int(serve_cfg["prefill_chunk"])
    pages = int(serve_cfg["kv_cache_pages"])
    schedule = str(serve_cfg["schedule"])
    policy = str(serve_cfg.get("page_policy", "reserve"))
    share = bool(int(serve_cfg.get("share_prefix", 0)))
    k_draft = int(serve_cfg.get("draft_len", 0))
    # sharding subspace (absent = single device, the historical space):
    # "replicas" widens capacity ×r with replicated weights and no
    # collectives; "tp" shards the per-step compute m ways and pays the
    # all-reduce — exactly the engine's mesh orientation semantics
    n_dev = int(serve_cfg.get("mesh_devices", 1))
    tp_mode = str(serve_cfg.get("tp_vs_replicas", "tp"))
    r_rep = n_dev if (n_dev > 1 and tp_mode == "replicas") else 1
    m_tp = n_dev if (n_dev > 1 and tp_mode == "tp") else 1
    # TP only shards attention when the head count divides the model
    # axis (spec_for_shape's divisibility fallback replicates otherwise);
    # the weight stream still shrinks — ff/vocab columns shard regardless
    m_eff = m_tp if p.heads % m_tp == 0 else 1

    # prefix sharing stores the workload's repeated prompt fraction once
    # (copy-on-write groups) and skips its prefill: each request's
    # PRIVATE footprint shrinks to prompt*(1-f)+gen — which raises
    # residency on page-bound pools — and the prefill term shrinks the
    # same way (TTFT is exactly the prefill no longer issued)
    f_share = p.prefix_share_frac if share else 0.0
    prompt_eff = p.prompt_len * (1.0 - f_share)

    # reservation-based residency: group-granular, minus the scratch
    # group — the allocator's exact admission arithmetic (ppb=1 pools;
    # serve_knob_space does not expose the group-size knob).  reserve
    # packs by the worst-case footprint; on_demand by the EXPECTED one
    # (residency grows linearly from prompt to prompt+gen over a
    # request's lifetime, so the time-averaged footprint is prompt+gen/2)
    groups_worst = math.ceil((prompt_eff + p.gen_len) / PAGE_TOKENS)
    if policy == "on_demand":
        groups_per_req = math.ceil(
            (prompt_eff + p.gen_len / 2.0) / PAGE_TOKENS)
    else:
        groups_per_req = groups_worst
    c_pages = max(1, (pages - 1) // groups_per_req)
    # replicas widen capacity ×r (the knobs are per-replica quantities,
    # matching ServeConfig semantics); each replica hosts c_rep of the C
    # total residents and the replicas step in lockstep
    C = max(1, min(B * r_rep, c_pages * r_rep, p.n_requests))
    c_rep = -(-C // r_rep)

    attn_s = p.n_layers * _attn_step_seconds(kernel_cfg, c_rep, p) / m_eff
    step_s = (p.weight_stream_s / m_tp + c_rep * p.per_token_s + attn_s
              + B * r_rep * p.slot_dispatch_s
              + pages * r_rep * p.page_table_s)
    comm_s = 0.0
    if m_tp > 1:
        # per-step collectives: 2 all-reduces per layer (attention + MLP
        # outputs), fixed latency floor + ring bytes ∝ (m-1)/m — the cost
        # that makes replicas-vs-TP flip with batch pressure instead of
        # TP dominating unconditionally
        act_bytes = c_rep * p.heads * p.head_dim * _dtype_bytes(p.dtype)
        comm_s = p.n_layers * 2 * (
            p.allreduce_base_s
            + p.allreduce_byte_s * act_bytes * (m_tp - 1) / m_tp)
        step_s += comm_s
    if policy == "on_demand":  # per-step reservation-growth bookkeeping
        step_s += c_rep * p.extend_check_s

    # prefill: ceil(prompt/chunk) chunks, each paying fixed overhead —
    # over the NON-shared tail only (shared groups are already resident);
    # TP shards the prefill flops with the same head-divisibility gate
    chunk = min(chunk, max(int(math.ceil(prompt_eff)), 1))
    n_chunks = math.ceil(prompt_eff / chunk)
    prefill_s = n_chunks * (p.prefill_chunk_overhead_s
                            + chunk * p.prefill_tok_s / m_eff)

    # recompute tax: admitting past the preemption-free concurrency means
    # some requests outgrow the pool mid-decode, get preempted and
    # re-prefill — charged as a fraction of an extra prefill per
    # over-admission (zero when the pool covers the worst case at C)
    preempt_frac = 0.0
    if policy == "on_demand":
        c_worst = max(1, min(
            B * r_rep,
            max(1, (pages - 1) // groups_worst) * r_rep,
            p.n_requests))
        preempt_frac = max(0.0, 1.0 - c_worst / float(C))
        prefill_s *= 1.0 + p.preempt_recompute * preempt_frac

    # self-speculative decoding: a draft of k tokens rides every decode
    # dispatch; with per-token acceptance a, each dispatch lands
    # E = sum_{i=0..k} a^i = (1-a^(k+1))/(1-a) tokens in expectation (the
    # first column is the regular decode token and always lands), so g
    # tokens take g/E dispatches, each dearer by k verify columns.  With
    # a == 0 any k > 0 is strictly worse — exactly how the tuner learns
    # to switch speculation off on non-repetitive workloads.
    spec_E = 1.0
    step_eff = step_s
    if k_draft > 0:
        a = min(max(p.spec_accept, 0.0), 0.999)
        spec_E = (1.0 - a ** (k_draft + 1)) / (1.0 - a)
        step_eff = step_s + k_draft * p.draft_token_s

    g = p.gen_len
    decode_cycle = g / spec_E * step_eff
    # fifo/sjf admission stalls are paid per REPLICA (each replica's loop
    # prefills its own c_rep admissions; replicas stall in parallel)
    if schedule == "interleave":
        denom = decode_cycle * p.interleave_step_factor + prefill_s
    else:
        denom = decode_cycle + c_rep * prefill_s
    tput = C * g / denom

    # mean latency: service at residency C + queue wait behind R requests
    service = prefill_s + decode_cycle
    R = max(p.n_requests, C)
    latency = service * (R + C) / (2.0 * C)
    if schedule == "sjf":  # short jobs exit first under mixed lengths
        latency *= 1.0 - p.sjf_latency_gain * p.prompt_spread

    value = tput
    if latency > p.sla_s > 0:
        value = tput * (p.sla_s / latency) ** p.sla_penalty
    return PerfMetric(
        value=float(value), higher_is_better=True,
        metrics={"raw_throughput": float(tput), "latency_s": float(latency),
                 "step_s": float(step_s), "attn_s": float(attn_s),
                 "prefill_s": float(prefill_s),
                 "resident": float(C),
                 "resident_per_replica": float(c_rep),
                 "kv_util": float(C) / float(B * r_rep),
                 "mesh_devices": int(n_dev),
                 "tp_vs_replicas": tp_mode,
                 "comm_s": float(comm_s),
                 "page_policy": policy,
                 "preempt_frac": float(preempt_frac),
                 "share_prefix": bool(share),
                 "draft_len": int(k_draft),
                 "spec_tokens_per_step": float(spec_E),
                 "sla_met": bool(latency <= p.sla_s)})


class ServeSurrogate(Surrogate):
    """The serve engine tuned *in isolation*: the kernel is whatever config
    the serve team deploys against (stock blocks by default) — the
    independent-tuning arm of the co-tuning comparison, and the "serve"
    member of the joint ``CompositeSUT``."""

    name = "serve"

    def __init__(self, params: Optional[CotuneParams] = None,
                 kernel_cfg: Optional[Config] = None,
                 max_devices: int = 1):
        self.params = params or CotuneParams()
        self.kernel_cfg = dict(kernel_cfg) if kernel_cfg \
            else self.params.default_kernel_config()
        self.max_devices = int(max_devices)

    def space(self) -> ParameterSpace:
        return serve_knob_space(self.params.max_seq,
                                max_devices=self.max_devices)

    @property
    def feasibility_model(self):
        """Deployability floor of the paged continuous runtime the
        surrogate models — configs ``apply_serve_knobs`` would mutate are
        pruned before they burn a test (including undeployable meshes:
        device counts that don't divide the host and head counts the
        model axis can't split)."""
        from repro.analysis.feasibility import serve_feasibility

        return serve_feasibility(self.params.max_seq,
                                 n_devices=self.max_devices,
                                 n_heads=self.params.heads,
                                 n_kv_heads=self.params.kv_heads)

    def test_batch(self, configs: Sequence[Config]) -> List[PerfMetric]:
        return [coupled_serve_metrics(c, self.kernel_cfg, self.params)
                for c in configs]


class ServeKernelCoupling:
    """Scalarizer for the joint SUT: the end-to-end measurement.

    Receives every member's subconfig, so the serve throughput is computed
    at the *actual* kernel blocks under test — the interaction the member
    metrics alone cannot express.  The kernel member's standalone cost is
    kept in the metrics for reporting.
    """

    def __init__(self, params: Optional[CotuneParams] = None):
        self.params = params or CotuneParams()

    def __call__(self, metrics: Dict[str, PerfMetric],
                 configs: Dict[str, Config]) -> PerfMetric:
        out = coupled_serve_metrics(configs["serve"], configs["kernel"],
                                    self.params)
        if "kernel" in metrics:
            out.metrics["kernel_alone_s"] = float(metrics["kernel"].value)
        return out


# ---------------------------------------------------------------------------
# the LIVE co-tuning path (wall-clock the real engine; --joint --real)
# ---------------------------------------------------------------------------
class LiveServeSUT:
    """The real ``ServeEngine`` as a system-under-tune.

    Each test maps the candidate knobs onto a ``ServeConfig``
    (``apply_serve_knobs``), builds a fresh engine — the paper's
    apply-config-and-restart loop; the restart cost here is the XLA
    compile, which is exactly why the resource limit counts tests — and
    wall-clocks ``generate`` over a fixed synthetic workload.  Timing uses
    the shared live methodology (``repro.core.sut_jax.median_wall_clock``):
    ``warmup`` untimed calls absorb compilation, then the median of
    ``repeats`` timed calls scores the config.

    The metric is generated tokens/sec; ``latency_s`` (the full-workload
    wall time — every admitted request has finished by then) rides along
    for SLA scalarizers, as do the prefill/decode split and the chunk
    count, so a tuned ``prefill_chunk`` is visible in the provenance.
    """

    def __init__(self, model, params, base: Optional[Any] = None,
                 prompt_len: int = 32, gen_len: int = 8,
                 n_requests: int = 8, warmup: int = 1, repeats: int = 3,
                 seed: int = 0, max_slots: int = 64,
                 max_devices: int = 1):
        from .engine import ServeConfig

        self.model = model
        self.params = params
        self.base = base or ServeConfig(max_seq=128)
        self.max_devices = int(max_devices)
        if prompt_len + gen_len > self.base.max_seq:
            raise ValueError("prompt_len + gen_len exceeds the serving "
                             f"window ({self.base.max_seq})")
        self.prompt_len = prompt_len
        self.gen_len = gen_len
        self.warmup = warmup
        self.repeats = repeats
        self.max_slots = max_slots
        rng = np.random.default_rng(seed)
        self.prompts = rng.integers(
            1, model.cfg.vocab_size, size=(n_requests, prompt_len)).tolist()
        # frontend/encoder models need memory inputs; a fixed synthetic
        # embedding batch keeps the workload deterministic across trials
        self.frontend_embeds = None
        if model.cfg.frontend or model.cfg.encoder:
            self.frontend_embeds = rng.normal(
                size=(n_requests, model.cfg.frontend_tokens,
                      model.cfg.frontend_dim)).astype(np.float32)
        self.name = f"serve-live[{model.cfg.name}]"

    def space(self) -> ParameterSpace:
        return serve_knob_space(self.base.max_seq, self.max_slots,
                                max_devices=self.max_devices)

    @property
    def feasibility_model(self):
        """The deployability floor of THIS deployment base: a below-floor
        candidate would not build the engine the knobs describe
        (``apply_serve_knobs`` would silently resize it), a mesh the host
        cannot tile would refuse to build at all, and on the live path
        each such trial would also pay an XLA compile to score a mutated
        config."""
        from repro.analysis.feasibility import serve_feasibility

        return serve_feasibility(
            self.base.max_seq, runtime=self.base.runtime,
            kv_layout=self.base.kv_layout,
            kv_page_block=self.base.kv_page_block,
            n_devices=self.max_devices,
            n_heads=self.model.cfg.padded_heads,
            n_kv_heads=self.model.cfg.n_kv_heads)

    def test(self, config: Config) -> PerfMetric:
        from repro.core.sut_jax import median_wall_clock

        from .engine import ServeEngine

        scfg = apply_serve_knobs(config, self.base)
        engine = ServeEngine(self.model, self.params, scfg)
        out: Dict[str, Any] = {}

        def run():
            out["res"] = engine.generate(
                self.prompts, self.gen_len,
                frontend_embeds=self.frontend_embeds)

        wall = median_wall_clock(run, self.warmup, self.repeats)
        res = out["res"]
        n_tok = sum(len(t) for t in res.tokens)
        tput = n_tok / max(wall, 1e-9)
        return PerfMetric(
            value=float(tput), higher_is_better=True,
            metrics={"latency_s": float(wall),
                     "prefill_s": float(res.prefill_seconds),
                     "decode_s": float(res.decode_seconds),
                     "prefill_chunks": int(res.prefill_chunks),
                     "steps": int(res.steps), "tokens": int(n_tok),
                     "warmup": self.warmup, "repeats": self.repeats})


class LiveCotuneScalarizer:
    """Joint objective for the live composite (serve + train + kernel).

    value = serve tokens/s, SLA-penalized when ``sla_s > 0`` (smooth
    ``(sla/lat)**penalty`` past the bound, like the surrogate), scaled by
    the decode kernel's speedup over its default tiling raised to
    ``kernel_coupling`` (the kernel member measures/models in isolation;
    the exponent is roughly the attention share of a decode step), plus
    train tokens/s at the ``train_weight`` exchange rate (co-located
    training shares the host; its tokens are worth a fraction of a served
    token).  Every member's raw value is kept in the metrics.
    """

    def __init__(self, sla_s: float = 0.0, penalty: float = 2.0,
                 train_weight: float = 0.25,
                 kernel_coupling: float = 0.25,
                 kernel_ref: Optional[float] = None):
        self.sla_s = sla_s
        self.penalty = penalty
        self.train_weight = train_weight
        self.kernel_coupling = kernel_coupling
        self.kernel_ref = kernel_ref

    def __call__(self, metrics: Dict[str, PerfMetric],
                 configs: Dict[str, Config]) -> PerfMetric:
        serve = metrics["serve"]
        lat = float(serve.metrics["latency_s"])
        value = float(serve.value)
        sla_met = True
        if self.sla_s > 0 and lat > self.sla_s:
            sla_met = False
            value *= (self.sla_s / lat) ** self.penalty
        kern = metrics.get("kernel")
        kernel_speedup = 1.0
        if kern is not None and self.kernel_ref:
            kernel_speedup = self.kernel_ref / max(float(kern.value), 1e-12)
            value *= kernel_speedup ** self.kernel_coupling
        train = metrics.get("train")
        train_tput = float(train.value) if train is not None else 0.0
        value += self.train_weight * train_tput
        return PerfMetric(
            value=float(value), higher_is_better=True,
            metrics={"serve_tput": float(serve.value),
                     "latency_s": lat, "sla_met": bool(sla_met),
                     "train_tput": train_tput,
                     "kernel_speedup": float(kernel_speedup),
                     "prefill_chunks": serve.metrics.get("prefill_chunks")})


def make_live_cotune_sut(model_cfg, *, max_seq: int = 128,
                         prompt_len: int = 32, gen_len: int = 8,
                         n_requests: int = 8, max_slots: int = 8,
                         train_seq: int = 32, train_batch: int = 8,
                         warmup: int = 1, repeats: int = 3, seed: int = 0,
                         sla_s: float = 0.0, train_weight: float = 0.25,
                         max_devices: int = 1) -> CompositeSUT:
    """Serve engine + train step + decode kernel as ONE live SUT.

    Unlike ``make_cotune_sut`` (the analytic surrogate), every serve/train
    test here wall-clocks the real system: the engine is rebuilt under the
    candidate knobs and timed end to end, and the train step is re-jitted
    and timed.  The kernel member stays the roofline model on CPU and
    wall-clocks on real accelerator backends (``KernelSUT`` mode
    auto-detect); its default-tiling cost is measured once up front as the
    speedup reference the scalarizer couples through.
    """
    import jax

    from repro.autotune.sut import KernelSUT
    from repro.core.sut_jax import TrainStepSUT
    from repro.models import Model

    from .engine import ServeConfig

    model = Model(model_cfg)
    params = model.init(jax.random.PRNGKey(seed))
    # paged continuous runtime: schedule AND kv_cache_pages act in the
    # engine being wall-clocked, so the live joint mode really tunes the
    # scheduler x pager x kernel interaction (stacks without continuous
    # support fall back to the wave loop inside the engine)
    base = ServeConfig(max_seq=max_seq, kv_layout="paged")
    serve = LiveServeSUT(model, params, base=base, prompt_len=prompt_len,
                         gen_len=gen_len, n_requests=n_requests,
                         warmup=warmup, repeats=repeats, seed=seed,
                         max_slots=max_slots, max_devices=max_devices)
    train = TrainStepSUT(model_cfg, seq_len=train_seq,
                         global_batch=train_batch, warmup=warmup,
                         repeats=repeats, seed=seed)
    default_batch = int(serve.space()["max_batch"].default)
    dims = {"B": default_batch, "S": max_seq, "H": model_cfg.padded_heads,
            "KV": model_cfg.n_kv_heads, "D": model_cfg.head_dim_}
    kernel = KernelSUT("decode_attention", dims,
                       dtype=model_cfg.compute_dtype)
    kernel_ref = float(
        kernel.test(kernel.space().default_config()).value)
    return CompositeSUT(
        {"serve": serve, "train": train, "kernel": kernel},
        scalarize=LiveCotuneScalarizer(
            sla_s=sla_s, train_weight=train_weight, kernel_ref=kernel_ref),
        name="serve+train+kernel:live",
    )


def make_cotune_sut(params: Optional[CotuneParams] = None,
                    max_devices: int = 1) -> CompositeSUT:
    """Serve engine + decode kernel as one SUT under one budget.

    The serve subsystem is config-only: its end-to-end measurement IS the
    scalarizer (which needs the kernel blocks), so a standalone serve
    evaluation would be recomputed-and-discarded work.  The kernel member
    still runs — its microbenchmark cost is the ``kernel_alone_s``
    provenance in every joint metric.  ``max_devices > 1`` widens the
    serve member with the sharding subspace, so the joint mode co-tunes
    layout with schedule/pager/kernel blocks.
    """
    from repro.analysis.feasibility import serve_feasibility
    from repro.autotune.sut import KernelSUT

    params = params or CotuneParams()
    default_batch = int(serve_knob_space(params.max_seq)["max_batch"].default)
    return CompositeSUT(
        {
            "serve": serve_knob_space(params.max_seq,
                                      max_devices=max_devices),
            # the kernel team's microbenchmark shape: stock serve batch,
            # no co-residency — exactly what tuning it in isolation sees
            "kernel": KernelSUT("decode_attention",
                                params.decode_dims(default_batch),
                                dtype=params.dtype, mode="model"),
        },
        scalarize=ServeKernelCoupling(params),
        name="serve+kernel",
        # the serve member is config-only (a bare space has no SUT to
        # carry a model), so its deployability predicates attach here;
        # the kernel member's model is auto-detected off the KernelSUT
        feasibility={"serve": serve_feasibility(
            params.max_seq, n_devices=max_devices,
            n_heads=params.heads, n_kv_heads=params.kv_heads)},
    )
