"""Serve-engine knobs as an ACTS ``ParameterSpace`` + the co-tuning surface.

``serve_knob_space`` exposes the engine's config surface — batch slots,
prefill chunk, KV-cache pages, scheduling policy — to the ordinary tuner
stack, and ``apply_serve_knobs`` maps a tuned config back onto a
``ServeConfig``.  Today ``batch_slots`` and the KV-page capacity act in the
engine at runtime; ``prefill_chunk`` and ``schedule`` are validated,
modelled by the surrogate below, and get their runtime wiring with paged
attention / continuous batching (see the field notes on ``ServeConfig``).

The rest of the module is the CPU-side **co-deployment surrogate** behind
``python -m repro.launch.tune --joint``, ``benchmarks/cotune_bench.py`` and
the composite tests: an analytic serve-throughput model whose optimum
depends on the decode kernel's block configuration.  The coupling is the
paper's §2.1 phenomenon made concrete, twice over:

* the latency SLA ties them — a slower attention kernel inflates the decode
  step, so the SLA binds at a smaller batch; tuning the serve engine
  against stock kernel blocks therefore lands on a batch size that wastes
  the tuned kernel's headroom;
* co-residency ties them — engine slot state and kernel KV tiles share
  VMEM, so large ``block_kv`` choices that win a kernel-only microbenchmark
  start thrashing at the batch sizes joint tuning wants.

Numbers (weight-stream time, per-token costs, slot bytes) are calibrated to
be *plausible*, not measured — on a real TPU the same ``CompositeSUT``
wiring wall-clocks the live engine instead.  This module stays numpy-only
(no jax import) so the tuning path is cheap to spin up.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence

from repro.autotune.space import KERNELS, VMEM_BYTES, _dtype_bytes
from repro.core.composite import CompositeSUT
from repro.core.params import Config, EnumParam, IntParam, ParameterSpace
from repro.core.surrogates import Surrogate
from repro.core.tuner import PerfMetric

__all__ = [
    "PAGE_TOKENS",
    "SCHEDULES",
    "serve_knob_space",
    "apply_serve_knobs",
    "CotuneParams",
    "coupled_serve_metrics",
    "ServeSurrogate",
    "ServeKernelCoupling",
    "make_cotune_sut",
]

PAGE_TOKENS = 16  # KV-cache page granularity (tokens per page)
SCHEDULES = ("fifo", "sjf", "interleave")


def serve_knob_space(max_seq: int = 2048) -> ParameterSpace:
    """The serve engine's tunable knobs (``ServeConfig`` fields).

    The KV-page range scales with ``max_seq`` so the knob always spans
    "one resident sequence" .. "all 64 slots resident" — at the default
    2048-token serving window it matches ``ServeConfig``'s defaults.
    """
    page_per_seq = max(1, max_seq // PAGE_TOKENS)
    return ParameterSpace([
        # engine batch slots (ServeConfig.batch_slots)
        IntParam("max_batch", 1, 64, default=8, log=True),
        # prefill split size: scheduler granularity vs per-chunk overhead
        EnumParam("prefill_chunk", (128, 256, 512, 1024, 2048), 512),
        # KV capacity in PAGE_TOKENS-token pages (must cover batch x seq)
        IntParam("kv_cache_pages", page_per_seq, 64 * page_per_seq,
                 default=8 * page_per_seq, log=True),
        # wave admission order
        EnumParam("schedule", SCHEDULES, "fifo"),
    ])


def apply_serve_knobs(config: Config, base: Optional[Any] = None):
    """Tuned serve knobs -> a ``ServeConfig`` (lazy engine import: the
    tuning path itself never needs jax).

    The tuned page count was chosen for the *tuning* serving window; the
    deployment's ``max_seq`` may differ (and the tuner legitimately
    explores undersized caches, which it scores as thrash).  Pages are
    therefore raised to the floor the deployed batch actually requires, so
    a persisted winner always produces a constructible config.
    """
    from .engine import ServeConfig

    base = base or ServeConfig()
    slots = int(config["max_batch"])
    min_pages = -(-slots * base.max_seq // PAGE_TOKENS)
    return replace(
        base,
        batch_slots=slots,
        prefill_chunk=int(config["prefill_chunk"]),
        kv_cache_pages=max(int(config["kv_cache_pages"]), min_pages),
        schedule=str(config["schedule"]),
    )


# ---------------------------------------------------------------------------
# the co-deployment surrogate
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class CotuneParams:
    """Model shape + serving workload behind the co-deployment surrogate."""

    heads: int = 16
    kv_heads: int = 4
    head_dim: int = 64
    n_layers: int = 8
    max_seq: int = 2048
    prompt_len: int = 512
    gen_len: int = 64
    dtype: str = "float32"
    sla_s: float = 0.55          # per-request latency SLA
    sla_penalty: float = 2.0     # soft-penalty exponent past the SLA
    weight_stream_s: float = 2e-3   # weights read once per decode step
    per_token_s: float = 5e-5       # non-attention compute per token
    prefill_tok_s: float = 2e-6
    prefill_chunk_overhead_s: float = 1e-3
    page_table_s: float = 2e-8      # per page per step (table walk)
    slot_vmem_bytes: int = 460 * 1024  # engine dispatch state per slot
    kv_buffer_factor: int = 4          # double-buffered k and v tiles

    @classmethod
    def from_model(cls, cfg, max_seq: int = 2048, **kw) -> "CotuneParams":
        """Derive the shape fields from a ``ModelConfig``.

        The SLA scales with the serving window (longer contexts mean
        proportionally slower decode steps) unless given explicitly.
        """
        kw.setdefault("sla_s", 0.55 * max_seq / 2048.0)
        return cls(heads=cfg.padded_heads, kv_heads=cfg.n_kv_heads,
                   head_dim=cfg.head_dim_, n_layers=cfg.n_layers,
                   max_seq=max_seq, dtype=cfg.compute_dtype, **kw)

    def decode_dims(self, batch: int) -> Dict[str, int]:
        return {"B": int(batch), "S": self.max_seq, "H": self.heads,
                "KV": self.kv_heads, "D": self.head_dim}

    def default_kernel_config(self) -> Config:
        return KERNELS["decode_attention"].make_space().default_config()

    def kernel_space(self) -> ParameterSpace:
        return KERNELS["decode_attention"].make_space()


def _attn_step_seconds(kernel_cfg: Config, batch: int,
                       p: CotuneParams) -> float:
    """Per-decode-step attention time at this batch, with co-residency.

    The roofline cost model gives the kernel-alone time; on top of it the
    serve engine's per-slot dispatch state competes for VMEM with the
    kernel's (buffered) KV tiles, so oversized ``block_kv`` tilings start
    spilling to HBM exactly at the batch sizes joint tuning cares about.
    """
    base = float(KERNELS["decode_attention"].model_cost(
        kernel_cfg, p.decode_dims(batch), p.dtype))
    ib = _dtype_bytes(p.dtype)
    bk = min(int(kernel_cfg["block_kv"]), p.max_seq)
    tile = p.kv_buffer_factor * bk * p.head_dim * ib
    overflow = (tile + batch * p.slot_vmem_bytes - VMEM_BYTES) / VMEM_BYTES
    if overflow > 0:  # spill: steeper than linear, still smooth
        base *= 1.0 + 16.0 * overflow + 64.0 * overflow * overflow
    return base


def coupled_serve_metrics(serve_cfg: Config, kernel_cfg: Config,
                          p: CotuneParams) -> PerfMetric:
    """End-to-end serve throughput (tokens/s) for one co-deployment config.

    value = decode throughput under the latency SLA (soft penalty past it);
    metrics carry the raw throughput, per-request latency and the step
    breakdown.  Deterministic, so batched/sequential tuner parity is exact.
    """
    B = int(serve_cfg["max_batch"])
    chunk = int(serve_cfg["prefill_chunk"])
    pages = int(serve_cfg["kv_cache_pages"])
    schedule = str(serve_cfg["schedule"])

    attn_s = p.n_layers * _attn_step_seconds(kernel_cfg, B, p)
    step_s = (p.weight_stream_s + B * p.per_token_s + attn_s
              + pages * p.page_table_s)

    # prefill: ceil(prompt/chunk) chunks, each paying fixed overhead
    chunk = min(chunk, p.prompt_len)
    n_chunks = math.ceil(p.prompt_len / chunk)
    prefill_s = n_chunks * (p.prefill_chunk_overhead_s
                            + chunk * p.prefill_tok_s)
    if schedule == "interleave":  # prefill overlapped with decode
        prefill_s *= 0.4
        step_s *= 1.03

    # KV pages must cover the live batch; undersizing thrashes on eviction
    needed = B * p.max_seq
    capacity = pages * PAGE_TOKENS
    util = min(1.0, capacity / needed) ** 2

    tput = B * p.gen_len * util / (prefill_s + p.gen_len * step_s)
    latency = prefill_s + p.gen_len * step_s
    if schedule == "sjf":  # shortest-job-first trims mean request latency
        latency *= 0.9

    value = tput
    if latency > p.sla_s > 0:
        value = tput * (p.sla_s / latency) ** p.sla_penalty
    return PerfMetric(
        value=float(value), higher_is_better=True,
        metrics={"raw_throughput": float(tput), "latency_s": float(latency),
                 "step_s": float(step_s), "attn_s": float(attn_s),
                 "prefill_s": float(prefill_s), "kv_util": float(util),
                 "sla_met": bool(latency <= p.sla_s)})


class ServeSurrogate(Surrogate):
    """The serve engine tuned *in isolation*: the kernel is whatever config
    the serve team deploys against (stock blocks by default) — the
    independent-tuning arm of the co-tuning comparison, and the "serve"
    member of the joint ``CompositeSUT``."""

    name = "serve"

    def __init__(self, params: Optional[CotuneParams] = None,
                 kernel_cfg: Optional[Config] = None):
        self.params = params or CotuneParams()
        self.kernel_cfg = dict(kernel_cfg) if kernel_cfg \
            else self.params.default_kernel_config()

    def space(self) -> ParameterSpace:
        return serve_knob_space(self.params.max_seq)

    def test_batch(self, configs: Sequence[Config]) -> List[PerfMetric]:
        return [coupled_serve_metrics(c, self.kernel_cfg, self.params)
                for c in configs]


class ServeKernelCoupling:
    """Scalarizer for the joint SUT: the end-to-end measurement.

    Receives every member's subconfig, so the serve throughput is computed
    at the *actual* kernel blocks under test — the interaction the member
    metrics alone cannot express.  The kernel member's standalone cost is
    kept in the metrics for reporting.
    """

    def __init__(self, params: Optional[CotuneParams] = None):
        self.params = params or CotuneParams()

    def __call__(self, metrics: Dict[str, PerfMetric],
                 configs: Dict[str, Config]) -> PerfMetric:
        out = coupled_serve_metrics(configs["serve"], configs["kernel"],
                                    self.params)
        if "kernel" in metrics:
            out.metrics["kernel_alone_s"] = float(metrics["kernel"].value)
        return out


def make_cotune_sut(params: Optional[CotuneParams] = None) -> CompositeSUT:
    """Serve engine + decode kernel as one SUT under one budget.

    The serve subsystem is config-only: its end-to-end measurement IS the
    scalarizer (which needs the kernel blocks), so a standalone serve
    evaluation would be recomputed-and-discarded work.  The kernel member
    still runs — its microbenchmark cost is the ``kernel_alone_s``
    provenance in every joint metric.
    """
    from repro.autotune.sut import KernelSUT

    params = params or CotuneParams()
    default_batch = int(serve_knob_space(params.max_seq)["max_batch"].default)
    return CompositeSUT(
        {
            "serve": serve_knob_space(params.max_seq),
            # the kernel team's microbenchmark shape: stock serve batch,
            # no co-residency — exactly what tuning it in isolation sees
            "kernel": KernelSUT("decode_attention",
                                params.decode_dims(default_batch),
                                dtype=params.dtype, mode="model"),
        },
        scalarize=ServeKernelCoupling(params),
        name="serve+kernel",
    )
